# Build entry points. `make artifacts` is the one the Rust error
# messages reference: it AOT-lowers every model to HLO text + manifest
# (requires Python + JAX; the Rust side never does).

.PHONY: artifacts artifacts-large fixtures build test bench doc

artifacts:
	cd python && python -m compile.aot --outdir ../artifacts

artifacts-large:
	cd python && python -m compile.aot --outdir ../artifacts --large

# Numeric fixtures only (no HLO lowering): the python-reference loss
# sequences rust/tests/fixture_replay.rs replays. The native_mlp fixture
# is committed, so this is only needed to regenerate after model edits.
fixtures:
	cd python && python -m compile.aot --outdir ../artifacts --fixtures-only

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
