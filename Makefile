# Build entry points. `make artifacts` is the one the Rust error
# messages reference: it AOT-lowers every model to HLO text + manifest
# (requires Python + JAX; the Rust side never does).

.PHONY: artifacts artifacts-large build test bench doc

artifacts:
	cd python && python -m compile.aot --outdir ../artifacts

artifacts-large:
	cd python && python -m compile.aot --outdir ../artifacts --large

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
