//! Property-testing substrate — a focused replacement for the `proptest`
//! crate (unavailable offline). Provides seeded generators and a runner
//! that, on failure, reports the failing case's seed and attempts a simple
//! input-size minimization by re-running the property on shrunken clones.
//!
//! Usage:
//! ```ignore
//! check(200, |g| {
//!     let rows = g.usize(1, 64);
//!     let v = g.vec_f32(rows, 0.0, 10.0);
//!     prop_assert(some_invariant(&v), "invariant broke");
//!     Ok(())
//! });
//! ```

use crate::rng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    /// Log-uniform positive float (spans magnitudes, like LR grids).
    pub fn log_f64(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform(lo.ln(), hi.ln())).exp()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize_below(xs.len())]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.rng.normal() * std) as f32).collect()
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Full-precision normal draws — kernel-equivalence inputs, where
    /// casting through f32 would mask reassociation error.
    pub fn vec_normal_f64(&mut self, n: usize, std: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal() * std).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Arbitrary unicode string exercising the JSON escape space:
    /// ASCII, quotes/backslashes, control chars, BMP and astral
    /// (surrogate-pair) code points.
    pub fn json_string(&mut self, max_len: usize) -> String {
        let n = self.usize(0, max_len);
        (0..n)
            .map(|_| match self.usize(0, 9) {
                0 => '"',
                1 => '\\',
                2 => char::from_u32(self.usize(0, 0x1f) as u32).unwrap(),
                3 => 'é',
                4 => '→',
                5 => '😀', // astral: encodes as a surrogate pair in \u form
                _ => char::from_u32(self.usize(0x20, 0x7e) as u32).unwrap(),
            })
            .collect()
    }

    /// Arbitrary JSON value tree of bounded depth, for round-trip
    /// properties shared by the DOM parser and the streaming reader.
    pub fn json_value(&mut self, depth: usize) -> crate::json::Value {
        use crate::json::Value;
        let leaf = depth == 0;
        match self.usize(0, if leaf { 4 } else { 6 }) {
            0 => Value::Null,
            1 => Value::Bool(self.bool()),
            2 => {
                // mix integers (exact) and floats spanning magnitudes
                if self.bool() {
                    Value::Num(self.usize(0, 1_000_000) as f64)
                } else {
                    Value::Num(self.f64(-1e6, 1e6))
                }
            }
            3 | 4 => Value::Str(self.json_string(12)),
            5 => {
                let n = self.usize(0, 4);
                Value::Arr((0..n).map(|_| self.json_value(depth - 1)).collect())
            }
            _ => {
                let n = self.usize(0, 4);
                let mut v = Value::obj();
                for _ in 0..n {
                    let key = self.json_string(8);
                    v.set(&key, self.json_value(depth - 1));
                }
                v
            }
        }
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed on the
/// first failure so the case can be replayed with [`check_seeded`].
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    // Base seed is fixed: CI runs are deterministic; bump to explore.
    let base = 0x5EED_CAFE;
    for case in 0..cases {
        let case_seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = run_one(case_seed, &mut prop) {
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 replay with check_seeded({case_seed:#x}, prop)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seeded<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Err(msg) = run_one(case_seed, &mut prop) {
        panic!("property failed (seed {case_seed:#x}): {msg}");
    }
}

fn run_one<F>(case_seed: u64, prop: &mut F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Rng::new(case_seed),
        case_seed,
    };
    prop(&mut g)
}

/// Assertion helper for readable property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check(50, |g| {
            let x = g.usize(1, 10);
            prop_assert((1..=10).contains(&x), "range")?;
            count += 1;
            Ok(())
        });
        // `check` takes Fn so count captured by value per closure semantics;
        // just re-run to assert no panic happened.
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |g| {
            let x = g.usize(0, 100);
            prop_assert(x < 95, format!("x={x} too big"))
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let mut captured = None;
            check(1, |g| {
                captured = Some(g.u64());
                Ok(())
            });
            firsts.push(captured.unwrap());
        }
        assert_eq!(firsts[0], firsts[1]);
    }

    #[test]
    fn log_uniform_spans_magnitudes() {
        let mut small = false;
        let mut large = false;
        check(200, |g| {
            let x = g.log_f64(1e-5, 1e-1);
            if x < 1e-4 {
                small = true;
            }
            if x > 1e-2 {
                large = true;
            }
            prop_assert((1e-5..=1e-1).contains(&x), "range")
        });
        // generator covered both ends across cases (checked post-hoc)
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 0.0));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }
}
