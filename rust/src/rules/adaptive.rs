//! Self-tuning SlimAdam: the online SNR-driven rule-switching controller
//! (DESIGN.md §18).
//!
//! The paper derives *static* rules from an SNR probe run; ROADMAP "Next
//! directions" §4 asks for the online version — monitor per-tensor SNR
//! during training and switch each tensor between full-V Adam and
//! reduced-V SlimAdam mid-run. The controller here is a per-tensor
//! hysteresis state machine:
//!
//! ```text
//!            snr >= enter for `patience` consecutive evals
//!      Full ──────────────────────────────────────────────▶ Reduced
//!           ◀──────────────────────────────────────────────
//!            snr < exit for `patience` consecutive evals
//! ```
//!
//! with `exit <= enter`, so readings inside the band `[exit, enter)`
//! reset the streak and can never cause a transition — modes cannot flap
//! however noisy the signal is inside the band. Tensors whose target rule
//! is `K = ∅` (vectors, unruled params) are *inert*: they stay full-V and
//! the controller never logs a decision for them.
//!
//! The controller is a pure function of the observation trace: feeding the
//! same `(step, snr[])` sequence to a fresh controller reproduces the
//! identical decision log (the replay-determinism contract the resume and
//! serve paths rely on; locked by `rust/tests/adaptive_rules.rs`).

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::optim::KMode;

/// Default enter threshold: the paper's compression cutoff (signal must
/// dominate noise before we drop precision on it).
pub const DEFAULT_ENTER: f64 = 1.0;
/// Default exit threshold: well below enter so ordinary SNR jitter around
/// the cutoff cannot bounce a tensor back out of reduced mode.
pub const DEFAULT_EXIT: f64 = 0.25;
/// Default consecutive-eval patience before either transition.
pub const DEFAULT_PATIENCE: usize = 3;
/// Default controller eval cadence in optimizer steps.
pub const DEFAULT_EVERY: usize = 25;

/// Controller thresholds + cadence. Parsed from `--adaptive
/// [enter:exit:patience[:every]]`; all four fields are part of run
/// identity (see [`AdaptivePolicy::key`] and `runstore::config_key`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptivePolicy {
    /// compress when windowed SNR stays `>= enter` for `patience` evals
    pub enter: f64,
    /// decompress when it falls `< exit` (the lower hysteresis edge)
    pub exit: f64,
    /// consecutive evals required before either transition fires
    pub patience: usize,
    /// eval cadence in optimizer steps
    pub every: usize,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            enter: DEFAULT_ENTER,
            exit: DEFAULT_EXIT,
            patience: DEFAULT_PATIENCE,
            every: DEFAULT_EVERY,
        }
    }
}

impl AdaptivePolicy {
    /// Parse `enter:exit:patience[:every]`. The empty string (a bare
    /// `--adaptive` flag) yields the defaults.
    pub fn parse(spec: &str) -> Result<AdaptivePolicy> {
        if spec.is_empty() {
            return Ok(AdaptivePolicy::default());
        }
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() < 3 || parts.len() > 4 {
            bail!(
                "adaptive spec {spec:?}: want enter:exit:patience[:every], \
                 e.g. 1.0:0.25:3 or 1.0:0.25:3:25"
            );
        }
        let p = AdaptivePolicy {
            enter: parts[0]
                .parse()
                .with_context(|| format!("adaptive enter threshold {:?}", parts[0]))?,
            exit: parts[1]
                .parse()
                .with_context(|| format!("adaptive exit threshold {:?}", parts[1]))?,
            patience: parts[2]
                .parse()
                .with_context(|| format!("adaptive patience {:?}", parts[2]))?,
            every: match parts.get(3) {
                Some(s) => s
                    .parse()
                    .with_context(|| format!("adaptive eval cadence {:?}", s))?,
                None => DEFAULT_EVERY,
            },
        };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        // Infinities are legal — the never-fire differential policy pins
        // `enter = +inf, exit = -inf` — but NaN would make every band
        // comparison vacuously false, so reject it outright.
        if self.enter.is_nan() || self.exit.is_nan() {
            bail!("adaptive thresholds must not be NaN");
        }
        if self.exit > self.enter {
            bail!(
                "adaptive exit threshold {} must be <= enter threshold {} \
                 (the hysteresis band would be inverted)",
                self.exit,
                self.enter
            );
        }
        if self.patience == 0 {
            bail!("adaptive patience must be >= 1");
        }
        if self.every == 0 {
            bail!("adaptive eval cadence must be >= 1");
        }
        Ok(())
    }

    /// Never-fire policy for differential testing: thresholds pinned so no
    /// finite SNR can ever cross either edge (`enter = +inf`, `exit = -inf`).
    pub fn never_fire() -> AdaptivePolicy {
        AdaptivePolicy {
            enter: f64::INFINITY,
            exit: f64::NEG_INFINITY,
            patience: 1,
            every: DEFAULT_EVERY,
        }
    }

    /// Bit-exact identity segment for `runstore::config_key`: thresholds
    /// as raw f64 bits so `0.25` and `0.250000001` never collide.
    pub fn key(&self) -> String {
        format!(
            "{:x}:{:x}:{}:{}",
            self.enter.to_bits(),
            self.exit.to_bits(),
            self.patience,
            self.every
        )
    }

    /// Inverse of [`AdaptivePolicy::key`] (used when deserializing run
    /// rows; exact for every policy, including non-finite thresholds).
    pub fn from_key(s: &str) -> Result<AdaptivePolicy> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 4 {
            bail!("adaptive policy key {s:?}: want enterbits:exitbits:patience:every");
        }
        let bits = |t: &str| -> Result<f64> {
            Ok(f64::from_bits(
                u64::from_str_radix(t, 16).with_context(|| format!("policy key bits {t:?}"))?,
            ))
        };
        Ok(AdaptivePolicy {
            enter: bits(parts[0])?,
            exit: bits(parts[1])?,
            patience: parts[2].parse().context("policy key patience")?,
            every: parts[3].parse().context("policy key cadence")?,
        })
    }

    /// Human-readable spec (round-trips through [`AdaptivePolicy::parse`]
    /// for finite thresholds); used in run labels.
    pub fn spec(&self) -> String {
        format!("{}:{}:{}:{}", self.enter, self.exit, self.patience, self.every)
    }
}

/// Which way a tensor switched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// full-V Adam → reduced-V SlimAdam (collapse V by the mean rule)
    Compress,
    /// reduced-V SlimAdam → full-V Adam (expand V by broadcast)
    Decompress,
}

impl Direction {
    pub fn as_str(&self) -> &'static str {
        match self {
            Direction::Compress => "compress",
            Direction::Decompress => "decompress",
        }
    }

    pub fn parse(s: &str) -> Result<Direction> {
        match s {
            "compress" => Ok(Direction::Compress),
            "decompress" => Ok(Direction::Decompress),
            _ => bail!("unknown adaptive direction {s:?}"),
        }
    }
}

/// One logged mode switch. The full decision log is serialized into the
/// run-store summary row (it IS part of the run's observable output), so
/// resume restores it byte-identically without re-execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// optimizer step at which the eval fired
    pub step: usize,
    /// manifest parameter index
    pub tensor: usize,
    /// parameter name (redundant with `tensor`; kept for log readability)
    pub name: String,
    pub dir: Direction,
    /// the SNR reading that completed the patience streak
    pub snr: f64,
}

impl Decision {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("step", self.step)
            .set("tensor", self.tensor)
            .set("name", self.name.clone())
            .set("dir", self.dir.as_str())
            .set("snr", self.snr);
        v
    }

    pub fn from_json(v: &Value) -> Result<Decision> {
        Ok(Decision {
            step: v.get("step")?.as_f64()? as usize,
            tensor: v.get("tensor")?.as_f64()? as usize,
            name: v.get("name")?.as_str()?.to_string(),
            dir: Direction::parse(v.get("dir")?.as_str()?)?,
            snr: v.get("snr")?.as_f64()?,
        })
    }
}

/// Current storage mode of one tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// V at full parameter shape (exact AdamW)
    Full,
    /// V at the target rule's reduced shape
    Reduced,
}

#[derive(Debug, Clone)]
struct TensorState {
    mode: Mode,
    /// consecutive out-of-band evals toward the pending transition
    streak: usize,
}

/// Per-tensor hysteresis controller. Construct once per run, call
/// [`Controller::observe`] at every eval point, apply the returned
/// switches to the engine.
#[derive(Debug, Clone)]
pub struct Controller {
    policy: AdaptivePolicy,
    names: Vec<String>,
    /// target reduced mode per tensor; `KMode::None` marks the tensor
    /// inert (never compressed, never observed)
    target: Vec<KMode>,
    state: Vec<TensorState>,
    log: Vec<Decision>,
    evals: usize,
}

impl Controller {
    /// `targets[i]` is tensor i's reduced mode under the static rule set
    /// the run was launched with; `start[i]` its storage mode at step 0.
    /// Adaptive runs start from the static SlimAdam artifact, so ruled
    /// tensors begin `Reduced` — see [`Controller::slim_start`].
    pub fn new(
        policy: AdaptivePolicy,
        names: Vec<String>,
        target: Vec<KMode>,
        start: Vec<Mode>,
    ) -> Controller {
        assert_eq!(names.len(), target.len());
        assert_eq!(names.len(), start.len());
        let state = target
            .iter()
            .zip(&start)
            .map(|(&k, &mode)| TensorState {
                mode: if k == KMode::None { Mode::Full } else { mode },
                streak: 0,
            })
            .collect();
        Controller {
            policy,
            names,
            target,
            state,
            log: Vec::new(),
            evals: 0,
        }
    }

    /// The standard start state: every ruled tensor compressed (the run
    /// boots from the static SlimAdam artifact), inert tensors full.
    pub fn slim_start(
        policy: AdaptivePolicy,
        names: Vec<String>,
        target: Vec<KMode>,
    ) -> Controller {
        let start = target
            .iter()
            .map(|&k| if k == KMode::None { Mode::Full } else { Mode::Reduced })
            .collect();
        Controller::new(policy, names, target, start)
    }

    pub fn policy(&self) -> &AdaptivePolicy {
        &self.policy
    }

    /// Current storage mode of tensor `i`.
    pub fn mode(&self, i: usize) -> Mode {
        self.state[i].mode
    }

    /// Effective K of tensor `i` right now: the target rule while
    /// `Reduced`, `K = ∅` while `Full`.
    pub fn current_k(&self, i: usize) -> KMode {
        match self.state[i].mode {
            Mode::Reduced => self.target[i],
            Mode::Full => KMode::None,
        }
    }

    pub fn is_inert(&self, i: usize) -> bool {
        self.target[i] == KMode::None
    }

    pub fn n_tensors(&self) -> usize {
        self.target.len()
    }

    pub fn evals(&self) -> usize {
        self.evals
    }

    pub fn log(&self) -> &[Decision] {
        &self.log
    }

    /// Whether `step` (1-based optimizer step) is an eval point.
    pub fn due(&self, step: usize) -> bool {
        step % self.policy.every == 0
    }

    /// Feed one eval's per-tensor SNR readings; returns the switches that
    /// fired, in tensor order. `snrs[i]` for inert tensors is ignored.
    /// Non-finite readings (NaN) count as in-band: they reset the streak.
    pub fn observe(&mut self, step: usize, snrs: &[f64]) -> Vec<Decision> {
        assert_eq!(snrs.len(), self.state.len());
        self.evals += 1;
        let mut fired = Vec::new();
        for i in 0..self.state.len() {
            if self.is_inert(i) {
                continue;
            }
            let snr = snrs[i];
            let st = &mut self.state[i];
            let out_of_band = match st.mode {
                Mode::Reduced => snr < self.policy.exit,
                Mode::Full => snr >= self.policy.enter,
            };
            if !out_of_band {
                st.streak = 0;
                continue;
            }
            st.streak += 1;
            if st.streak < self.policy.patience {
                continue;
            }
            st.streak = 0;
            let dir = match st.mode {
                Mode::Reduced => {
                    st.mode = Mode::Full;
                    Direction::Decompress
                }
                Mode::Full => {
                    st.mode = Mode::Reduced;
                    Direction::Compress
                }
            };
            let d = Decision {
                step,
                tensor: i,
                name: self.names[i].clone(),
                dir,
                snr,
            };
            self.log.push(d.clone());
            fired.push(d);
        }
        fired
    }

    /// Count of ruled tensors currently in `Reduced` mode.
    pub fn n_compressed(&self) -> usize {
        (0..self.state.len())
            .filter(|&i| !self.is_inert(i) && self.state[i].mode == Mode::Reduced)
            .count()
    }

    /// Decision log as a JSON array (the run-store checkpoint form).
    pub fn log_json(&self) -> Value {
        Value::Arr(self.log.iter().map(|d| d.to_json()).collect())
    }
}

/// Everything an adaptive run reports beyond its losses: the decision
/// log, the second-moment-memory timeline, and the final compression
/// state. Serialized into the run-store summary row (`"adaptive"` field)
/// so `--resume` restores it without re-execution and `exp::fig_adaptive`
/// can plot memory-over-time straight from stored rows.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveReport {
    pub policy: AdaptivePolicy,
    /// controller evals that actually ran (divergence can cut them short)
    pub evals: usize,
    pub decisions: Vec<Decision>,
    /// `(step, stored V elements)` — step 0 start plus one point after
    /// every eval at which at least one switch fired
    pub timeline: Vec<(usize, usize)>,
    /// stored V elements at the end of the run
    pub final_v_elems: usize,
    /// full-V Adam baseline (= total parameter elements)
    pub full_v_elems: usize,
    /// fraction of Adam's second-moment elements living in compressed
    /// (reduced-V) tensors at the end of the run
    pub compressed_frac: f64,
}

impl AdaptiveReport {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        let timeline: Vec<Value> = self
            .timeline
            .iter()
            .map(|&(step, elems)| {
                let mut p = Value::obj();
                p.set("step", step).set("v_elems", elems);
                p
            })
            .collect();
        v.set("policy", self.policy.key())
            .set("spec", self.policy.spec())
            .set("evals", self.evals)
            .set(
                "decisions",
                Value::Arr(self.decisions.iter().map(|d| d.to_json()).collect()),
            )
            .set("timeline", Value::Arr(timeline))
            .set("final_v_elems", self.final_v_elems)
            .set("full_v_elems", self.full_v_elems)
            .set("compressed_frac", self.compressed_frac);
        v
    }

    pub fn from_json(v: &Value) -> Result<AdaptiveReport> {
        let decisions = v
            .get("decisions")?
            .as_arr()?
            .iter()
            .map(Decision::from_json)
            .collect::<Result<Vec<_>>>()?;
        let timeline = v
            .get("timeline")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok((
                    p.get("step")?.as_usize()?,
                    p.get("v_elems")?.as_usize()?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(AdaptiveReport {
            policy: AdaptivePolicy::from_key(v.get("policy")?.as_str()?)?,
            evals: v.get("evals")?.as_usize()?,
            decisions,
            timeline,
            final_v_elems: v.get("final_v_elems")?.as_usize()?,
            full_v_elems: v.get("full_v_elems")?.as_usize()?,
            compressed_frac: v.get("compressed_frac")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(policy: AdaptivePolicy) -> Controller {
        Controller::slim_start(
            policy,
            vec!["w".into(), "ln".into()],
            vec![KMode::FanIn, KMode::None],
        )
    }

    #[test]
    fn parse_roundtrip_and_defaults() {
        assert_eq!(AdaptivePolicy::parse("").unwrap(), AdaptivePolicy::default());
        let p = AdaptivePolicy::parse("2.0:0.5:4:10").unwrap();
        assert_eq!(p.enter, 2.0);
        assert_eq!(p.exit, 0.5);
        assert_eq!(p.patience, 4);
        assert_eq!(p.every, 10);
        let back = AdaptivePolicy::parse(&p.spec()).unwrap();
        assert_eq!(back, p);
        // three-field form defaults the cadence
        assert_eq!(AdaptivePolicy::parse("1:0.1:2").unwrap().every, DEFAULT_EVERY);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(AdaptivePolicy::parse("1.0").is_err());
        assert!(AdaptivePolicy::parse("0.1:1.0:3").is_err()); // exit > enter
        assert!(AdaptivePolicy::parse("1.0:0.1:0").is_err()); // patience 0
        assert!(AdaptivePolicy::parse("1.0:0.1:3:0").is_err()); // every 0
        assert!(AdaptivePolicy::parse("nan:0.1:3").is_err());
    }

    #[test]
    fn hysteresis_band_never_switches() {
        let p = AdaptivePolicy {
            enter: 1.0,
            exit: 0.25,
            patience: 1,
            every: 1,
        };
        let mut c = ctl(p);
        // readings inside [exit, enter) forever: no decision either way
        for step in 1..=50 {
            let fired = c.observe(step, &[0.5, 0.0]);
            assert!(fired.is_empty());
        }
        assert_eq!(c.mode(0), Mode::Reduced);
        assert!(c.log().is_empty());
    }

    #[test]
    fn patience_gates_both_directions() {
        let p = AdaptivePolicy {
            enter: 1.0,
            exit: 0.25,
            patience: 3,
            every: 1,
        };
        let mut c = ctl(p);
        // two lows, an in-band reset, then three lows -> decompress on the
        // third consecutive low only
        assert!(c.observe(1, &[0.1, 0.0]).is_empty());
        assert!(c.observe(2, &[0.1, 0.0]).is_empty());
        assert!(c.observe(3, &[0.5, 0.0]).is_empty()); // reset
        assert!(c.observe(4, &[0.1, 0.0]).is_empty());
        assert!(c.observe(5, &[0.1, 0.0]).is_empty());
        let fired = c.observe(6, &[0.1, 0.0]);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].dir, Direction::Decompress);
        assert_eq!(c.mode(0), Mode::Full);
        // now three highs -> compress again
        assert!(c.observe(7, &[2.0, 0.0]).is_empty());
        assert!(c.observe(8, &[2.0, 0.0]).is_empty());
        let fired = c.observe(9, &[2.0, 0.0]);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].dir, Direction::Compress);
        assert_eq!(c.mode(0), Mode::Reduced);
        assert_eq!(c.log().len(), 2);
    }

    #[test]
    fn inert_tensors_never_fire() {
        let p = AdaptivePolicy {
            enter: 1.0,
            exit: 0.25,
            patience: 1,
            every: 1,
        };
        let mut c = ctl(p);
        for step in 1..=10 {
            // wild swings on the inert tensor's slot
            let fired = c.observe(step, &[0.5, if step % 2 == 0 { 100.0 } else { -5.0 }]);
            assert!(fired.is_empty());
        }
        assert_eq!(c.mode(1), Mode::Full);
        assert_eq!(c.current_k(1), KMode::None);
    }

    #[test]
    fn never_fire_policy_is_inert_everywhere() {
        let mut c = ctl(AdaptivePolicy::never_fire());
        for step in 1..=20 {
            let fired = c.observe(step, &[f64::INFINITY, 0.0]);
            assert!(fired.is_empty());
            let fired = c.observe(step, &[-1e300, 0.0]);
            assert!(fired.is_empty());
        }
        assert!(c.log().is_empty());
        assert_eq!(c.n_compressed(), 1);
    }

    #[test]
    fn nan_readings_reset_streaks() {
        let p = AdaptivePolicy {
            enter: 1.0,
            exit: 0.25,
            patience: 2,
            every: 1,
        };
        let mut c = ctl(p);
        assert!(c.observe(1, &[0.1, 0.0]).is_empty());
        assert!(c.observe(2, &[f64::NAN, 0.0]).is_empty()); // reset
        assert!(c.observe(3, &[0.1, 0.0]).is_empty());
        assert_eq!(c.observe(4, &[0.1, 0.0]).len(), 1);
    }

    #[test]
    fn decision_json_roundtrip() {
        let d = Decision {
            step: 75,
            tensor: 2,
            name: "h0.attn_q".into(),
            dir: Direction::Compress,
            snr: 1.75,
        };
        let back = Decision::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn key_is_bit_exact() {
        let a = AdaptivePolicy::default();
        let mut b = a;
        b.exit = 0.25 + 1e-12;
        assert_ne!(a.key(), b.key());
        assert_eq!(a.key(), AdaptivePolicy::default().key());
        // key round-trips exactly, including non-finite thresholds
        let nf = AdaptivePolicy::never_fire();
        assert_eq!(AdaptivePolicy::from_key(&nf.key()).unwrap(), nf);
        assert_eq!(AdaptivePolicy::from_key(&a.key()).unwrap(), a);
    }

    #[test]
    fn report_json_roundtrip() {
        let report = AdaptiveReport {
            policy: AdaptivePolicy::default(),
            evals: 7,
            decisions: vec![Decision {
                step: 50,
                tensor: 1,
                name: "w".into(),
                dir: Direction::Decompress,
                snr: 0.125,
            }],
            timeline: vec![(0, 100), (50, 164)],
            final_v_elems: 164,
            full_v_elems: 200,
            compressed_frac: 0.5,
        };
        let back = AdaptiveReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);
    }
}
