//! SNR → compression-rule derivation: the "DIY: Build Your Own Low-Memory
//! Adam" machinery of §5.
//!
//! A [`RuleSet`] maps parameter names to sharing dimensions K. SlimAdam's
//! policy: compress each matrix-like second moment along the K with the
//! highest time-averaged SNR *if* it exceeds the cutoff; leave vector-like
//! moments uncompressed (high variability, negligible memory).
//!
//! Variants:
//! * [`RuleSet::derive`] — per-parameter rules (the default).
//! * [`RuleSet::derive_depth_averaged`] — per-layer-type rules from
//!   depth-averaged SNR ("SlimAdam-mean", App. H / Fig. 30), which the
//!   paper shows performs identically and transfers across widths.
//! * [`RuleSet::table3_default`] — the paper's Table 3 recommendations,
//!   usable without running an SNR probe.

pub mod adaptive;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::Value;
use crate::optim::adamk::v_len;
use crate::runtime::manifest::{KMode, Manifest};
use crate::snr::SnrSummary;

/// Default SNR cutoff: compress only when signal dominates noise (>= 1).
pub const DEFAULT_CUTOFF: f64 = 1.0;

#[derive(Debug, Clone, PartialEq)]
pub struct RuleSet {
    pub label: String,
    pub cutoff: f64,
    /// learning rate the SNR probe ran at (paper §5: rules derived at
    /// ~10x below optimal LR compress the most).
    pub derived_at_lr: Option<f64>,
    /// param name -> K. Params absent from the map default to K = ∅.
    pub rules: BTreeMap<String, KMode>,
}

impl RuleSet {
    /// Paper Table 3 recommendations keyed by layer type.
    pub fn table3_default(man: &Manifest) -> RuleSet {
        let mut rules = BTreeMap::new();
        for p in &man.params {
            if p.is_vector() {
                continue; // vectors stay uncompressed
            }
            let k = match p.layer_type.as_str() {
                "attn_q" | "attn_k" => KMode::FanIn,
                "attn_v" | "attn_proj" => KMode::FanOut,
                "mlp_up" | "mlp_gate" | "mlp_down" => KMode::FanOut,
                // embeddings stored (vocab, d): keep the token axis, average
                // the embedding axis (= fan_in in our storage convention)
                "tok_embd" | "lm_head" => KMode::FanIn,
                "patch_embd" | "head" => KMode::FanIn,
                // conv weights sit in the matrix view (C_out, C_in·kh·kw):
                // average fan_in — one second moment per output filter —
                // which keeps the per-filter scale structure the paper's
                // ResNet SNR analysis shows dominates (Fig. 5)
                "conv" => KMode::FanIn,
                _ => KMode::None,
            };
            if k != KMode::None {
                rules.insert(p.name.clone(), k);
            }
        }
        RuleSet {
            label: "table3".into(),
            cutoff: DEFAULT_CUTOFF,
            derived_at_lr: None,
            rules,
        }
    }

    /// Per-parameter derivation from a time-averaged SNR summary.
    pub fn derive(
        summary: &SnrSummary,
        cutoff: f64,
        label: impl Into<String>,
        lr: Option<f64>,
    ) -> RuleSet {
        let mut rules = BTreeMap::new();
        for (avg, info) in summary.per_param.iter().zip(&summary.metas) {
            if info.is_vector() || avg.n == 0 {
                continue;
            }
            let (k, snr) = avg.best();
            if snr.is_finite() && snr >= cutoff {
                rules.insert(info.name.clone(), k);
            }
        }
        RuleSet {
            label: label.into(),
            cutoff,
            derived_at_lr: lr,
            rules,
        }
    }

    /// "SlimAdam-mean": derive one rule per layer type from depth-averaged
    /// SNR, then apply it to every parameter of that type.
    pub fn derive_depth_averaged(
        summary: &SnrSummary,
        cutoff: f64,
        label: impl Into<String>,
        lr: Option<f64>,
    ) -> RuleSet {
        let by_type = summary.by_layer_type();
        let mut rules = BTreeMap::new();
        for info in &summary.metas {
            if info.is_vector() {
                continue;
            }
            if let Some(avg) = by_type.get(&info.layer_type) {
                let (k, snr) = avg.best();
                if snr.is_finite() && snr >= cutoff {
                    rules.insert(info.name.clone(), k);
                }
            }
        }
        RuleSet {
            label: label.into(),
            cutoff,
            derived_at_lr: lr,
            rules,
        }
    }

    /// Per-tensor K modes in manifest parameter order.
    pub fn modes_for(&self, man: &Manifest) -> Vec<KMode> {
        man.params
            .iter()
            .map(|p| self.rules.get(&p.name).copied().unwrap_or(KMode::None))
            .collect()
    }

    /// Stored second-moment elements under these rules.
    pub fn v_elems(&self, man: &Manifest) -> usize {
        man.params
            .iter()
            .map(|p| v_len(p, self.rules.get(&p.name).copied().unwrap_or(KMode::None)))
            .sum()
    }

    /// Fraction of Adam's second moments *saved* (Fig. 10 top).
    pub fn saving(&self, man: &Manifest) -> f64 {
        let adam: usize = man.total_param_elems();
        1.0 - self.v_elems(man) as f64 / adam as f64
    }

    /// Differences against another rule set (paper Tables 1 and 2).
    pub fn diff(&self, other: &RuleSet) -> Vec<RuleDiff> {
        let mut names: Vec<&String> =
            self.rules.keys().chain(other.rules.keys()).collect();
        names.sort();
        names.dedup();
        names
            .into_iter()
            .filter_map(|name| {
                let a = self.rules.get(name).copied().unwrap_or(KMode::None);
                let b = other.rules.get(name).copied().unwrap_or(KMode::None);
                if a != b {
                    Some(RuleDiff {
                        name: name.clone(),
                        left: a,
                        right: b,
                    })
                } else {
                    None
                }
            })
            .collect()
    }

    pub fn to_json(&self) -> Value {
        let mut rules = Value::obj();
        for (name, k) in &self.rules {
            rules.set(name, k.as_str());
        }
        let mut v = Value::obj();
        v.set("label", self.label.clone())
            .set("cutoff", self.cutoff)
            .set("rules", rules);
        if let Some(lr) = self.derived_at_lr {
            v.set("derived_at_lr", lr);
        }
        v
    }

    pub fn from_json(v: &Value) -> Result<RuleSet> {
        let mut rules = BTreeMap::new();
        for (name, kv) in v.get("rules")?.as_obj()? {
            let s = kv.as_str()?;
            let k = if let Some(n) = s.strip_prefix("blocks") {
                KMode::Blocks(n.parse().context("blocks count")?)
            } else {
                KMode::parse(s)?
            };
            rules.insert(name.clone(), k);
        }
        Ok(RuleSet {
            label: v.get("label")?.as_str()?.to_string(),
            cutoff: v.get("cutoff")?.as_f64()?,
            derived_at_lr: v.opt("derived_at_lr").and_then(|x| x.as_f64().ok()),
            rules,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().dump_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RuleSet> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        RuleSet::from_json(&Value::parse(&text)?)
    }
}

/// One rule difference (a row of Table 1 / Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct RuleDiff {
    pub name: String,
    pub left: KMode,
    pub right: KMode,
}

/// Aggregate Table 3: the most common K per layer type across rule sets,
/// flagging types whose K varies ("inconsistent trends" markers).
pub fn recommend(
    rulesets: &[(&RuleSet, &Manifest)],
) -> BTreeMap<String, (KMode, bool)> {
    let mut votes: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
    for (rs, man) in rulesets {
        for p in &man.params {
            if p.is_vector() {
                continue;
            }
            let k = rs.rules.get(&p.name).copied().unwrap_or(KMode::None);
            *votes
                .entry(p.layer_type.clone())
                .or_default()
                .entry(k.as_str())
                .or_default() += 1;
        }
    }
    votes
        .into_iter()
        .map(|(lt, dist)| {
            let total: usize = dist.values().sum();
            let (best_k, best_n) = dist
                .iter()
                .max_by_key(|(_, &n)| n)
                .map(|(k, &n)| (k.clone(), n))
                .unwrap();
            let k = if let Some(n) = best_k.strip_prefix("blocks") {
                KMode::Blocks(n.parse().unwrap_or(1))
            } else {
                KMode::parse(&best_k).unwrap_or(KMode::None)
            };
            let inconsistent = best_n * 4 < total * 3; // < 75% agreement
            (lt, (k, inconsistent))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamInfo;
    use crate::snr::{SnrAvg, SnrSummary};
    use crate::tensor::Init;

    fn info(name: &str, lt: &str, shape: &[usize], depth: i64) -> ParamInfo {
        ParamInfo {
            name: name.into(),
            shape: shape.to_vec(),
            layer_type: lt.into(),
            depth,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    fn manifest2() -> Manifest {
        Manifest::parse(
            r#"{
          "kind": "grad_step",
          "model": {"name": "t", "family": "gpt", "vocab": 64},
          "params": [
            {"name": "q", "shape": [8, 8], "layer_type": "attn_q", "depth": 0,
             "init_mitchell": {"scheme": "zeros"}, "init_default": {"scheme": "zeros"},
             "wd": true, "fan_out_axis": 0},
            {"name": "ln", "shape": [8], "layer_type": "ln_attn", "depth": 0,
             "init_mitchell": {"scheme": "ones"}, "init_default": {"scheme": "ones"},
             "wd": false, "fan_out_axis": 0}
          ],
          "batch": [{"name": "x", "shape": [2, 4], "dtype": "s32"}],
          "inputs": ["param:q", "param:ln", "batch:x"],
          "outputs": ["loss", "grad:q", "grad:ln"]
        }"#,
        )
        .unwrap()
    }

    fn avg(fo: f64, fi: f64, both: f64) -> SnrAvg {
        SnrAvg {
            fan_out: fo,
            fan_in: fi,
            both,
            n: 5,
        }
    }

    #[test]
    fn derive_picks_argmax_above_cutoff() {
        let metas = vec![info("q", "attn_q", &[8, 8], 0), info("ln", "ln_attn", &[8], 0)];
        let summary = SnrSummary {
            per_param: vec![avg(0.5, 3.0, 1.2), avg(9.0, 9.0, 9.0)],
            metas,
        };
        let rs = RuleSet::derive(&summary, 1.0, "t", Some(3e-4));
        assert_eq!(rs.rules.get("q"), Some(&KMode::FanIn));
        assert!(!rs.rules.contains_key("ln")); // vector skipped

        let rs_hi = RuleSet::derive(&summary, 5.0, "t", None);
        assert!(!rs_hi.rules.contains_key("q")); // cutoff excludes
    }

    #[test]
    fn depth_averaged_unifies_types() {
        let metas = vec![
            info("h0.q", "attn_q", &[8, 8], 0),
            info("h1.q", "attn_q", &[8, 8], 1),
        ];
        // layer 0 prefers fan_in (strongly), layer 1 weakly prefers fan_out;
        // the depth mean prefers fan_in for both.
        let summary = SnrSummary {
            per_param: vec![avg(0.5, 10.0, 0.1), avg(1.4, 1.2, 0.1)],
            metas,
        };
        let per_layer = RuleSet::derive(&summary, 1.0, "pl", None);
        assert_eq!(per_layer.rules.get("h0.q"), Some(&KMode::FanIn));
        assert_eq!(per_layer.rules.get("h1.q"), Some(&KMode::FanOut));
        let mean = RuleSet::derive_depth_averaged(&summary, 1.0, "m", None);
        assert_eq!(mean.rules.get("h0.q"), Some(&KMode::FanIn));
        assert_eq!(mean.rules.get("h1.q"), Some(&KMode::FanIn));
    }

    #[test]
    fn json_roundtrip() {
        let metas = vec![info("q", "attn_q", &[8, 8], 0)];
        let summary = SnrSummary {
            per_param: vec![avg(0.5, 3.0, 1.2)],
            metas,
        };
        let rs = RuleSet::derive(&summary, 1.0, "rt", Some(1e-4));
        let back = RuleSet::from_json(&rs.to_json()).unwrap();
        assert_eq!(back, rs);
    }

    #[test]
    fn table3_covers_gpt_layers() {
        let man = manifest2();
        let rs = RuleSet::table3_default(&man);
        assert_eq!(rs.rules.get("q"), Some(&KMode::FanIn));
        assert!(!rs.rules.contains_key("ln"));
        let modes = rs.modes_for(&man);
        assert_eq!(modes, vec![KMode::FanIn, KMode::None]);
    }

    #[test]
    fn table3_conv_rules_compress_fan_in() {
        let man = crate::runtime::backend::native::grad_manifest("conv_mini").unwrap();
        let rs = RuleSet::table3_default(&man);
        assert_eq!(rs.rules.get("conv1"), Some(&KMode::FanIn));
        assert_eq!(rs.rules.get("conv2"), Some(&KMode::FanIn));
        assert_eq!(rs.rules.get("head"), Some(&KMode::FanIn));
        // fan_in over (C_in, kh, kw): one V per output filter / class row
        assert_eq!(rs.v_elems(&man), 8 + 16 + 10);
        assert!(rs.saving(&man) > 0.97, "{}", rs.saving(&man));
    }

    #[test]
    fn savings_math() {
        let man = manifest2();
        let rs = RuleSet::table3_default(&man);
        // q: 8x8 -> 8 (fan_in); ln: 8 uncompressed. total v = 16 of 72.
        assert_eq!(rs.v_elems(&man), 16);
        assert!((rs.saving(&man) - (1.0 - 16.0 / 72.0)).abs() < 1e-12);
    }

    #[test]
    fn diff_reports_changes() {
        let metas = vec![info("q", "attn_q", &[8, 8], 0)];
        let s1 = SnrSummary {
            per_param: vec![avg(0.5, 3.0, 0.2)],
            metas: metas.clone(),
        };
        let s2 = SnrSummary {
            per_param: vec![avg(3.0, 0.5, 0.2)],
            metas,
        };
        let a = RuleSet::derive(&s1, 1.0, "a", None);
        let b = RuleSet::derive(&s2, 1.0, "b", None);
        let d = a.diff(&b);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].left, KMode::FanIn);
        assert_eq!(d[0].right, KMode::FanOut);
        assert!(a.diff(&a).is_empty());
    }

    #[test]
    fn recommend_majority_and_inconsistency() {
        let man = manifest2();
        let mut r1 = RuleSet::table3_default(&man);
        let r2 = RuleSet::table3_default(&man);
        let r3 = RuleSet::table3_default(&man);
        let recs = recommend(&[(&r1, &man), (&r2, &man), (&r3, &man)]);
        assert_eq!(recs["attn_q"], (KMode::FanIn, false));
        // flip one -> 2/3 agreement < 75% -> inconsistent flag
        r1.rules.insert("q".into(), KMode::FanOut);
        let recs = recommend(&[(&r1, &man), (&r2, &man), (&r3, &man)]);
        assert!(recs["attn_q"].1);
    }
}
