//! Bench harness substrate — replaces `criterion` (unavailable offline).
//!
//! `cargo bench` targets in `benches/` use `harness = false` and drive this
//! module: warmup, fixed-duration sampling, IQR outlier filtering, and a
//! compact report (median / mean / p10-p90 / throughput). Results are also
//! appended as JSONL to `results/bench/<name>.jsonl` — pruned to the
//! newest [`BENCH_KEEP_DEFAULT`] rows on every write, so the tracked perf
//! trajectory stays bounded — and the native suite additionally emits the
//! consolidated per-family [`write_native_summary`] JSON the CI bench job
//! uploads as `BENCH_native.json` (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

use crate::json::Value;

#[derive(Debug, Clone)]
pub struct Sample {
    pub nanos_per_iter: f64,
}

#[derive(Debug, Clone)]
pub struct Report {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Optional user-provided unit count per iteration (tokens, params, ...)
    pub units_per_iter: Option<(f64, &'static str)>,
}

impl Report {
    pub fn print(&self) {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        print!(
            "{:44} {:>12}/iter  (mean {:>12}, p10 {:>12}, p90 {:>12}, n={})",
            self.name,
            fmt(self.median_ns),
            fmt(self.mean_ns),
            fmt(self.p10_ns),
            fmt(self.p90_ns),
            self.iters
        );
        if let Some((units, label)) = self.units_per_iter {
            let per_sec = units / (self.median_ns / 1e9);
            print!("  [{} {label}/s]", human(per_sec));
        }
        println!();
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", self.name.clone())
            .set("iters", self.iters)
            .set("median_ns", self.median_ns)
            .set("mean_ns", self.mean_ns)
            .set("p10_ns", self.p10_ns)
            .set("p90_ns", self.p90_ns)
            .set("unix_ms", now_ms());
        if let Some((units, label)) = self.units_per_iter {
            v.set("units_per_iter", units).set("unit", label);
        }
        v
    }

    /// Median throughput in units/second, when a unit count was given.
    pub fn units_per_sec(&self) -> Option<f64> {
        self.units_per_iter
            .map(|(units, _)| units / (self.median_ns / 1e9).max(1e-12))
    }
}

fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Default retention for `results/bench/*.jsonl`: rows kept per file.
/// Override with `SLIMADAM_BENCH_KEEP=<n>` (run-store growth item: the
/// perf trajectory stays bounded no matter how many CI runs append).
pub const BENCH_KEEP_DEFAULT: usize = 256;

fn bench_keep() -> usize {
    std::env::var("SLIMADAM_BENCH_KEEP")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(BENCH_KEEP_DEFAULT)
}

/// Append one JSONL row to `dir/<sanitized name>.jsonl`, pruning the file
/// to its newest [`BENCH_KEEP_DEFAULT`] (or `SLIMADAM_BENCH_KEEP`) rows on
/// every write. Best-effort like the rest of the bench sinks: IO errors
/// never fail a bench run.
pub fn append_row(dir: &std::path::Path, name: &str, row: &Value) {
    append_row_keep(dir, name, row, bench_keep());
}

/// [`append_row`] with an explicit retention cap (tests drive this
/// directly; production callers use the env-configured default).
pub fn append_row_keep(dir: &std::path::Path, name: &str, row: &Value, keep: usize) {
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{}.jsonl", sanitize(name)));
    let mut text = std::fs::read_to_string(&path).unwrap_or_default();
    if !text.is_empty() && !text.ends_with('\n') {
        text.push('\n');
    }
    text.push_str(&row.dump());
    text.push('\n');
    let lines: Vec<&str> = text.lines().collect();
    let tail = if lines.len() > keep {
        &lines[lines.len() - keep..]
    } else {
        &lines[..]
    };
    let mut out = tail.join("\n");
    out.push('\n');
    // write-then-rename so a crash mid-prune never loses the whole file
    let tmp = path.with_extension("jsonl.tmp");
    if std::fs::write(&tmp, &out).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
}

/// Write a consolidated suite summary (the CI `BENCH_<suite>.json`
/// artifacts): a `families` array of per-row metrics under a `suite` tag,
/// comparable against a committed baseline by
/// [`check_native_regression`].
pub fn write_suite_summary(
    suite: &str,
    rows: &[Value],
    path: &std::path::Path,
) -> std::io::Result<()> {
    let mut root = Value::obj();
    root.set("suite", suite)
        .set("unix_ms", now_ms())
        .set("families", Value::Arr(rows.to_vec()));
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, root.dump_pretty())
}

/// Write the consolidated per-family native throughput summary (the CI
/// `BENCH_native.json` artifact): one row per builtin model, produced by
/// `benches/bench_native_step.rs`.
pub fn write_native_summary(rows: &[Value], path: &std::path::Path) -> std::io::Result<()> {
    write_suite_summary("native", rows, path)
}

/// Per-family throughput metrics gated by the CI `bench-regression` job
/// (each is a "bigger is better" rate from the BENCH_native.json /
/// BENCH_serve.json rows; latency-style metrics stay unregistered — the
/// gate only understands rates).
pub const REGRESSION_METRICS: &[&str] = &[
    "grad_units_per_s",
    "split_steps_per_s",
    "fused_steps_per_s",
    "adaptive_steps_per_s",
    "fused_jobs_per_s_batch4",
    "serve_jobs_per_s_depth1",
    "serve_jobs_per_s_depth8",
    "serve_jobs_per_s_depth64",
];

/// Outcome of comparing a fresh native summary against the committed
/// baseline (CI `bench-regression`). `violations` fail the job;
/// `warnings` are informational.
#[derive(Debug, Default)]
pub struct RegressionOutcome {
    pub warnings: Vec<String>,
    pub violations: Vec<String>,
}

impl RegressionOutcome {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Compare a fresh `BENCH_native.json` summary against a committed
/// baseline: every [`REGRESSION_METRICS`] rate must stay within
/// `max_regression` (e.g. `0.15` = 15%) of the baseline for every model
/// the baseline covers.
///
/// A baseline whose root carries `"provisional": true` — the bootstrap
/// state, committed before any CI box has recorded real numbers — never
/// fails: its findings (including "metric absent from baseline")
/// downgrade to warnings, and the job's artifact upload becomes the
/// first real measurement to commit.
pub fn check_native_regression(
    baseline: &Value,
    current: &Value,
    max_regression: f64,
) -> RegressionOutcome {
    let mut out = RegressionOutcome::default();
    let provisional = baseline
        .opt("provisional")
        .and_then(|v| v.as_bool().ok())
        .unwrap_or(false);
    let empty: [Value; 0] = [];
    let base_rows = baseline
        .opt("families")
        .and_then(|v| v.as_arr().ok())
        .unwrap_or(&empty);
    let cur_rows = current
        .opt("families")
        .and_then(|v| v.as_arr().ok())
        .unwrap_or(&empty);
    for b_row in base_rows {
        let Some(model) = b_row.opt("model").and_then(|v| v.as_str().ok().map(String::from))
        else {
            continue;
        };
        let Some(c_row) = cur_rows.iter().find(|r| {
            r.opt("model").and_then(|v| v.as_str().ok()) == Some(model.as_str())
        }) else {
            out.violations
                .push(format!("{model}: present in baseline, missing from summary"));
            continue;
        };
        for &metric in REGRESSION_METRICS {
            let base = b_row.opt(metric).and_then(|v| v.as_f64().ok());
            let cur = c_row.opt(metric).and_then(|v| v.as_f64().ok());
            match (base, cur) {
                (Some(base), Some(cur)) if base > 0.0 => {
                    let floor = base * (1.0 - max_regression);
                    if cur < floor {
                        out.violations.push(format!(
                            "{model}.{metric}: {cur:.1}/s is {:.1}% below \
                             baseline {base:.1}/s (allowed {:.0}%)",
                            100.0 * (1.0 - cur / base),
                            100.0 * max_regression
                        ));
                    }
                }
                // Metric absent from both sides: not applicable to this
                // suite's rows (native rows don't carry serve rates and
                // vice versa) — skip silently.
                (None, None) => {}
                (Some(_), Some(_)) | (None, Some(_)) => {
                    out.warnings
                        .push(format!("{model}.{metric}: no usable baseline rate"));
                }
                (Some(_), None) => {
                    out.violations
                        .push(format!("{model}.{metric}: missing from summary"));
                }
            }
        }
    }
    if provisional {
        out.warnings.append(&mut out.violations);
        out.warnings
            .push("baseline is provisional: findings reported as warnings only".into());
    }
    out
}

/// Promote the latest native bench summary to the committed regression
/// baseline (`slimadam bench promote`): rewrites `baseline` from the rows
/// in `summary`, dropping the bootstrap `"provisional"` marker so the
/// next `bench-regression` run gates for real. Refuses an empty summary,
/// and writes via temp-file + atomic rename like the other sinks.
pub fn promote_baseline(
    summary: &std::path::Path,
    baseline: &std::path::Path,
) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(summary).map_err(|e| {
        anyhow::anyhow!(
            "reading {summary:?}: {e} — run `cargo bench --bench bench_native_step` first"
        )
    })?;
    let mut v = Value::parse(&text)?;
    let n = v
        .opt("families")
        .and_then(|f| f.as_arr().ok())
        .map(|a| a.len())
        .unwrap_or(0);
    anyhow::ensure!(
        n > 0,
        "{summary:?} has no families rows — refusing to promote an empty baseline"
    );
    if let Value::Obj(o) = &mut v {
        o.remove("provisional");
        o.insert(
            "promoted_from".into(),
            Value::Str(summary.display().to_string()),
        );
    }
    if let Some(dir) = baseline.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = baseline.with_extension("json.tmp");
    std::fs::write(&tmp, v.dump_pretty())?;
    std::fs::rename(&tmp, baseline)?;
    Ok(())
}

/// Benchmark runner with warmup + timed sampling.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    sink: Option<std::path::PathBuf>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor quick runs: SLIMADAM_BENCH_FAST=1 shrinks durations so the
        // full `cargo bench` suite stays tractable in CI.
        let fast = std::env::var("SLIMADAM_BENCH_FAST").is_ok();
        Bencher {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            min_samples: 5,
            sink: Some(std::path::PathBuf::from("results/bench")),
        }
    }
}

impl Bencher {
    pub fn no_sink(mut self) -> Self {
        self.sink = None;
        self
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Report {
        self.bench_units(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation (units processed per iter).
    pub fn bench_with_units<F: FnMut()>(
        &self,
        name: &str,
        units: f64,
        label: &'static str,
        mut f: F,
    ) -> Report {
        self.bench_units(name, Some((units, label)), &mut f)
    }

    /// Benchmark a byte-oriented workload (parsers, scanners): reports
    /// B/s throughput from the bytes one iteration consumes. Used by the
    /// runstore scan benches (`benches/bench_runstore.rs`).
    pub fn bench_bytes<F: FnMut()>(&self, name: &str, bytes: usize, f: F) -> Report {
        self.bench_with_units(name, bytes as f64, "B", f)
    }

    fn bench_units(
        &self,
        name: &str,
        units: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) -> Report {
        // Warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure
        let mut samples: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure || samples.len() < self.min_samples {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let report = summarize(name, &mut samples, units);
        report.print();
        if let Some(dir) = &self.sink {
            append_row(dir, name, &report.to_json());
        }
        report
    }
}

/// Result of one serial-vs-parallel sweep comparison (see [`bench_sweep`]).
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub name: String,
    pub jobs: usize,
    pub workers: usize,
    pub serial_s: f64,
    pub parallel_s: f64,
}

impl SweepReport {
    /// Wall-clock speedup of the parallel run over the serial run.
    pub fn speedup(&self) -> f64 {
        self.serial_s / self.parallel_s.max(1e-12)
    }

    /// Sweep throughput of the parallel run (the jobs/sec metric the
    /// batched-dispatch perf table tracks; EXPERIMENTS.md §Perf).
    pub fn jobs_per_sec(&self) -> f64 {
        self.jobs as f64 / self.parallel_s.max(1e-12)
    }

    pub fn print(&self) {
        println!(
            "{:44} {} jobs: serial {:.3} s, {} workers {:.3} s  [{:.2}x, {:.1} jobs/s]",
            self.name,
            self.jobs,
            self.serial_s,
            self.workers,
            self.parallel_s,
            self.speedup(),
            self.jobs_per_sec()
        );
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", self.name.clone())
            .set("jobs", self.jobs)
            .set("workers", self.workers)
            .set("serial_s", self.serial_s)
            .set("parallel_s", self.parallel_s)
            .set("speedup", self.speedup())
            .set("jobs_per_sec", self.jobs_per_sec())
            .set("unix_ms", now_ms());
        v
    }
}

/// Result of one batched-vs-sequential dispatch comparison
/// (DESIGN.md §12): the same job set run unbatched and with
/// `SweepScheduler::batch(n)`-style stacked dispatch, reported as
/// jobs/sec. Emitted as JSONL into `results/bench/` like every other
/// bench row, so EXPERIMENTS.md's perf table can diff runs.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub name: String,
    pub jobs: usize,
    /// Max jobs stacked per dispatch in the batched run.
    pub batch: usize,
    pub sequential_s: f64,
    pub batched_s: f64,
}

impl BatchReport {
    pub fn sequential_jobs_per_sec(&self) -> f64 {
        self.jobs as f64 / self.sequential_s.max(1e-12)
    }

    pub fn batched_jobs_per_sec(&self) -> f64 {
        self.jobs as f64 / self.batched_s.max(1e-12)
    }

    /// Throughput gain of batched over sequential dispatch.
    pub fn speedup(&self) -> f64 {
        self.sequential_s / self.batched_s.max(1e-12)
    }

    pub fn print(&self) {
        println!(
            "{:44} {} jobs: sequential {:.3} s ({:.1} jobs/s), batch {} {:.3} s ({:.1} jobs/s)  [{:.2}x]",
            self.name,
            self.jobs,
            self.sequential_s,
            self.sequential_jobs_per_sec(),
            self.batch,
            self.batched_s,
            self.batched_jobs_per_sec(),
            self.speedup()
        );
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", self.name.clone())
            .set("jobs", self.jobs)
            .set("batch", self.batch)
            .set("sequential_s", self.sequential_s)
            .set("batched_s", self.batched_s)
            .set("sequential_jobs_per_sec", self.sequential_jobs_per_sec())
            .set("batched_jobs_per_sec", self.batched_jobs_per_sec())
            .set("speedup", self.speedup())
            .set("unix_ms", now_ms());
        v
    }
}

/// Time a sequential run and a batched run of the same `jobs`-job
/// workload once each (sweep-scale workloads are too coarse for
/// repeated sampling) and report jobs/sec for both. `None` sink
/// suppresses the JSONL row.
pub fn bench_batched<S, B>(
    name: &str,
    jobs: usize,
    batch: usize,
    sink: Option<&std::path::Path>,
    sequential: S,
    batched: B,
) -> BatchReport
where
    S: FnOnce(),
    B: FnOnce(),
{
    let t0 = Instant::now();
    sequential();
    let sequential_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    batched();
    let batched_s = t1.elapsed().as_secs_f64();

    let report = BatchReport {
        name: name.to_string(),
        jobs,
        batch,
        sequential_s,
        batched_s,
    };
    report.print();
    if let Some(dir) = sink {
        append_row(dir, name, &report.to_json());
    }
    report
}

/// Wall-clock comparison for coarse job sets (sweep scheduling): run
/// `n_jobs` invocations of `job` once serially, then once on a
/// `workers`-thread work-stealing pool, and report the speedup. Results
/// append to `results/bench/<name>.jsonl` like [`Bencher`] runs; use
/// [`bench_sweep_sink`] to redirect or suppress the sink.
pub fn bench_sweep<F>(name: &str, n_jobs: usize, workers: usize, job: F) -> SweepReport
where
    F: Fn(usize) + Sync,
{
    bench_sweep_sink(
        name,
        n_jobs,
        workers,
        Some(std::path::Path::new("results/bench")),
        job,
    )
}

/// [`bench_sweep`] with an explicit JSONL sink directory (`None` = no file).
pub fn bench_sweep_sink<F>(
    name: &str,
    n_jobs: usize,
    workers: usize,
    sink: Option<&std::path::Path>,
    job: F,
) -> SweepReport
where
    F: Fn(usize) + Sync,
{
    let jobs: Vec<usize> = (0..n_jobs).collect();

    let t0 = Instant::now();
    for &i in &jobs {
        job(i);
    }
    let serial_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    crate::pool::parallel_map_sharded(&jobs, workers, |i, _| i as u64, |_, &i| {
        job(i);
        Ok(())
    })
    .expect("bench jobs do not fail");
    let parallel_s = t1.elapsed().as_secs_f64();

    let report = SweepReport {
        name: name.to_string(),
        jobs: n_jobs,
        workers,
        serial_s,
        parallel_s,
    };
    report.print();
    if let Some(dir) = sink {
        append_row(dir, name, &report.to_json());
    }
    report
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

/// IQR-filtered summary statistics.
pub fn summarize(
    name: &str,
    samples: &mut [f64],
    units: Option<(f64, &'static str)>,
) -> Report {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let q = |p: f64| -> f64 {
        let idx = (p * (n - 1) as f64).round() as usize;
        samples[idx.min(n - 1)]
    };
    let (q1, q3) = (q(0.25), q(0.75));
    let iqr = q3 - q1;
    let (lo, hi) = (q1 - 1.5 * iqr, q3 + 1.5 * iqr);
    let kept: Vec<f64> = samples
        .iter()
        .copied()
        .filter(|&x| x >= lo && x <= hi)
        .collect();
    let kept = if kept.is_empty() { samples.to_vec() } else { kept };
    let mean = kept.iter().sum::<f64>() / kept.len() as f64;
    Report {
        name: name.to_string(),
        iters: n as u64,
        median_ns: q(0.5),
        mean_ns: mean,
        p10_ns: q(0.10),
        p90_ns: q(0.90),
        units_per_iter: units,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let mut s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let r = summarize("t", &mut s, None);
        assert!((r.median_ns - 50.0).abs() <= 1.0);
        assert!(r.p10_ns < r.p90_ns);
    }

    #[test]
    fn summarize_filters_outliers() {
        let mut s: Vec<f64> = vec![10.0; 99];
        s.push(1e9); // massive outlier
        let r = summarize("t", &mut s, None);
        assert!((r.mean_ns - 10.0).abs() < 1.0, "mean {}", r.mean_ns);
    }

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            sink: None,
        };
        let mut acc = 0u64;
        let r = b.bench("noop", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 3);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize("a b/c:d"), "a_b_c_d");
    }

    #[test]
    fn append_row_prunes_to_retention_cap() {
        let dir = std::env::temp_dir().join(format!(
            "slimadam_bench_retention_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        for i in 0..12 {
            let mut row = Value::obj();
            row.set("i", i as i64);
            append_row_keep(&dir, "retention_probe", &row, 5);
        }
        let text = std::fs::read_to_string(dir.join("retention_probe.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5, "pruned to keep=5:\n{text}");
        // the newest rows survive, oldest are dropped
        assert!(lines[0].contains("\"i\":7"), "{}", lines[0]);
        assert!(lines[4].contains("\"i\":11"), "{}", lines[4]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn units_per_sec_from_median() {
        let r = Report {
            name: "t".into(),
            iters: 1,
            median_ns: 1e9, // 1 s/iter
            mean_ns: 1e9,
            p10_ns: 1e9,
            p90_ns: 1e9,
            units_per_iter: Some((500.0, "tok")),
        };
        assert!((r.units_per_sec().unwrap() - 500.0).abs() < 1e-9);
        let none = Report { units_per_iter: None, ..r };
        assert!(none.units_per_sec().is_none());
    }

    #[test]
    fn native_summary_writes_families_json() {
        let dir = std::env::temp_dir().join(format!(
            "slimadam_bench_summary_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut row = Value::obj();
        row.set("model", "mlp_tiny").set("grad_tok_per_s", 1000.0);
        let path = dir.join("BENCH_native.json");
        write_native_summary(&[row], &path).unwrap();
        let parsed = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("suite").unwrap().as_str().unwrap(), "native");
        assert_eq!(parsed.get("families").unwrap().as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_batched_reports_jobs_per_sec() {
        let r = bench_batched(
            "test_batched",
            8,
            4,
            None,
            || std::thread::sleep(Duration::from_millis(40)),
            || std::thread::sleep(Duration::from_millis(20)),
        );
        assert_eq!(r.jobs, 8);
        assert_eq!(r.batch, 4);
        assert!(r.speedup() > 1.0, "speedup {:.2}", r.speedup());
        assert!(r.batched_jobs_per_sec() > r.sequential_jobs_per_sec());
        let json = r.to_json().dump();
        assert!(json.contains("jobs_per_sec"), "{json}");
    }

    fn summary(rows: &[(&str, f64)], provisional: bool) -> Value {
        let mut fams = Vec::new();
        for (model, rate) in rows {
            let mut r = Value::obj();
            r.set("model", *model);
            for &m in REGRESSION_METRICS {
                r.set(m, *rate);
            }
            fams.push(r);
        }
        let mut root = Value::obj();
        root.set("suite", "native").set("families", Value::Arr(fams));
        if provisional {
            root.set("provisional", true);
        }
        root
    }

    #[test]
    fn regression_gate_passes_within_threshold() {
        let base = summary(&[("mlp_tiny", 100.0), ("gpt_deep", 10.0)], false);
        let cur = summary(&[("mlp_tiny", 90.0), ("gpt_deep", 11.0)], false);
        let out = check_native_regression(&base, &cur, 0.15);
        assert!(out.passed(), "{:?}", out.violations);
    }

    #[test]
    fn regression_gate_fails_beyond_threshold() {
        let base = summary(&[("gpt_deep", 10.0)], false);
        let cur = summary(&[("gpt_deep", 8.0)], false); // -20%
        let out = check_native_regression(&base, &cur, 0.15);
        assert!(!out.passed());
        assert!(
            out.violations.iter().all(|v| v.contains("gpt_deep")),
            "{:?}",
            out.violations
        );
    }

    #[test]
    fn regression_gate_fails_on_missing_model() {
        let base = summary(&[("mlp_tiny", 100.0)], false);
        let cur = summary(&[], false);
        let out = check_native_regression(&base, &cur, 0.15);
        assert!(!out.passed());
    }

    #[test]
    fn provisional_baseline_only_warns() {
        let base = summary(&[("gpt_deep", 1e9)], true); // absurd bar, but provisional
        let cur = summary(&[("gpt_deep", 1.0)], false);
        let out = check_native_regression(&base, &cur, 0.15);
        assert!(out.passed(), "{:?}", out.violations);
        assert!(!out.warnings.is_empty());
    }

    #[test]
    fn serve_suite_rows_gate_on_serve_metrics_only() {
        // a serve row carries only serve rates; the native metrics are
        // absent from BOTH sides and must not produce noise or failures
        let row = |rate: f64| {
            let mut r = Value::obj();
            r.set("model", "serve")
                .set("serve_jobs_per_s_depth1", rate)
                .set("serve_jobs_per_s_depth8", rate)
                .set("serve_jobs_per_s_depth64", rate);
            r
        };
        let wrap = |r: Value| {
            let mut root = Value::obj();
            root.set("suite", "serve").set("families", Value::Arr(vec![r]));
            root
        };
        let out = check_native_regression(&wrap(row(100.0)), &wrap(row(95.0)), 0.15);
        assert!(out.passed(), "{:?}", out.violations);
        assert!(out.warnings.is_empty(), "{:?}", out.warnings);
        let out = check_native_regression(&wrap(row(100.0)), &wrap(row(50.0)), 0.15);
        assert!(!out.passed(), "a halved serve rate must gate");
    }

    #[test]
    fn suite_summary_roundtrips() {
        let dir = std::env::temp_dir().join(format!(
            "slimadam_bench_suite_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH_serve.json");
        let mut r = Value::obj();
        r.set("model", "serve").set("serve_jobs_per_s_depth1", 42.0);
        write_suite_summary("serve", &[r], &path).unwrap();
        let v = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("suite").unwrap().as_str().unwrap(), "serve");
        assert_eq!(v.get("families").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn promote_clears_provisional_and_keeps_rows() {
        let dir = std::env::temp_dir().join(format!(
            "slimadam_bench_promote_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let summary_path = dir.join("BENCH_native.json");
        let baseline_path = dir.join("BENCH_baseline.json");
        let mut s = summary(&[("mlp_tiny", 123.0)], false);
        s.set("provisional", true);
        std::fs::write(&summary_path, s.dump_pretty()).unwrap();
        promote_baseline(&summary_path, &baseline_path).unwrap();
        let promoted =
            Value::parse(&std::fs::read_to_string(&baseline_path).unwrap()).unwrap();
        assert!(promoted.opt("provisional").is_none(), "marker must be cleared");
        assert_eq!(promoted.get("families").unwrap().as_arr().unwrap().len(), 1);
        // empty summary refuses
        std::fs::write(&summary_path, Value::obj().dump()).unwrap();
        assert!(promote_baseline(&summary_path, &baseline_path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_sweep_measures_speedup() {
        // sleep-bound jobs: parallelism is limited only by worker count,
        // so even a loaded CI box shows > 1x
        let r = bench_sweep_sink("test_sweep", 8, 4, None, |_| {
            std::thread::sleep(Duration::from_millis(15));
        });
        assert_eq!(r.jobs, 8);
        assert!(r.serial_s >= 8.0 * 0.015);
        assert!(r.speedup() > 1.3, "speedup {:.2}", r.speedup());
    }
}
