//! Synthetic class-conditional image workload (CIFAR substitute).
//!
//! Each class has a deterministic low-frequency "prototype" pattern
//! (sinusoidal gratings with class-specific frequency, orientation and
//! phase per channel). A sample is `prototype * contrast + noise`, with
//! per-sample contrast and Gaussian pixel noise. This is learnable by
//! both convnets and ViTs (the classes are linearly separable in a
//! frequency basis but not in raw pixel space at high noise), exercising
//! the same code paths and gradient structure as CIFAR-10/100
//! (DESIGN.md §3).

use crate::rng::Rng;
use crate::runtime::engine::BatchData;

use super::DataSource;

#[derive(Debug, Clone)]
pub struct SynthImages {
    pub classes: usize,
    pub img: usize,
    pub channels: usize,
    pub noise: f64,
    /// class -> per-channel (fx, fy, phase, amp)
    protos: Vec<Vec<(f64, f64, f64, f64)>>,
}

impl SynthImages {
    pub fn new(classes: usize, img: usize, channels: usize, noise: f64, seed: u64) -> SynthImages {
        let mut rng = Rng::new(seed ^ 0x1774A6E5);
        let protos = (0..classes)
            .map(|_| {
                (0..channels)
                    .map(|_| {
                        (
                            rng.uniform(0.5, 4.0),                      // fx (cycles)
                            rng.uniform(0.5, 4.0),                      // fy
                            rng.uniform(0.0, std::f64::consts::TAU),    // phase
                            rng.uniform(0.5, 1.0),                      // amplitude
                        )
                    })
                    .collect()
            })
            .collect();
        SynthImages {
            classes,
            img,
            channels,
            noise,
            protos,
        }
    }

    /// Render one sample of class `c` into `out` (HWC layout).
    pub fn render_into(&self, c: usize, rng: &mut Rng, out: &mut [f32]) {
        let n = self.img;
        let contrast = rng.uniform(0.7, 1.3);
        for y in 0..n {
            for x in 0..n {
                for ch in 0..self.channels {
                    let (fx, fy, phase, amp) = self.protos[c][ch];
                    let v = amp
                        * ((std::f64::consts::TAU
                            * (fx * x as f64 / n as f64 + fy * y as f64 / n as f64)
                            + phase)
                            .sin());
                    out[(y * n + x) * self.channels + ch] =
                        (contrast * v + self.noise * rng.normal()) as f32;
                }
            }
        }
    }

    pub fn source(self, batch: usize, seed: u64) -> ImageSource {
        let mut root = Rng::new(seed);
        ImageSource {
            rng_train: root.fork(1),
            rng_eval: root.fork(2),
            batch,
            name: format!("synthimg_c{}", self.classes),
            gen: self,
        }
    }
}

pub struct ImageSource {
    gen: SynthImages,
    rng_train: Rng,
    rng_eval: Rng,
    batch: usize,
    name: String,
}

impl ImageSource {
    fn make(&mut self, eval: bool) -> Vec<BatchData> {
        let g = &self.gen;
        let px = g.img * g.img * g.channels;
        let mut images = vec![0f32; self.batch * px];
        let mut labels = vec![0i32; self.batch];
        for i in 0..self.batch {
            let rng = if eval { &mut self.rng_eval } else { &mut self.rng_train };
            let c = rng.usize_below(g.classes);
            labels[i] = c as i32;
            g.render_into(c, rng, &mut images[i * px..(i + 1) * px]);
        }
        vec![BatchData::F32(images), BatchData::I32(labels)]
    }
}

impl DataSource for ImageSource {
    fn next_batch(&mut self) -> Vec<BatchData> {
        self.make(false)
    }

    fn eval_batch(&mut self) -> Vec<BatchData> {
        self.make(true)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let gen = SynthImages::new(10, 32, 3, 0.3, 1);
        let mut src = gen.source(4, 2);
        let batch = src.next_batch();
        let BatchData::F32(imgs) = &batch[0] else { panic!() };
        let BatchData::I32(labels) = &batch[1] else { panic!() };
        assert_eq!(imgs.len(), 4 * 32 * 32 * 3);
        assert_eq!(labels.len(), 4);
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
        assert!(imgs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn classes_are_distinguishable() {
        // same-class samples correlate more than cross-class samples
        let gen = SynthImages::new(10, 16, 1, 0.1, 3);
        let mut rng = Rng::new(4);
        let px = 16 * 16;
        let mut a0 = vec![0f32; px];
        let mut a1 = vec![0f32; px];
        let mut b0 = vec![0f32; px];
        gen.render_into(0, &mut rng, &mut a0);
        gen.render_into(0, &mut rng, &mut a1);
        gen.render_into(5, &mut rng, &mut b0);
        let corr = |x: &[f32], y: &[f32]| -> f64 {
            let n = x.len() as f64;
            let mx = x.iter().map(|&v| v as f64).sum::<f64>() / n;
            let my = y.iter().map(|&v| v as f64).sum::<f64>() / n;
            let cov: f64 = x
                .iter()
                .zip(y)
                .map(|(&a, &b)| (a as f64 - mx) * (b as f64 - my))
                .sum::<f64>();
            let vx: f64 = x.iter().map(|&a| (a as f64 - mx).powi(2)).sum();
            let vy: f64 = y.iter().map(|&b| (b as f64 - my).powi(2)).sum();
            cov / (vx * vy).sqrt()
        };
        assert!(corr(&a0, &a1) > 0.8, "{}", corr(&a0, &a1));
        assert!(corr(&a0, &b0).abs() < 0.5, "{}", corr(&a0, &b0));
    }

    #[test]
    fn deterministic_per_seed() {
        let mk = || SynthImages::new(10, 8, 3, 0.2, 7).source(2, 9);
        let mut a = mk();
        let mut b = mk();
        let BatchData::F32(xa) = &a.next_batch()[0] else { panic!() };
        let xa = xa.clone();
        let BatchData::F32(xb) = &b.next_batch()[0] else { panic!() };
        assert_eq!(&xa, xb);
    }

    #[test]
    fn hundred_classes_supported() {
        let gen = SynthImages::new(100, 32, 3, 0.3, 11);
        let mut src = gen.source(64, 12);
        let batch = src.next_batch();
        let BatchData::I32(labels) = &batch[1] else { panic!() };
        let distinct: std::collections::HashSet<i32> = labels.iter().copied().collect();
        assert!(distinct.len() > 20);
    }
}
