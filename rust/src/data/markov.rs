//! Synthetic heavy-tailed language workload: Zipf unigrams + learnable
//! Markov structure.
//!
//! Why this preserves the paper's phenomena (DESIGN.md §3): the paper ties
//! language-model SNR behaviour to (i) heavy-tailed token frequencies —
//! rare tokens receive rare gradients, so the token dimension of Tok.Embd /
//! LM-Head needs per-token effective learning rates (§4.1) — and (ii) a
//! learnable objective that makes gradient statistics non-stationary.
//! A Zipf(alpha) unigram distribution reproduces (i) exactly; an order-1
//! Markov kernel mixing a deterministic successor permutation with the
//! Zipf marginal gives (ii): the model can reduce loss below the unigram
//! entropy by learning the transition structure.
//!
//! The fine-tuning experiments (§3.1.2) use [`MarkovLm::shifted`], which
//! re-draws the successor permutation and changes the mixing weight — a
//! distribution shift that mimics "pre-trained on A, fine-tuned on B".

use crate::rng::{Rng, ZipfTable};

use super::{DataSource, LmBatcher};
use crate::runtime::engine::BatchData;

/// Order-1 Markov language model with Zipf marginals.
#[derive(Debug, Clone)]
pub struct MarkovLm {
    pub vocab: usize,
    pub alpha: f64,
    /// probability of following the deterministic successor edge
    pub coherence: f64,
    zipf: ZipfTable,
    successor: Vec<usize>,
}

impl MarkovLm {
    /// Paper-calibrated default: alpha ~= 1.07 (natural-language-like tail),
    /// coherence 0.5 (half the tokens are structurally predictable).
    pub fn new(vocab: usize, alpha: f64, coherence: f64, seed: u64) -> MarkovLm {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let mut successor: Vec<usize> = (0..vocab).collect();
        rng.shuffle(&mut successor);
        MarkovLm {
            vocab,
            alpha,
            coherence,
            zipf: ZipfTable::new(vocab, alpha),
            successor,
        }
    }

    /// Distribution-shifted variant for fine-tuning experiments: new
    /// successor structure, higher coherence (more to learn).
    pub fn shifted(&self, seed: u64) -> MarkovLm {
        MarkovLm::new(self.vocab, self.alpha, (self.coherence + 0.3).min(0.9), seed ^ 0xF17E)
    }

    /// Sample one sequence into `seq`.
    pub fn sample_into(&self, rng: &mut Rng, seq: &mut [i32]) {
        let mut cur = self.zipf.sample(rng);
        for s in seq.iter_mut() {
            *s = cur as i32;
            cur = if rng.f64() < self.coherence {
                self.successor[cur]
            } else {
                self.zipf.sample(rng)
            };
        }
    }

    /// Empirical unigram entropy in nats (loss floor for a structure-blind
    /// model; the Markov structure allows going below it).
    pub fn unigram_entropy(&self) -> f64 {
        (0..self.vocab)
            .map(|k| {
                let p = self.zipf.pmf(k);
                if p > 0.0 {
                    -p * p.ln()
                } else {
                    0.0
                }
            })
            .sum()
    }

    /// Wrap into a [`DataSource`] for the given batch geometry.
    pub fn source(self, batch: usize, ctx: usize, seed: u64) -> impl DataSource {
        let name = format!("markov_v{}_a{:.2}", self.vocab, self.alpha);
        LmBatcher::new(name, batch, ctx, seed, move |rng, seq| {
            self.sample_into(rng, seq)
        })
    }
}

/// Classification-style wrapper is in `images.rs`; this module also offers
/// a trivially-unlearnable uniform source for control experiments.
pub struct UniformLm {
    pub vocab: usize,
}

impl UniformLm {
    pub fn source(self, batch: usize, ctx: usize, seed: u64) -> impl DataSource {
        let vocab = self.vocab as u64;
        LmBatcher::new(format!("uniform_v{}", self.vocab), batch, ctx, seed, move |rng, seq| {
            for s in seq.iter_mut() {
                *s = rng.below(vocab) as i32;
            }
        })
    }
}

/// Convenience: batch shapes straight from a manifest.
pub fn source_for_manifest(
    man: &crate::runtime::Manifest,
    lm: MarkovLm,
    seed: u64,
) -> impl DataSource {
    let b = man.batch[0].shape[0];
    let t = man.batch[0].shape[1];
    lm.source(b, t, seed)
}

/// Sanity helper for tests/benches: token histogram of a source's batches.
pub fn token_histogram(src: &mut dyn DataSource, vocab: usize, batches: usize) -> Vec<usize> {
    let mut hist = vec![0usize; vocab];
    for _ in 0..batches {
        let batch = src.next_batch();
        if let BatchData::I32(xs) = &batch[0] {
            for &x in xs {
                hist[x as usize] += 1;
            }
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_marginal_is_heavy_tailed() {
        let lm = MarkovLm::new(256, 1.07, 0.0, 1);
        let mut src = lm.source(8, 64, 2);
        let hist = token_histogram(&mut src, 256, 50);
        let total: usize = hist.iter().sum();
        // head token should dominate: rank-0 frequency >> uniform (1/256)
        assert!(hist[0] as f64 / total as f64 > 10.0 / 256.0);
        // tail tokens rare but present across vocab
        let nonzero = hist.iter().filter(|&&c| c > 0).count();
        assert!(nonzero > 128, "{nonzero}");
    }

    #[test]
    fn coherence_creates_structure() {
        let lm = MarkovLm::new(64, 1.0, 0.9, 3);
        let mut rng = Rng::new(4);
        let mut seq = vec![0i32; 400];
        lm.sample_into(&mut rng, &mut seq);
        // with coherence 0.9, ~90% of transitions follow the successor map
        let mut follows = 0;
        for w in seq.windows(2) {
            if lm.successor[w[0] as usize] == w[1] as usize {
                follows += 1;
            }
        }
        let frac = follows as f64 / (seq.len() - 1) as f64;
        assert!(frac > 0.8, "{frac}");
    }

    #[test]
    fn shifted_changes_structure() {
        let a = MarkovLm::new(64, 1.0, 0.5, 5);
        let b = a.shifted(6);
        assert_ne!(a.successor, b.successor);
        assert!(b.coherence > a.coherence);
    }

    #[test]
    fn unigram_entropy_positive_and_below_uniform() {
        let lm = MarkovLm::new(256, 1.07, 0.5, 1);
        let h = lm.unigram_entropy();
        assert!(h > 0.0);
        assert!(h < (256f64).ln()); // heavy tail -> below uniform entropy
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || MarkovLm::new(32, 1.0, 0.5, 9).source(2, 8, 10);
        let mut a = mk();
        let mut b = mk();
        let BatchData::I32(xa) = &a.next_batch()[0] else { panic!() };
        let xa = xa.clone();
        let BatchData::I32(xb) = &b.next_batch()[0] else { panic!() };
        assert_eq!(&xa, xb);
    }
}
