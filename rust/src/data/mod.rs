//! Data substrates: synthetic and real corpora, tokenizers and batchers.
//!
//! Dataset substitutions (DESIGN.md §3): the paper's OpenWebText /
//! FineWeb-Edu / WikiText / CIFAR / Alpaca workloads are replaced by
//! generators that preserve the properties the paper's analysis depends
//! on — heavy-tailed (Zipf) token frequencies with learnable sequential
//! structure for language tasks, and class-conditional learnable image
//! structure for vision tasks. `corpus.rs` additionally builds a *real*
//! natural-data corpus from this repository's own source tree, BPE-
//! tokenized at a controllable vocabulary size (the §4.1 control knob).

pub mod bpe;
pub mod corpus;
pub mod images;
pub mod markov;

use crate::rng::Rng;
use crate::runtime::engine::BatchData;

/// A stream of training batches for a given artifact's batch layout.
pub trait DataSource {
    /// Produce the next training batch (deterministic given the source's
    /// internal RNG state).
    fn next_batch(&mut self) -> Vec<BatchData>;

    /// Produce a held-out evaluation batch (drawn from a separate stream so
    /// it never overlaps training batches).
    fn eval_batch(&mut self) -> Vec<BatchData>;

    fn name(&self) -> &str;
}

/// Token-sequence batcher: draws (x, y) next-token pairs from any token
/// stream sampler.
pub struct LmBatcher<F: FnMut(&mut Rng, &mut [i32])> {
    pub batch: usize,
    pub ctx: usize,
    name: String,
    sample_seq: F,
    rng_train: Rng,
    rng_eval: Rng,
}

impl<F: FnMut(&mut Rng, &mut [i32])> LmBatcher<F> {
    /// `sample_seq` fills a buffer of ctx+1 tokens; x/y are the shifted
    /// views.
    pub fn new(name: impl Into<String>, batch: usize, ctx: usize, seed: u64, sample_seq: F) -> Self {
        let mut root = Rng::new(seed);
        let rng_train = root.fork(1);
        let rng_eval = root.fork(2);
        LmBatcher {
            batch,
            ctx,
            name: name.into(),
            sample_seq,
            rng_train,
            rng_eval,
        }
    }

    fn make(&mut self, eval: bool) -> Vec<BatchData> {
        let (b, t) = (self.batch, self.ctx);
        let mut xs = vec![0i32; b * t];
        let mut ys = vec![0i32; b * t];
        let mut seq = vec![0i32; t + 1];
        for i in 0..b {
            if eval {
                (self.sample_seq)(&mut self.rng_eval, &mut seq);
            } else {
                (self.sample_seq)(&mut self.rng_train, &mut seq);
            }
            xs[i * t..(i + 1) * t].copy_from_slice(&seq[..t]);
            ys[i * t..(i + 1) * t].copy_from_slice(&seq[1..]);
        }
        vec![BatchData::I32(xs), BatchData::I32(ys)]
    }
}

impl<F: FnMut(&mut Rng, &mut [i32])> DataSource for LmBatcher<F> {
    fn next_batch(&mut self) -> Vec<BatchData> {
        self.make(false)
    }

    fn eval_batch(&mut self) -> Vec<BatchData> {
        self.make(true)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_batcher_shapes_and_shift() {
        let mut src = LmBatcher::new("t", 2, 4, 7, |rng, seq| {
            let start = rng.below(100) as i32;
            for (i, s) in seq.iter_mut().enumerate() {
                *s = start + i as i32;
            }
        });
        let batch = src.next_batch();
        let (BatchData::I32(x), BatchData::I32(y)) = (&batch[0], &batch[1]) else {
            panic!("wrong batch types")
        };
        assert_eq!(x.len(), 8);
        assert_eq!(y.len(), 8);
        // y is x shifted by one within each row
        for row in 0..2 {
            for i in 0..3 {
                assert_eq!(y[row * 4 + i], x[row * 4 + i + 1]);
            }
        }
    }

    #[test]
    fn train_eval_streams_differ() {
        let mut src = LmBatcher::new("t", 1, 8, 7, |rng, seq| {
            for s in seq.iter_mut() {
                *s = rng.below(1000) as i32;
            }
        });
        let BatchData::I32(a) = &src.next_batch()[0] else { panic!() };
        let a = a.clone();
        let BatchData::I32(b) = &src.eval_batch()[0] else { panic!() };
        assert_ne!(&a, b);
    }

    #[test]
    fn deterministic_across_instances() {
        let make = || {
            LmBatcher::new("t", 1, 4, 42, |rng, seq| {
                for s in seq.iter_mut() {
                    *s = rng.below(10) as i32;
                }
            })
        };
        let mut s1 = make();
        let mut s2 = make();
        let BatchData::I32(a) = &s1.next_batch()[0] else { panic!() };
        let a = a.clone();
        let BatchData::I32(b) = &s2.next_batch()[0] else { panic!() };
        assert_eq!(&a, b);
    }
}
