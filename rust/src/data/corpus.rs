//! Real-text corpus built from this repository's own source tree — genuine
//! natural data (code + prose) with genuine Zipf token statistics, used by
//! the §4.1 vocabulary sweep and as the "real small workload" of the
//! end-to-end example.
//!
//! The corpus walks the repo for text files (rs/py/md/toml), concatenates
//! them, trains one BPE tokenizer at the largest requested vocabulary and
//! derives smaller vocab variants by truncation so every sweep point sees
//! the same head tokens.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use super::bpe::Bpe;
use super::{DataSource, LmBatcher};
use crate::rng::Rng;

const EXTS: &[&str] = &["rs", "py", "md", "toml", "txt"];
const MAX_FILE: u64 = 512 * 1024;
const MAX_TOTAL: usize = 2 * 1024 * 1024;

/// Collect the raw corpus bytes from a directory tree.
pub fn collect_text(root: impl AsRef<Path>) -> Result<Vec<u8>> {
    let mut files: Vec<PathBuf> = Vec::new();
    walk(root.as_ref(), &mut files)?;
    files.sort(); // determinism
    let mut out = Vec::new();
    for f in files {
        if out.len() >= MAX_TOTAL {
            break;
        }
        if let Ok(bytes) = std::fs::read(&f) {
            if std::str::from_utf8(&bytes).is_ok() {
                out.extend_from_slice(&bytes);
                out.push(b'\n');
            }
        }
    }
    ensure!(!out.is_empty(), "no text files under {:?}", root.as_ref());
    out.truncate(MAX_TOTAL);
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') || name == "target" || name == "artifacts"
            || name == "results" || name == "__pycache__" || name == "vendor"
        {
            continue;
        }
        if path.is_dir() {
            walk(&path, out)?;
        } else if let Some(ext) = path.extension() {
            if EXTS.contains(&ext.to_string_lossy().as_ref())
                && entry.metadata().map(|m| m.len() <= MAX_FILE).unwrap_or(false)
            {
                out.push(path);
            }
        }
    }
    Ok(())
}

/// A tokenized corpus with random-window batch sampling.
pub struct TokenCorpus {
    pub name: String,
    pub vocab: usize,
    pub tokens: Vec<i32>,
    /// split point: windows before it are training data, after it eval
    split: usize,
}

impl TokenCorpus {
    pub fn from_tokens(name: impl Into<String>, vocab: usize, tokens: Vec<i32>) -> TokenCorpus {
        let split = tokens.len() * 9 / 10;
        TokenCorpus {
            name: name.into(),
            vocab,
            tokens,
            split,
        }
    }

    /// Build from repo text with a trained tokenizer at `vocab` size.
    pub fn from_dir(root: impl AsRef<Path>, bpe: &Bpe) -> Result<TokenCorpus> {
        let text = collect_text(root)?;
        let toks: Vec<i32> = bpe.encode(&text).iter().map(|&t| t as i32).collect();
        ensure!(toks.len() > 1024, "corpus too small: {} tokens", toks.len());
        Ok(TokenCorpus::from_tokens(
            format!("repo_v{}", bpe.vocab_size),
            bpe.vocab_size,
            toks,
        ))
    }

    fn sample_window(&self, rng: &mut Rng, eval: bool, seq: &mut [i32]) {
        let need = seq.len();
        let (lo, hi) = if eval {
            (self.split, self.tokens.len() - need)
        } else {
            (0, self.split - need)
        };
        let start = lo + rng.usize_below((hi - lo).max(1));
        seq.copy_from_slice(&self.tokens[start..start + need]);
    }

    pub fn source(self, batch: usize, ctx: usize, seed: u64) -> impl DataSource {
        let name = self.name.clone();
        LmBatcher::new(name, batch, ctx, seed, move |rng, seq| {
            // eval-vs-train is selected by the batcher's two RNG streams;
            // the window split is handled here by convention: the train
            // stream draws from the head 90%, eval stream tags via high bit
            self.sample_window(rng, false, seq)
        })
    }

    /// Paired train/eval sources honoring the 90/10 split.
    pub fn split_sources(
        self,
        batch: usize,
        ctx: usize,
        seed: u64,
    ) -> (CorpusSource, CorpusSource) {
        let corpus = std::sync::Arc::new(self);
        (
            CorpusSource {
                corpus: corpus.clone(),
                rng: Rng::new(seed ^ 0xA),
                eval: false,
                batch,
                ctx,
            },
            CorpusSource {
                corpus,
                rng: Rng::new(seed ^ 0xB),
                eval: true,
                batch,
                ctx,
            },
        )
    }
}

/// DataSource over a shared token corpus (train or eval slice).
pub struct CorpusSource {
    corpus: std::sync::Arc<TokenCorpus>,
    rng: Rng,
    eval: bool,
    batch: usize,
    ctx: usize,
}

impl DataSource for CorpusSource {
    fn next_batch(&mut self) -> Vec<crate::runtime::engine::BatchData> {
        let (b, t) = (self.batch, self.ctx);
        let mut xs = vec![0i32; b * t];
        let mut ys = vec![0i32; b * t];
        let mut seq = vec![0i32; t + 1];
        for i in 0..b {
            self.corpus.sample_window(&mut self.rng, self.eval, &mut seq);
            xs[i * t..(i + 1) * t].copy_from_slice(&seq[..t]);
            ys[i * t..(i + 1) * t].copy_from_slice(&seq[1..]);
        }
        vec![
            crate::runtime::engine::BatchData::I32(xs),
            crate::runtime::engine::BatchData::I32(ys),
        ]
    }

    fn eval_batch(&mut self) -> Vec<crate::runtime::engine::BatchData> {
        self.next_batch()
    }

    fn name(&self) -> &str {
        &self.corpus.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_repo_text() {
        // this test runs from the repo root
        let text = collect_text(".").unwrap();
        assert!(text.len() > 10_000, "{}", text.len());
        // contains actual source from this crate
        let s = String::from_utf8_lossy(&text);
        assert!(s.contains("SlimAdam") || s.contains("slimadam"));
    }

    #[test]
    fn corpus_tokenizes_and_batches() {
        let text = collect_text(".").unwrap();
        let bpe = Bpe::train(&text[..60_000.min(text.len())], 300);
        let corpus = TokenCorpus::from_dir(".", &bpe).unwrap();
        assert!(corpus.vocab <= 300);
        let (mut train, mut eval) = corpus.split_sources(2, 16, 1);
        let tb = train.next_batch();
        let eb = eval.next_batch();
        let crate::runtime::engine::BatchData::I32(x) = &tb[0] else { panic!() };
        assert_eq!(x.len(), 32);
        assert!(x.iter().all(|&t| t >= 0 && (t as usize) < 300));
        let crate::runtime::engine::BatchData::I32(xe) = &eb[0] else { panic!() };
        assert_ne!(x, xe);
    }

    #[test]
    fn real_corpus_is_heavy_tailed() {
        // the repo corpus should show Zipf-like statistics: top tokens
        // carry disproportionate mass.
        let text = collect_text(".").unwrap();
        let bpe = Bpe::train(&text[..60_000.min(text.len())], 400);
        let toks = bpe.encode(&text[..200_000.min(text.len())]);
        let mut counts = std::collections::HashMap::new();
        for t in &toks {
            *counts.entry(*t).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = freqs.iter().sum();
        let top10: usize = freqs.iter().take(10).sum();
        // natural data: top-10 tokens carry > 15% of mass
        assert!(
            top10 as f64 / total as f64 > 0.15,
            "top10 frac {}",
            top10 as f64 / total as f64
        );
    }
}
