//! Byte-pair encoding substrate (Gage 1994 / Sennrich et al. 2016) — the
//! §4.1 vocabulary-size control knob. Training starts from the 256 byte
//! tokens and greedily merges the most frequent adjacent pair until the
//! requested vocabulary size is reached; encoding applies merges in rank
//! order. Reducing the vocab size progressively removes rare (deep-merge)
//! tokens — exactly the tail-mass manipulation of the paper's linear-model
//! experiment.

use std::collections::HashMap;

/// A trained BPE tokenizer.
#[derive(Debug, Clone)]
pub struct Bpe {
    pub vocab_size: usize,
    /// merge rank -> (left, right) token ids; merged id = 256 + rank.
    pub merges: Vec<(u32, u32)>,
    /// (left, right) -> merged id, for fast encoding
    merge_map: HashMap<(u32, u32), u32>,
}

impl Bpe {
    /// Train on `text` until `vocab_size` tokens (>= 256). Training uses a
    /// line-chunked corpus representation with incremental pair recounts.
    pub fn train(text: &[u8], vocab_size: usize) -> Bpe {
        assert!(vocab_size >= 256, "vocab must include all bytes");
        // Chunk by lines to bound merge scans; tokens never merge across
        // chunks (mirrors word-boundary behaviour of classic BPE).
        let mut chunks: Vec<Vec<u32>> = text
            .split(|&b| b == b'\n')
            .filter(|c| !c.is_empty())
            .map(|c| c.iter().map(|&b| b as u32).collect())
            .collect();

        let mut merges = Vec::new();
        let n_merges = vocab_size - 256;
        let mut pair_counts: HashMap<(u32, u32), i64> = HashMap::new();
        for chunk in &chunks {
            for w in chunk.windows(2) {
                *pair_counts.entry((w[0], w[1])).or_default() += 1;
            }
        }

        for rank in 0..n_merges {
            // most frequent pair (ties broken deterministically by pair id)
            let Some((&best, &cnt)) = pair_counts
                .iter()
                .filter(|(_, &c)| c > 0)
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if cnt < 2 {
                break; // no productive merges left
            }
            let new_id = 256 + rank as u32;
            merges.push(best);

            // apply the merge in every chunk, updating pair counts locally
            for chunk in chunks.iter_mut() {
                let mut i = 0;
                while i + 1 < chunk.len() {
                    if chunk[i] == best.0 && chunk[i + 1] == best.1 {
                        // decrement neighbours' old pairs
                        if i > 0 {
                            *pair_counts.entry((chunk[i - 1], chunk[i])).or_default() -= 1;
                        }
                        if i + 2 < chunk.len() {
                            *pair_counts
                                .entry((chunk[i + 1], chunk[i + 2]))
                                .or_default() -= 1;
                        }
                        *pair_counts.entry(best).or_default() -= 1;
                        chunk[i] = new_id;
                        chunk.remove(i + 1);
                        // increment new pairs
                        if i > 0 {
                            *pair_counts.entry((chunk[i - 1], new_id)).or_default() += 1;
                        }
                        if i + 1 < chunk.len() {
                            *pair_counts.entry((new_id, chunk[i + 1])).or_default() += 1;
                        }
                    } else {
                        i += 1;
                    }
                }
            }
            pair_counts.remove(&best);
        }

        let merge_map = merges
            .iter()
            .enumerate()
            .map(|(r, &p)| (p, 256 + r as u32))
            .collect();
        Bpe {
            vocab_size: 256 + merges.len(),
            merges,
            merge_map,
        }
    }

    /// Encode bytes to token ids (merges applied in rank order per chunk).
    pub fn encode(&self, text: &[u8]) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 2);
        for chunk in text.split(|&b| b == b'\n') {
            if chunk.is_empty() {
                continue;
            }
            let mut toks: Vec<u32> = chunk.iter().map(|&b| b as u32).collect();
            loop {
                // find the lowest-rank applicable merge
                let mut best: Option<(u32, usize)> = None; // (merged_id, pos)
                for i in 0..toks.len().saturating_sub(1) {
                    if let Some(&id) = self.merge_map.get(&(toks[i], toks[i + 1])) {
                        if best.map(|(b, _)| id < b).unwrap_or(true) {
                            best = Some((id, i));
                        }
                    }
                }
                let Some((id, _)) = best else { break };
                // apply that merge everywhere in the chunk
                let pair = self.merges[(id - 256) as usize];
                let mut i = 0;
                while i + 1 < toks.len() {
                    if toks[i] == pair.0 && toks[i + 1] == pair.1 {
                        toks[i] = id;
                        toks.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
            }
            out.extend_from_slice(&toks);
        }
        out
    }

    /// Decode token ids back to bytes.
    pub fn decode(&self, tokens: &[u32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            self.decode_token(t, &mut out);
        }
        out
    }

    fn decode_token(&self, t: u32, out: &mut Vec<u8>) {
        if t < 256 {
            out.push(t as u8);
        } else {
            let (a, b) = self.merges[(t - 256) as usize];
            self.decode_token(a, out);
            self.decode_token(b, out);
        }
    }

    /// Restrict to a smaller vocabulary (drop the highest-rank merges) —
    /// the §4.1 sweep repeatedly shrinks one trained tokenizer so vocab
    /// variants share their head tokens.
    pub fn truncated(&self, vocab_size: usize) -> Bpe {
        assert!(vocab_size >= 256 && vocab_size <= self.vocab_size);
        let merges: Vec<(u32, u32)> = self.merges[..vocab_size - 256].to_vec();
        let merge_map = merges
            .iter()
            .enumerate()
            .map(|(r, &p)| (p, 256 + r as u32))
            .collect();
        Bpe {
            vocab_size,
            merges,
            merge_map,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &[u8] = b"the quick brown fox jumps over the lazy dog\n\
        the quick brown fox jumps again\n\
        pack my box with five dozen liquor jugs\n\
        the five boxing wizards jump quickly\n";

    #[test]
    fn train_produces_merges() {
        let bpe = Bpe::train(SAMPLE, 300);
        assert!(bpe.vocab_size > 256);
        assert!(bpe.vocab_size <= 300);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let bpe = Bpe::train(SAMPLE, 320);
        let text = b"the quick brown fox";
        let toks = bpe.encode(text);
        assert_eq!(bpe.decode(&toks), text);
        // compression actually happened
        assert!(toks.len() < text.len(), "{} !< {}", toks.len(), text.len());
    }

    #[test]
    fn roundtrip_arbitrary_bytes() {
        crate::proptest::check(30, |g| {
            let n = g.usize(1, 200);
            let bytes: Vec<u8> = (0..n)
                .map(|_| (g.usize(1, 255)) as u8) // avoid \n chunk boundary
                .filter(|&b| b != b'\n')
                .collect();
            if bytes.is_empty() {
                return Ok(());
            }
            let bpe = Bpe::train(SAMPLE, 300);
            let dec = bpe.decode(&bpe.encode(&bytes));
            crate::proptest::prop_assert(dec == bytes, "roundtrip failed")
        });
    }

    #[test]
    fn bigger_vocab_compresses_more() {
        let text: Vec<u8> = SAMPLE.repeat(8);
        let small = Bpe::train(&text, 280);
        let large = Bpe::train(&text, 400);
        let probe = b"the quick brown fox jumps over the lazy dog";
        assert!(large.encode(probe).len() <= small.encode(probe).len());
    }

    #[test]
    fn truncated_shares_head_merges() {
        let bpe = Bpe::train(&SAMPLE.repeat(4), 350);
        let cut = bpe.truncated(300);
        assert_eq!(cut.merges[..], bpe.merges[..cut.merges.len()]);
        // truncated encoding still round-trips
        let probe = b"boxing wizards";
        assert_eq!(cut.decode(&cut.encode(probe)), probe);
        // and produces no tokens beyond its vocab
        assert!(cut.encode(probe).iter().all(|&t| (t as usize) < cut.vocab_size));
    }

    #[test]
    fn vocab_size_controls_tail_mass() {
        // larger vocab -> longer tail of rarely-used tokens; check that the
        // fraction of distinct tokens used once grows with vocab.
        let text: Vec<u8> = SAMPLE.repeat(16);
        let small = Bpe::train(&text, 280);
        let large = Bpe::train(&text, 480);
        let once = |bpe: &Bpe| {
            let toks = bpe.encode(&text);
            let mut counts = std::collections::HashMap::new();
            for t in toks {
                *counts.entry(t).or_insert(0usize) += 1;
            }
            counts.values().filter(|&&c| c <= 2).count()
        };
        assert!(once(&large) >= once(&small));
    }
}
