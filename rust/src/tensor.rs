//! Host tensor substrate: dense row-major f32 arrays with the shape
//! metadata and init schemes the optimizer family and SNR analysis need.
//!
//! Conventions (shared with the Python manifest — see
//! `python/compile/models/common.py`):
//!
//! * Linear weights are `(fan_out, fan_in)`; axis 0 = fan_out, axis 1 =
//!   fan_in, matching the paper's K-notation.
//! * Conv tensors carry a `fan_out_axis` in their spec; [`Tensor::matrix_view`]
//!   materializes the `(fan_out, prod(rest))` matrix used for Eq. 2 / Eq. 3.

use anyhow::{bail, Result};

use crate::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of the canonical matrix view: `(fan_out, everything-else)`
    /// after rotating `fan_out_axis` to the front. For 1-D tensors the view
    /// is `(1, n)`.
    pub fn matrix_dims(shape: &[usize], fan_out_axis: usize) -> (usize, usize) {
        if shape.len() <= 1 {
            return (1, shape.first().copied().unwrap_or(1));
        }
        let fo = shape[fan_out_axis];
        let rest: usize = shape.iter().product::<usize>() / fo;
        (fo, rest)
    }

    /// Materialize the `(fan_out, fan_in)` matrix view. For tensors whose
    /// `fan_out_axis` is already 0 (all our 1-D/2-D weights) this is a
    /// zero-copy borrow; conv tensors (fan_out_axis = 3, HWIO) are permuted.
    pub fn matrix_view(&self, fan_out_axis: usize) -> MatrixView<'_> {
        let (r, c) = Tensor::matrix_dims(&self.shape, fan_out_axis);
        if self.ndim() <= 2 || fan_out_axis == 0 {
            MatrixView {
                rows: r,
                cols: c,
                data: std::borrow::Cow::Borrowed(&self.data),
            }
        } else {
            // rotate fan_out_axis to the front
            let mut out = vec![0.0f32; self.data.len()];
            let fo = self.shape[fan_out_axis];
            let strides = row_major_strides(&self.shape);
            let fo_stride = strides[fan_out_axis];
            // iterate over the "rest" index space in row-major order with
            // the fan_out axis removed
            let rest_shape: Vec<usize> = self
                .shape
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != fan_out_axis)
                .map(|(_, &s)| s)
                .collect();
            let rest_strides: Vec<usize> = strides
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != fan_out_axis)
                .map(|(_, &s)| s)
                .collect();
            let rest_n: usize = rest_shape.iter().product();
            for o in 0..fo {
                for j in 0..rest_n {
                    // decompose j into the rest coordinates (row-major)
                    let mut rem = j;
                    let mut src = o * fo_stride;
                    for k in (0..rest_shape.len()).rev() {
                        let coord = rem % rest_shape[k];
                        rem /= rest_shape[k];
                        src += coord * rest_strides[k];
                    }
                    out[o * rest_n + j] = self.data[src];
                }
            }
            MatrixView {
                rows: r,
                cols: c,
                data: std::borrow::Cow::Owned(out),
            }
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

/// A `(rows, cols)` matrix view over tensor data (borrowed when no permute
/// was needed).
pub struct MatrixView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: std::borrow::Cow<'a, [f32]>,
}

impl MatrixView<'_> {
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
}

/// Parameter initialization schemes from the manifest
/// (`init_mitchell` / `init_default` blocks).
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Zeros,
    Ones,
    Normal { std: f64 },
    Uniform { limit: f64 },
    TruncNormal { std: f64 },
}

impl Init {
    pub fn from_json(v: &crate::json::Value) -> Result<Init> {
        let scheme = v.get("scheme")?.as_str()?;
        Ok(match scheme {
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            "normal" => Init::Normal {
                std: v.get("std")?.as_f64()?,
            },
            "uniform" => Init::Uniform {
                limit: v.get("limit")?.as_f64()?,
            },
            "trunc_normal" => Init::TruncNormal {
                std: v.get("std")?.as_f64()?,
            },
            s => bail!("unknown init scheme {s:?}"),
        })
    }

    pub fn materialize(&self, shape: &[usize], rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = match self {
            Init::Zeros => vec![0.0; n],
            Init::Ones => vec![1.0; n],
            Init::Normal { std } => (0..n)
                .map(|_| (rng.normal() * std) as f32)
                .collect(),
            Init::Uniform { limit } => (0..n)
                .map(|_| rng.uniform(-limit, *limit) as f32)
                .collect(),
            Init::TruncNormal { std } => (0..n)
                .map(|_| (rng.trunc_normal() * std) as f32)
                .collect(),
        };
        Tensor::from_vec(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    #[test]
    fn construction() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.shape, vec![2, 3]);
        let o = Tensor::ones(&[4]);
        assert_eq!(o.data, vec![1.0; 4]);
    }

    #[test]
    fn matrix_view_2d_is_borrowed() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = t.matrix_view(0);
        assert_eq!((v.rows, v.cols), (2, 3));
        assert_eq!(v.at(1, 2), 6.0);
        assert!(matches!(v.data, std::borrow::Cow::Borrowed(_)));
    }

    #[test]
    fn matrix_view_1d() {
        let t = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let v = t.matrix_view(0);
        assert_eq!((v.rows, v.cols), (1, 3));
    }

    #[test]
    fn matrix_view_conv_hwio() {
        // HWIO (2,1,2,3): fan_out_axis=3 -> view (3, 4) where each row o
        // contains [h,w,i] in row-major order.
        let t = Tensor::from_vec(
            &[2, 1, 2, 3],
            (0..12).map(|x| x as f32).collect(),
        );
        let v = t.matrix_view(3);
        assert_eq!((v.rows, v.cols), (3, 4));
        // element (o=1, h=0,w=0,i=0) = data[0*6+0*6+0*3+1] = 1
        assert_eq!(v.at(1, 0), 1.0);
        // element (o=2, h=1,w=0,i=1) = data[1*6 + 0*3 + 1*3 + 2] -> index
        // h*6 + w*6? strides for (2,1,2,3) = (6,6,3,1); (1,0,1,2) -> 6+3+2=11
        assert_eq!(v.at(2, 3), 11.0);
    }

    #[test]
    fn matrix_view_conv_roundtrip_sum() {
        let t = Tensor::from_vec(&[3, 3, 4, 8], (0..288).map(|x| x as f32).collect());
        let v = t.matrix_view(3);
        let s1: f32 = v.data.iter().sum();
        let s2: f32 = t.data.iter().sum();
        assert_eq!(s1, s2);
        assert_eq!((v.rows, v.cols), (8, 36));
    }

    #[test]
    fn init_normal_stats() {
        let mut rng = Rng::new(1);
        let t = Init::Normal { std: 0.02 }.materialize(&[100, 100], &mut rng);
        let mean = t.mean();
        let var = t.data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / t.numel() as f64;
        assert!(mean.abs() < 1e-3);
        assert!((var.sqrt() - 0.02).abs() < 1e-3);
    }

    #[test]
    fn init_uniform_bounds() {
        let mut rng = Rng::new(2);
        let t = Init::Uniform { limit: 0.125 }.materialize(&[1000], &mut rng);
        assert!(t.data.iter().all(|&x| x.abs() <= 0.125));
        assert!(t.data.iter().any(|&x| x.abs() > 0.06));
    }

    #[test]
    fn init_from_json() {
        let v = Value::parse(r#"{"scheme":"normal","std":0.02}"#).unwrap();
        assert_eq!(Init::from_json(&v).unwrap(), Init::Normal { std: 0.02 });
        let v = Value::parse(r#"{"scheme":"uniform","limit":0.1}"#).unwrap();
        assert_eq!(Init::from_json(&v).unwrap(), Init::Uniform { limit: 0.1 });
        let v = Value::parse(r#"{"scheme":"ones"}"#).unwrap();
        assert_eq!(Init::from_json(&v).unwrap(), Init::Ones);
    }

    #[test]
    fn l2_norm() {
        let t = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-9);
    }
}
