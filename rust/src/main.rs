//! `slimadam` — launcher for the SlimAdam reproduction.
//!
//! The Layer-3 coordinator entry point. All compute graphs were AOT-lowered
//! by `make artifacts`; this binary is self-contained (Python is never on
//! the request path).
//!
//! Subcommands:
//!   exp <id>        reproduce a paper figure/table (fig1..fig30, table1..3, all)
//!   train           run one training config
//!   sweep           run an (optimizer × LR) grid on the parallel scheduler
//!                   (`--resume <dir>` skips jobs already in the run store)
//!   serve           long-lived sweep daemon: durable queue, per-tenant
//!                   stores, streaming subscriptions, drain (DESIGN.md §16)
//!   client          talk to a serve daemon: submit | watch | status |
//!                   drain | cancel | ping
//!   runs            inspect a run store: ls | report | compact
//!   snr             probe a run's second-moment SNR and print the layer table
//!   rules           derive + save SlimAdam compression rules from an SNR probe
//!   memory          optimizer-state memory accounting for a model
//!   list            list artifacts, optimizers and experiment ids
//!   trace           flight-recorder traces: export --chrome (DESIGN.md §15)
//!   obs             observability report from trace/metrics files
//!   bench           bench baseline management: promote
//!
//! Global observability switches (any run command): `--trace` records
//! spans to `results/trace/trace-<pid>.jsonl`, `--telemetry snr[:n]`
//! additionally streams live per-tensor SNR rows (implies --trace).

use anyhow::{bail, Result};

use slimadam::cli::{render_help, subcommand, Args, OptSpec};
use slimadam::coordinator::{run_config, DataSpec, SweepScheduler, TrainConfig};
use slimadam::optim::presets;
use slimadam::rules::RuleSet;
use slimadam::runstore::{RunStore, StoreMeta, SCHEMA_VERSION};
use slimadam::runtime::backend::BackendKind;
use slimadam::snr::ProbeSchedule;
use slimadam::sweep::{log_grid, LrSweep};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const FLAGS: &[&str] = &[
    "help",
    "all",
    "repretrain",
    "fused",
    "corpus",
    "default-init",
    "seed-jobs",
    "quiet",
    "synthetic",
    "trace",
    "chrome",
    "watch",
    // bare `--adaptive` selects the default policy; `--adaptive=e:x:p[:n]`
    // (the `=` form routes around flag parsing) overrides it
    "adaptive",
];

fn dispatch(argv: Vec<String>) -> Result<()> {
    let Ok((cmd, rest)) = subcommand(argv) else {
        print_global_help();
        return Ok(());
    };
    let args = Args::parse(rest, FLAGS)?;
    obs_init(&args)?;
    let result = run_command(&cmd, &args);
    obs_finish();
    result
}

/// Arm the flight recorder from `--trace` / `--telemetry` / env before
/// the command runs (DESIGN.md §15). `--telemetry` implies tracing: SNR
/// rows ride the trace stream.
fn obs_init(args: &Args) -> Result<()> {
    let mut trace = args.flag("trace")
        || std::env::var("SLIMADAM_TRACE")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false);
    if let Some(spec) = args.get("telemetry") {
        let every = slimadam::obs::telemetry::parse_spec(spec)?;
        slimadam::obs::telemetry::set_snr_every(Some(every));
        trace = true;
    }
    if trace {
        let dir = args
            .get("trace-dir")
            .map(std::path::PathBuf::from)
            .or_else(|| std::env::var("SLIMADAM_TRACE_DIR").ok().map(Into::into))
            .unwrap_or_else(slimadam::obs::flush::default_dir);
        slimadam::obs::start_tracing(&dir)?;
        eprintln!("trace: recording to {}", dir.display());
    }
    Ok(())
}

/// Flush and close the trace session, if one was armed.
fn obs_finish() {
    let dir = slimadam::obs::trace_dir();
    if let Ok(n) = slimadam::obs::stop_tracing() {
        if n > 0 {
            if let Some(d) = dir {
                eprintln!("trace: {n} spans -> {}", d.display());
            }
        }
    }
}

fn run_command(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "exp" => {
            if args.positional.is_empty() || args.flag("help") {
                println!(
                    "{}",
                    render_help(
                        "slimadam",
                        "exp <id>",
                        "reproduce a paper figure/table",
                        &exp_opts()
                    )
                );
                println!("experiment ids: {}", slimadam::exp::IDS.join(", "));
                return Ok(());
            }
            let id = args.positional[0].clone();
            slimadam::exp::run(&id, args)
        }
        "train" => cmd_train(args),
        "sweep" => cmd_sweep(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "runs" => cmd_runs(args),
        "snr" => cmd_snr(args),
        "rules" => cmd_rules(args),
        "memory" => cmd_memory(args),
        "report" => cmd_report(args),
        "trace" => cmd_trace(args),
        "obs" => cmd_obs(args),
        "bench" => cmd_bench(args),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_global_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `slimadam help`"),
    }
}

fn print_global_help() {
    println!(
        "slimadam — reproduction of \"When Can You Get Away with Low Memory Adam?\"\n\n\
         Usage: slimadam <command> [options]\n\n\
         Commands:\n\
         \x20 exp <id>   reproduce a paper figure/table (see `slimadam exp --help`)\n\
         \x20 train      run one training config\n\
         \x20 sweep      run an (optimizer × LR) grid on the parallel scheduler\n\
         \x20 serve      long-lived sweep daemon with a durable queue (DESIGN.md §16)\n\
         \x20 client     talk to a serve daemon: submit | watch | status | drain\n\
         \x20 runs       inspect a run store: ls | report | compact\n\
         \x20 snr        probe second-moment SNR along an Adam run\n\
         \x20 rules      derive SlimAdam compression rules from an SNR probe\n\
         \x20 memory     optimizer-state memory accounting\n\
         \x20 trace      flight-recorder traces: export --chrome\n\
         \x20 obs        observability report from trace/metrics files\n\
         \x20 bench      bench baseline management: promote\n\
         \x20 list       list artifacts, optimizers and experiments\n\n\
         Global: --trace records spans to results/trace/, --telemetry\n\
         snr[:n] streams live SNR rows (implies --trace).\n\n\
         Run `make artifacts` first to AOT-lower the HLO artifacts."
    );
}

fn exp_opts() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "model", help: "artifact model name", default: Some("per-experiment"), is_flag: false },
        OptSpec { name: "backend", help: "execution backend: pjrt | native", default: Some("pjrt"), is_flag: false },
        OptSpec { name: "precision", help: "native compute precision: f64 | f32 (f64 is the verify reference)", default: Some("f64"), is_flag: false },
        OptSpec { name: "intraop", help: "intra-op kernel worker threads (native; results invariant)", default: Some("1"), is_flag: false },
        OptSpec { name: "steps", help: "training steps per run", default: Some("per-experiment"), is_flag: false },
        OptSpec { name: "lrs", help: "comma-separated LR grid", default: Some("per-experiment"), is_flag: false },
        OptSpec { name: "workers", help: "parallel runs", default: Some("cores"), is_flag: false },
        OptSpec { name: "all", help: "include expensive extras (fine-tune regime)", default: None, is_flag: true },
        OptSpec { name: "trace", help: "record flight-recorder spans to results/trace/", default: None, is_flag: true },
        OptSpec { name: "telemetry", help: "live SNR tap: snr[:every_n] (implies --trace)", default: None, is_flag: false },
    ]
}

fn data_spec(args: &Args) -> DataSpec {
    if args.flag("corpus") {
        DataSpec::Corpus
    } else {
        DataSpec::Markov {
            alpha: 1.07,
            coherence: 0.5,
            seed: 1234,
        }
    }
}

/// The builtin native models carry their own names; default to the
/// native transformer when `--backend native` is given without `--model`.
fn default_model(kind: BackendKind) -> &'static str {
    match kind {
        BackendKind::Native => "gpt_micro",
        BackendKind::Pjrt => "gpt_nano",
    }
}

fn base_config(args: &Args) -> Result<TrainConfig> {
    // Intra-op kernel parallelism (native backend; DESIGN.md §14).
    // Results are worker-count invariant by construction, so this is a
    // throughput knob only and deliberately absent from config keys.
    if let Ok(n) = args.usize_or("intraop", 0) {
        if n > 0 {
            slimadam::pool::set_intraop_workers(n);
        }
    }
    let backend = slimadam::exp::backend_spec(args)?;
    let model = args.str_or("model", default_model(backend.kind)).to_string();
    let optimizer = args.str_or("optimizer", "adam").to_string();
    let lr = args.f64_or("lr", 1e-3)?;
    let steps = args.usize_or("steps", 100)?;
    let vision = TrainConfig::is_vision(&model);
    let mut cfg = TrainConfig::auto(&model, &optimizer, lr, steps);
    if !vision {
        cfg.data = data_spec(args);
    }
    cfg.backend = backend;
    cfg.seed = args.u64_or("seed", 0)?;
    cfg.accum = args.usize_or("accum", 1)?;
    if args.flag("default-init") {
        cfg.init = "default".into();
    }
    if args.flag("fused") {
        cfg.engine = slimadam::coordinator::EngineKind::Fused(
            args.str_or("ruleset", "adam").to_string(),
        );
    }
    if let Some(path) = args.get("rules") {
        cfg.ruleset = Some(RuleSet::load(path)?);
    }
    if args.flag("adaptive") || args.get("adaptive").is_some() {
        cfg.adaptive = Some(slimadam::rules::adaptive::AdaptivePolicy::parse(
            args.str_or("adaptive", ""),
        )?);
        if !args.flag("fused") {
            bail!("--adaptive needs --fused (the controller migrates fused V state; try --fused --ruleset slimadam)");
        }
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "{}",
            render_help("slimadam", "train", "run one training config", &[
                OptSpec { name: "model", help: "artifact model", default: Some("gpt_nano (pjrt) / gpt_micro (native)"), is_flag: false },
                OptSpec { name: "backend", help: "execution backend: pjrt | native (optionally +f32 and/or @device, e.g. native+f32@cpu:0)", default: Some("pjrt"), is_flag: false },
                OptSpec { name: "precision", help: "native compute precision: f64 | f32 (overrides the spec suffix)", default: Some("f64"), is_flag: false },
                OptSpec { name: "intraop", help: "intra-op kernel worker threads (native; results invariant)", default: Some("1"), is_flag: false },
                OptSpec { name: "optimizer", help: "optimizer preset", default: Some("adam"), is_flag: false },
                OptSpec { name: "lr", help: "peak learning rate", default: Some("1e-3"), is_flag: false },
                OptSpec { name: "steps", help: "training steps", default: Some("100"), is_flag: false },
                OptSpec { name: "rules", help: "SlimAdam rules JSON path", default: None, is_flag: false },
                OptSpec { name: "fused", help: "use the fused train_step artifact", default: None, is_flag: true },
                OptSpec { name: "ruleset", help: "fused artifact ruleset", default: Some("adam"), is_flag: false },
                OptSpec { name: "adaptive", help: "online SNR-driven rule switching (native fused only): bare flag for defaults, or --adaptive=enter:exit:patience[:every]", default: Some("1:0.25:3:25"), is_flag: true },
                OptSpec { name: "corpus", help: "train on the repo-source corpus", default: None, is_flag: true },
                OptSpec { name: "default-init", help: "PyTorch-default init instead of Mitchell", default: None, is_flag: true },
                OptSpec { name: "trace", help: "record flight-recorder spans to results/trace/", default: None, is_flag: true },
                OptSpec { name: "telemetry", help: "live SNR tap: snr[:every_n] (implies --trace)", default: None, is_flag: false },
            ])
        );
        return Ok(());
    }
    let cfg = base_config(args)?;
    println!("training {}", cfg.label());
    let s = run_config(&cfg)?;
    println!(
        "done: final train loss {:.4}, eval loss {:.4}, {:.2} steps/s{}",
        s.result.final_train_loss,
        s.result.eval_loss,
        s.steps_per_s,
        if s.result.diverged { " (DIVERGED)" } else { "" }
    );
    if let Some(m) = &s.memory {
        println!("{}", m.row());
    }
    Ok(())
}

/// Run an (optimizer × LR) grid on the work-stealing sweep scheduler,
/// with optional streaming JSONL and CSV sinks.
fn cmd_sweep(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "{}",
            render_help("slimadam", "sweep", "run an (optimizer × LR) grid on the parallel scheduler", &[
                OptSpec { name: "model", help: "artifact model", default: Some("gpt_nano (pjrt) / gpt_micro (native)"), is_flag: false },
                OptSpec { name: "backend", help: "execution backend: pjrt | native (optionally +f32, e.g. native+f32)", default: Some("pjrt"), is_flag: false },
                OptSpec { name: "precision", help: "native compute precision: f64 | f32 (overrides the spec suffix)", default: Some("f64"), is_flag: false },
                OptSpec { name: "intraop", help: "intra-op kernel worker threads per job (native; results invariant)", default: Some("1"), is_flag: false },
                OptSpec { name: "optimizers", help: "comma-separated optimizer presets (bake-off: adam,slimadam,lion,adafactor,sm3,sgdm,lowrank_v)", default: Some("adam,slimadam"), is_flag: false },
                OptSpec { name: "lrs", help: "comma-separated LR grid", default: Some("log grid 1e-4..1e-2, 4 pts"), is_flag: false },
                OptSpec { name: "steps", help: "training steps per job", default: Some("100"), is_flag: false },
                OptSpec { name: "workers", help: "worker threads (0 = one per core)", default: Some("0"), is_flag: false },
                OptSpec { name: "batch", help: "stack up to N same-artifact jobs into one backend dispatch per step (results identical to --batch 1)", default: Some("1"), is_flag: false },
                OptSpec { name: "stream", help: "append per-job JSONL rows to this path as jobs finish", default: None, is_flag: false },
                OptSpec { name: "resume", help: "run store dir: skip jobs already completed there (streams new rows into it unless --stream overrides)", default: None, is_flag: false },
                OptSpec { name: "csv", help: "write the finished sweep table to this CSV path", default: None, is_flag: false },
                OptSpec { name: "fused", help: "fused train_step engine: each optimizer token runs its own <model>.train.<token> artifact", default: None, is_flag: true },
                OptSpec { name: "adaptive", help: "online SNR-driven rule switching per job (native fused only): bare flag or --adaptive=enter:exit:patience[:every]", default: Some("1:0.25:3:25"), is_flag: true },
                OptSpec { name: "seed-jobs", help: "derive an independent seed per grid point (default: paired)", default: None, is_flag: true },
                OptSpec { name: "quiet", help: "suppress per-job progress lines", default: None, is_flag: true },
                OptSpec { name: "synthetic", help: "deterministic artifact-free synthetic runs (testing; same as SLIMADAM_SYNTH_RUNS=1)", default: None, is_flag: true },
                OptSpec { name: "trace", help: "record flight-recorder spans to results/trace/", default: None, is_flag: true },
                OptSpec { name: "telemetry", help: "live SNR tap: snr[:every_n] (implies --trace)", default: None, is_flag: false },
            ])
        );
        return Ok(());
    }
    if args.flag("synthetic") {
        std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
    }
    let base = base_config(args)?;
    let opts = args.str_list("optimizers", &["adam", "slimadam"]);
    let opt_refs: Vec<&str> = opts.iter().map(|s| s.as_str()).collect();
    let lrs = args.f64_list("lrs", &log_grid(1e-4, 1e-2, 4))?;
    let workers = args.usize_or("workers", 0)?;
    let batch = args.usize_or("batch", 1)?;

    let mut scheduler = SweepScheduler::new(workers).batch(batch);
    if args.flag("quiet") {
        scheduler = scheduler.quiet();
    }
    let store_meta = StoreMeta {
        schema_version: SCHEMA_VERSION,
        base_seed: base.seed,
        backend: base.backend.key(),
    };
    if let Some(dir) = args.get("resume") {
        let store = RunStore::open_with(dir, &store_meta)?;
        // default the stream sink into the store so finished jobs extend it
        scheduler = scheduler
            .resume_from(&store)?
            .stream_to(args.get("stream").map(Into::into).unwrap_or(store.primary()));
    } else if let Some(path) = args.get("stream") {
        // Plain streaming claims no store: --stream may point anywhere
        // (including cwd next to unrelated files). The directory becomes
        // a run store — manifest written with real provenance — on the
        // first --resume against it.
        scheduler = scheduler.stream_to(path);
    }
    println!(
        "sweep: {} × {} optimizers × {} LRs, {} steps each{}",
        base.model,
        opts.len(),
        lrs.len(),
        base.steps,
        if batch > 1 {
            format!(", batched dispatch ≤{batch}")
        } else {
            String::new()
        }
    );
    let sweep = if args.flag("seed-jobs") {
        LrSweep::run_seeded(&base, &opt_refs, &lrs, &scheduler, base.seed)
    } else {
        LrSweep::run_with(&base, &opt_refs, &lrs, &scheduler)
    }?;

    println!("\n{}", sweep.chart("sweep — final loss vs learning rate"));
    for (i, name) in sweep.optimizers.iter().enumerate() {
        let (lr, loss) = sweep.best(i);
        println!("{name:16} best lr {lr:.2e} -> loss {loss:.4}");
    }
    if let Some(path) = args.get("csv") {
        sweep.write_csv(path)?;
        println!("wrote {path}");
    }
    // cache hit/compile totals now ride the scheduler's structured
    // `sweep summary:` line (registry counters, DESIGN.md §15)
    Ok(())
}

/// `slimadam serve --addr <unix-socket|host:port>`: the long-lived
/// sweep-as-a-service daemon (DESIGN.md §16). Owns one warm executable
/// cache and worker pool, journals every accepted job to
/// `<state-dir>/queue.jsonl`, streams result rows to subscribers, and
/// exits 0 after a graceful drain (SIGTERM/SIGINT or a `drain` request).
fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("help") {
        println!(
            "{}",
            render_help("slimadam", "serve", "long-lived sweep daemon with a durable queue", &[
                OptSpec { name: "addr", help: "listen address: unix socket path (contains '/') or host:port", default: None, is_flag: false },
                OptSpec { name: "state-dir", help: "daemon state: queue.jsonl journal + tenants/<ns>/ run stores", default: Some("results/serve"), is_flag: false },
                OptSpec { name: "workers", help: "worker threads (0 = one per core, capped at 8)", default: Some("0"), is_flag: false },
                OptSpec { name: "max-batch", help: "adaptive batched-dispatch cap (1 = never batch)", default: Some("8"), is_flag: false },
                OptSpec { name: "queue-cap", help: "bounded queue capacity in jobs; beyond it submits get `overloaded`", default: Some("64"), is_flag: false },
                OptSpec { name: "quiet", help: "suppress per-row progress lines", default: None, is_flag: true },
                OptSpec { name: "synthetic", help: "deterministic artifact-free synthetic runs (testing; same as SLIMADAM_SYNTH_RUNS=1)", default: None, is_flag: true },
                OptSpec { name: "trace", help: "record flight-recorder spans to results/trace/", default: None, is_flag: true },
            ])
        );
        return Ok(());
    }
    if args.flag("synthetic") {
        std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
    }
    let Some(addr) = args.get("addr") else {
        bail!("serve needs --addr <unix-socket path | host:port>");
    };
    let opts = slimadam::serve::ServeOpts {
        addr: addr.to_string(),
        state_dir: std::path::PathBuf::from(args.str_or("state-dir", "results/serve")),
        workers: args.usize_or("workers", 0)?,
        max_batch: args.usize_or("max-batch", 8)?,
        queue_cap: args.usize_or("queue-cap", 64)?,
        quiet: args.flag("quiet"),
    };
    slimadam::serve::run(opts)
}

/// Build a serve [`slimadam::serve::JobSpec`] from the same grid flags as
/// `sweep`, so a submitted job expands to byte-identical configs.
fn job_spec(args: &Args) -> Result<slimadam::serve::JobSpec> {
    let backend = slimadam::exp::backend_spec(args)?;
    let spec = slimadam::serve::JobSpec {
        model: args.str_or("model", default_model(backend.kind)).to_string(),
        backend: backend.key(),
        optimizers: args.str_list("optimizers", &["adam", "slimadam"]),
        lrs: args.f64_list("lrs", &log_grid(1e-4, 1e-2, 4))?,
        steps: args.usize_or("steps", 100)?,
        seed: args.u64_or("seed", 0)?,
        accum: args.usize_or("accum", 1)?,
        fused: if args.flag("fused") {
            Some(args.str_or("ruleset", "adam").to_string())
        } else {
            None
        },
        seed_jobs: args.flag("seed-jobs"),
        adaptive: if args.flag("adaptive") || args.get("adaptive").is_some() {
            Some(args.str_or("adaptive", "").to_string())
        } else {
            None
        },
    };
    spec.validate()?;
    Ok(spec)
}

/// `slimadam client <submit|watch|status|drain|cancel|ping> --addr a`:
/// thin CLI over [`slimadam::serve::Client`] (DESIGN.md §16).
fn cmd_client(args: &Args) -> Result<()> {
    if args.flag("help") || args.positional.is_empty() {
        println!(
            "{}",
            render_help("slimadam", "client <submit|watch|status|drain|cancel|ping>", "talk to a running serve daemon", &[
                OptSpec { name: "addr", help: "daemon address: unix socket path or host:port", default: None, is_flag: false },
                OptSpec { name: "tenant", help: "client namespace (per-tenant run store)", default: Some("default"), is_flag: false },
                OptSpec { name: "job", help: "job id (watch filter / cancel target)", default: None, is_flag: false },
                OptSpec { name: "watch", help: "submit: stream result rows until the job completes", default: None, is_flag: true },
                OptSpec { name: "model", help: "submit: artifact model", default: Some("gpt_nano (pjrt) / gpt_micro (native)"), is_flag: false },
                OptSpec { name: "backend", help: "submit: execution backend: pjrt | native (optionally +f32)", default: Some("pjrt"), is_flag: false },
                OptSpec { name: "precision", help: "submit: native compute precision: f64 | f32", default: Some("f64"), is_flag: false },
                OptSpec { name: "optimizers", help: "submit: comma-separated optimizer presets", default: Some("adam,slimadam"), is_flag: false },
                OptSpec { name: "lrs", help: "submit: comma-separated LR grid", default: Some("log grid 1e-4..1e-2, 4 pts"), is_flag: false },
                OptSpec { name: "steps", help: "submit: training steps per job", default: Some("100"), is_flag: false },
                OptSpec { name: "seed", help: "submit: base seed", default: Some("0"), is_flag: false },
                OptSpec { name: "accum", help: "submit: gradient accumulation steps", default: Some("1"), is_flag: false },
                OptSpec { name: "fused", help: "submit: use the fused train_step artifact", default: None, is_flag: true },
                OptSpec { name: "ruleset", help: "submit: fused artifact ruleset", default: Some("adam"), is_flag: false },
                OptSpec { name: "adaptive", help: "submit: online SNR-driven rule switching (native fused only)", default: None, is_flag: true },
                OptSpec { name: "seed-jobs", help: "submit: derive an independent seed per grid point", default: None, is_flag: true },
            ])
        );
        println!(
            "actions:\n\
             \x20 submit   queue an (optimizer × LR) grid under --tenant\n\
             \x20 watch    stream result rows (--tenant / --job filter)\n\
             \x20 status   queue depth and per-job states\n\
             \x20 drain    stop admitting, finish in-flight work, exit 0\n\
             \x20 cancel   remove a still-queued job (--job)\n\
             \x20 ping     liveness probe"
        );
        return Ok(());
    }
    let action =
        args.require_positional(0, "action (submit | watch | status | drain | cancel | ping)")?;
    let Some(addr) = args.get("addr") else {
        bail!("client needs --addr <unix-socket path | host:port>");
    };
    let mut client = slimadam::serve::Client::connect(addr)?;
    match action {
        "ping" => {
            anyhow::ensure!(client.ping()?, "daemon on {addr} did not answer pong");
            println!("pong");
            Ok(())
        }
        "status" => {
            println!("{}", client.status()?.dump_pretty());
            Ok(())
        }
        "drain" => {
            let r = client.drain()?;
            anyhow::ensure!(
                r.get("reply")?.as_str()? == "draining",
                "drain rejected: {}",
                r.dump()
            );
            println!("draining");
            Ok(())
        }
        "cancel" => {
            let Some(job) = args.get("job") else {
                bail!("cancel needs --job <id>");
            };
            if client.cancel(job)? {
                println!("cancelled {job}");
            } else {
                println!("{job} was not queued (already running, done, or unknown)");
            }
            Ok(())
        }
        "submit" => {
            let tenant = args.str_or("tenant", "default");
            let spec = job_spec(args)?;
            let watch = args.flag("watch");
            let reply = client.submit(tenant, &spec, watch)?;
            let kind = reply.get("reply")?.as_str()?.to_string();
            anyhow::ensure!(kind == "queued", "submit rejected: {}", reply.dump());
            let job = reply.get("job")?.as_str()?.to_string();
            println!(
                "queued {job} — tenant {tenant}, {} configs",
                reply.get("configs")?.as_usize()?
            );
            if watch {
                let done = client.wait_job(&job, |event| {
                    if let Some(row) = event.opt("row") {
                        println!("{}", row.dump());
                    }
                })?;
                let failed = done
                    .opt("failed")
                    .and_then(|b| b.as_bool().ok())
                    .unwrap_or(false);
                anyhow::ensure!(!failed, "job {job} failed — see the daemon log");
                println!(
                    "done {job}: {} ran, {} resumed",
                    done.get("ran")?.as_usize()?,
                    done.get("skipped")?.as_usize()?
                );
            }
            Ok(())
        }
        "watch" => {
            client.subscribe(args.get("tenant"), args.get("job"))?;
            while let Some(event) = client.next_event()? {
                println!("{}", event.dump());
                if event.opt("reply").and_then(|r| r.as_str().ok()) == Some("bye") {
                    break;
                }
            }
            Ok(())
        }
        other => {
            bail!("unknown client action {other:?} — try submit, watch, status, drain, cancel or ping")
        }
    }
}

/// Inspect a run store: `slimadam runs <ls|report|compact> [--dir d]`.
fn cmd_runs(args: &Args) -> Result<()> {
    if args.flag("help") || args.positional.is_empty() {
        println!(
            "{}",
            render_help("slimadam", "runs <ls|report|compact>", "inspect a run store of completed sweep jobs", &[
                OptSpec { name: "dir", help: "run store directory (or a .jsonl file inside it)", default: Some("results/sweep"), is_flag: false },
            ])
        );
        println!(
            "actions:\n\
             \x20 ls       list stream files with row/torn/legacy counts\n\
             \x20 report   aggregate completed jobs per (model, optimizer)\n\
             \x20 compact  merge stream files, dropping duplicate/torn rows"
        );
        return Ok(());
    }
    let action = args.require_positional(0, "action (ls | report | compact)")?;
    let dir = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or_else(|| args.str_or("dir", "results/sweep"));
    let store = RunStore::open(dir)?;
    match action {
        "ls" => {
            let (files, idx) = store.ls()?;
            if files.is_empty() {
                println!("run store {:?}: no stream files", store.dir());
                return Ok(());
            }
            println!(
                "{:<40} {:>10} {:>7} {:>7} {:>6} {:>6}",
                "file", "bytes", "rows", "legacy", "torn", "bad"
            );
            for f in &files {
                println!(
                    "{:<40} {:>10} {:>7} {:>7} {:>6} {:>6}",
                    f.path.display().to_string(),
                    f.bytes,
                    f.rows,
                    f.legacy,
                    f.torn,
                    f.skipped
                );
            }
            println!(
                "\n{} unique completed jobs ({} duplicates, {} conflicts)",
                idx.len(),
                idx.stats.duplicates,
                idx.stats.conflicts
            );
            Ok(())
        }
        "report" => {
            print!("{}", store.report()?);
            Ok(())
        }
        "compact" => {
            let report = slimadam::runstore::compact(&store)?;
            println!("{}", report.line());
            Ok(())
        }
        other => bail!("unknown runs action {other:?} — try ls, report or compact"),
    }
}

fn cmd_snr(args: &Args) -> Result<()> {
    let mut cfg = base_config(args)?;
    cfg.probe = Some(ProbeSchedule::default());
    println!("probing SNR along {}", cfg.label());
    let s = run_config(&cfg)?;
    let snr = s.snr.expect("probe enabled");
    println!("\n{}", slimadam::exp::layer_type_table(&snr));
    Ok(())
}

fn cmd_rules(args: &Args) -> Result<()> {
    let mut cfg = base_config(args)?;
    cfg.probe = Some(ProbeSchedule::default());
    let cutoff = args.f64_or("cutoff", 1.0)?;
    let out = args.str_or("out", "results/rules.json").to_string();
    let depth_mean = args.get("variant").map(|v| v == "mean").unwrap_or(false);
    println!("deriving rules from {} (cutoff {cutoff})", cfg.label());
    let s = run_config(&cfg)?;
    let snr = s.snr.expect("probe enabled");
    let rules = if depth_mean {
        RuleSet::derive_depth_averaged(&snr, cutoff, "cli_mean", Some(cfg.lr))
    } else {
        RuleSet::derive(&snr, cutoff, "cli", Some(cfg.lr))
    };
    let man = slimadam::exp::manifest_for(&cfg.backend, &cfg.model)?;
    rules.save(&out)?;
    println!(
        "saved {} rules to {out} — {:.1}% of second moments saved",
        rules.rules.len(),
        100.0 * rules.saving(&man)
    );
    println!("\n{}", slimadam::exp::layer_type_table(&snr));
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let backend = slimadam::exp::backend_spec(args)?;
    let model = args.str_or("model", default_model(backend.kind));
    let man = slimadam::exp::manifest_for(&backend, model)?;
    let total = man.total_param_elems();
    println!(
        "model {model}: {} tensors, {total} parameters\n",
        man.n_params()
    );
    for name in presets::ALL {
        let opt = presets::build(name, &man, Default::default())?;
        println!("{}", slimadam::optim::memory::report(opt.as_ref(), total).row());
    }
    Ok(())
}

/// Assemble every experiment's `summary.md` into one report (the measured
/// half of EXPERIMENTS.md).
fn cmd_report(args: &Args) -> Result<()> {
    let out_path = args.str_or("out", "results/REPORT.md").to_string();
    let mut out = String::from("# SlimAdam reproduction — collected experiment summaries\n");
    let mut found = 0;
    let order = [
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
        "fig9", "fig10", "fig11", "fig12", "fig27", "fig30", "table1",
        "table2", "table3", "e2e",
    ];
    for id in order {
        let path = std::path::Path::new("results").join(id).join("summary.md");
        if let Ok(text) = std::fs::read_to_string(&path) {
            out.push_str(&format!("\n\n---\n\n<!-- results/{id}/summary.md -->\n\n"));
            out.push_str(&text);
            found += 1;
        }
    }
    anyhow::ensure!(found > 0, "no results/<id>/summary.md files found — run `slimadam exp all`");
    std::fs::write(&out_path, &out)?;
    println!("wrote {found} experiment summaries to {out_path}");
    Ok(())
}

/// `slimadam trace export --chrome [--dir d] [--out f]`: convert the
/// flight-recorder JSONL traces to one Chrome `trace_event` JSON for
/// `chrome://tracing` / Perfetto (DESIGN.md §15).
fn cmd_trace(args: &Args) -> Result<()> {
    if args.flag("help") || args.positional.is_empty() {
        println!(
            "{}",
            render_help("slimadam", "trace <export>", "flight-recorder trace tooling", &[
                OptSpec { name: "chrome", help: "export as Chrome trace_event JSON (the only format)", default: None, is_flag: true },
                OptSpec { name: "dir", help: "trace directory to read", default: Some("results/trace"), is_flag: false },
                OptSpec { name: "out", help: "output path", default: Some("<dir>/trace.chrome.json"), is_flag: false },
            ])
        );
        return Ok(());
    }
    let action = args.require_positional(0, "action (export)")?;
    anyhow::ensure!(action == "export", "unknown trace action {action:?} — try export");
    let dir = std::path::PathBuf::from(args.str_or("dir", "results/trace"));
    let out = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| dir.join("trace.chrome.json"));
    let stats = slimadam::obs::chrome::export_dir(&dir, &out)?;
    println!(
        "exported {} events from {} trace file(s) to {}{}",
        stats.events,
        stats.files,
        out.display(),
        if stats.torn > 0 {
            format!(" ({} torn tail(s) recovered)", stats.torn)
        } else {
            String::new()
        }
    );
    Ok(())
}

/// `slimadam obs report [--dir d]`: merge the `metrics-*.json` registry
/// snapshots and roll up span kinds from the `trace-*.jsonl` files into
/// one table (DESIGN.md §15).
fn cmd_obs(args: &Args) -> Result<()> {
    if args.flag("help") || args.positional.is_empty() {
        println!(
            "{}",
            render_help("slimadam", "obs <report>", "observability report from trace/metrics files", &[
                OptSpec { name: "dir", help: "trace directory to read", default: Some("results/trace"), is_flag: false },
            ])
        );
        return Ok(());
    }
    let action = args.require_positional(0, "action (report)")?;
    anyhow::ensure!(action == "report", "unknown obs action {action:?} — try report");
    let dir = std::path::PathBuf::from(args.str_or("dir", "results/trace"));
    let report = slimadam::obs::report::build(&dir)?;
    print!("{report}");
    Ok(())
}

/// `slimadam bench promote`: rewrite the committed bench-regression
/// baseline from the latest `BENCH_native.json`, clearing the bootstrap
/// `provisional` marker so the CI gate arms for real.
fn cmd_bench(args: &Args) -> Result<()> {
    if args.flag("help") || args.positional.is_empty() {
        println!(
            "{}",
            render_help("slimadam", "bench <promote>", "bench baseline management", &[
                OptSpec { name: "summary", help: "fresh summary to promote", default: Some("results/bench/BENCH_native.json"), is_flag: false },
                OptSpec { name: "baseline", help: "baseline file to rewrite", default: Some("results/bench/BENCH_baseline.json"), is_flag: false },
            ])
        );
        return Ok(());
    }
    let action = args.require_positional(0, "action (promote)")?;
    anyhow::ensure!(action == "promote", "unknown bench action {action:?} — try promote");
    let summary = std::path::PathBuf::from(
        args.str_or("summary", "results/bench/BENCH_native.json"),
    );
    let baseline = std::path::PathBuf::from(
        args.str_or("baseline", "results/bench/BENCH_baseline.json"),
    );
    slimadam::benchkit::promote_baseline(&summary, &baseline)?;
    println!(
        "promoted {} -> {} (provisional marker cleared)",
        summary.display(),
        baseline.display()
    );
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("experiments: {}", slimadam::exp::IDS.join(", "));
    println!("optimizers:  {}", presets::ALL.join(", "));
    println!(
        "native:      {} (rulesets: {}; fused optimizers: {}) — `--backend native`, no artifacts needed",
        slimadam::runtime::backend::native::MODELS.join(", "),
        slimadam::runtime::backend::native::RULESETS.join(", "),
        slimadam::runtime::backend::native::OPTIMIZERS.join(", ")
    );
    print!("artifacts:   ");
    let dir = std::path::Path::new("artifacts");
    if dir.exists() {
        let mut names: Vec<String> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_string_lossy()
                    .strip_suffix(".hlo.txt")
                    .map(|s| s.to_string())
            })
            .collect();
        names.sort();
        println!("{}", names.join(", "));
    } else {
        println!("(none — run `make artifacts`)");
    }
    Ok(())
}
