//! Figure 3 (+ App. Figs. 14/16/17): depth dependence of time-averaged SNR
//! per layer type — which compression dimension wins at each depth.
//!
//! Offline: `--backend native` defaults to the builtin `gpt_deep`
//! (4 transformer blocks, per-block `h<i>.*` parameter names), so the
//! depth axis is real without any artifacts.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::{results_dir, CsvWriter};
use crate::runtime::backend::BackendKind;

use super::{probed_run, steps_or, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let default_model = match super::backend_spec(args)?.kind {
        BackendKind::Native => "gpt_deep",
        BackendKind::Pjrt => "gpt_nano",
    };
    let model = args.str_or("model", default_model).to_string();
    let steps = steps_or(args, 200);
    let lr = args.f64_or("lr", 1e-3)?;

    println!("fig3: depth dependence of averaged SNR on {model}");
    let mut cfg = TrainConfig::lm(&model, "adam", lr, steps);
    super::apply_common(args, &mut cfg)?;
    let (_, snr) = probed_run(cfg)?;

    let dir = results_dir("fig3")?;
    let mut w = CsvWriter::create(
        dir.join("rows.csv"),
        &["layer_type", "depth", "snr_fan_out", "snr_fan_in", "snr_both", "best_k"],
    )?;
    let mut md = String::from(
        "# Fig. 3 — depth dependence of averaged SNR\n\n\
         | layer_type | depth | fan_out | fan_in | both | K* |\n|---|---|---|---|---|---|\n",
    );
    for (avg, info) in snr.per_param.iter().zip(&snr.metas) {
        if info.is_vector() || avg.n == 0 {
            continue;
        }
        let (k, _) = avg.best();
        w.row(&[
            info.layer_type.clone(),
            info.depth.to_string(),
            format!("{:.4}", avg.fan_out),
            format!("{:.4}", avg.fan_in),
            format!("{:.4}", avg.both),
            k.as_str(),
        ])?;
        md.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {} |\n",
            info.layer_type,
            info.depth,
            avg.fan_out,
            avg.fan_in,
            avg.both,
            k.as_str()
        ));
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
