//! Figure 2 (+ App. C.1 Figs. 13/15): SNR trajectories of selected
//! second-moment blocks along an Adam run. Paper shapes to reproduce:
//! Tok.Embd strongly prefers the embedding dimension over the token
//! dimension; keys/queries prefer fan_in over fan_out; values/projections
//! the opposite; MLP LayerNorms stay high while attention LayerNorms sag.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::json::Value;
use crate::metrics::{ascii_chart, results_dir, JsonlWriter};
use crate::runtime::KMode;

use super::{probed_run, steps_or, write_snr, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 200);
    let lr = args.f64_or("lr", 1e-3)?;
    let data = args.str_or("data", "markov").to_string();

    let mut cfg = TrainConfig::lm(&model, "adam", lr, steps);
    super::apply_common(args, &mut cfg)?;
    let backend = cfg.backend;
    if data == "corpus" {
        cfg.data = crate::coordinator::DataSpec::Corpus;
    }
    println!("fig2: probing Adam second moments on {model} ({steps} steps, lr {lr:.0e}, {data})");
    let (summary, snr) = probed_run(cfg)?;

    let dir = results_dir("fig2")?;
    write_snr(&dir, "snr_avg.jsonl", &snr)?;

    // full trajectories
    let man = super::manifest_for(&backend, &model)?;
    let mut w = JsonlWriter::create(dir.join("trajectories.jsonl"))?;
    for (idx, samples) in &summary.result.probe.records {
        let info = &man.params[*idx];
        for s in samples {
            let mut v = Value::obj();
            v.set("name", info.name.clone())
                .set("layer_type", info.layer_type.clone())
                .set("depth", info.depth)
                .set("step", s.step)
                .set("fan_out", finite(s.fan_out))
                .set("fan_in", finite(s.fan_in))
                .set("both", finite(s.both));
            w.write(&v)?;
        }
    }

    // charts for the paper's selected blocks
    let mut md = String::from("# Fig. 2 — SNR trajectories (Adam second moments)\n\n");
    for (title, name, k_pref, k_avoid) in selected_blocks(&man.family) {
        let Some(idx) = man.params.iter().position(|p| p.name == name) else {
            continue;
        };
        let Some(samples) = summary.result.probe.records.get(&idx) else {
            continue;
        };
        let pref: Vec<(f64, f64)> = samples
            .iter()
            .map(|s| (s.step as f64, s.get(k_pref).max(1e-6)))
            .collect();
        let avoid: Vec<(f64, f64)> = samples
            .iter()
            .map(|s| (s.step as f64, s.get(k_avoid).max(1e-6)))
            .collect();
        let chart = ascii_chart(
            &format!("{title} ({name}) — SNR vs step (log y)"),
            &[
                (&format!("K={}", k_pref.as_str()), &pref),
                (&format!("K={}", k_avoid.as_str()), &avoid),
            ],
            56,
            10,
            false,
            true,
        );
        println!("{chart}");
        let last = samples.last().unwrap();
        md.push_str(&format!(
            "- **{title}**: SNR_{}(end) = {:.3}, SNR_{}(end) = {:.3} — preferred dim {}\n",
            k_pref.as_str(),
            last.get(k_pref),
            k_avoid.as_str(),
            last.get(k_avoid),
            if last.get(k_pref) > last.get(k_avoid) {
                "matches paper"
            } else {
                "DOES NOT match paper"
            }
        ));
    }

    println!("{}", super::layer_type_table(&snr));
    md.push_str("\n## Depth-averaged SNR per layer type\n\n```\n");
    md.push_str(&super::layer_type_table(&snr));
    md.push_str("```\n");
    write_summary_md(&dir, &md)?;
    Ok(())
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        -1.0
    }
}

/// (chart title, param name, paper-preferred K, paper-averse K)
fn selected_blocks(family: &str) -> Vec<(&'static str, String, KMode, KMode)> {
    match family {
        "gpt" | "llama" => vec![
            // Tok.Embd (vocab, d): embedding axis = fan_in; token axis = fan_out
            ("Token Embedding", "tok_embd".into(), KMode::FanIn, KMode::FanOut),
            ("Attention Key (L0)", "h0.attn_k".into(), KMode::FanIn, KMode::FanOut),
            ("Attention Value (L0)", "h0.attn_v".into(), KMode::FanOut, KMode::FanIn),
            ("Attn Projection (L1)", "h1.attn_proj".into(), KMode::FanOut, KMode::FanIn),
            ("MLP Up (L0)", "h0.mlp_up".into(), KMode::FanOut, KMode::FanIn),
            ("MLP Down (L1)", "h1.mlp_down".into(), KMode::FanOut, KMode::FanIn),
        ],
        "vit" => vec![
            ("Patch Embedding", "patch_embd".into(), KMode::FanIn, KMode::FanOut),
            ("Attention Key (L0)", "h0.attn_k".into(), KMode::FanIn, KMode::FanOut),
            ("MLP Down (L1)", "h1.mlp_down".into(), KMode::FanOut, KMode::FanIn),
            ("Head", "head".into(), KMode::FanIn, KMode::FanOut),
        ],
        _ => vec![("Head", "head".into(), KMode::FanIn, KMode::FanOut)],
    }
}
