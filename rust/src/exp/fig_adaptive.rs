//! `exp fig_adaptive` — self-tuning SlimAdam vs the static endpoints
//! (DESIGN.md §18; ROADMAP "Next directions" §4).
//!
//! Trains the same model three ways at one learning rate: fused full-V
//! Adam, fused static SlimAdam, and the adaptive controller switching
//! per-tensor between the two mid-run. Produces the memory-over-time
//! trace (second-moment elements after every fired controller eval)
//! against each run's final loss: the adaptive line should start at the
//! SlimAdam floor, possibly excursion toward Adam where SNR sags, and
//! land within noise of static Adam's loss while holding most of the
//! compression.
//!
//! Native-only (the controller migrates fused V state in place, which
//! PJRT's fixed-shape executables cannot express).
//!
//! Outputs under `results/fig_adaptive/`:
//! * `rows.csv` — one row per run: final loss, V elements, saved fraction
//! * `timeline.csv` — adaptive memory-over-time (step, v_elems, saved)
//! * `decisions.jsonl` — the controller's full decision log
//! * `summary.md` — the comparison table for EXPERIMENTS.md

use anyhow::{ensure, Result};

use crate::cli::Args;
use crate::coordinator::{run_config, EngineKind, TrainConfig};
use crate::metrics::{results_dir, CsvWriter, JsonlWriter};
use crate::rules::adaptive::AdaptivePolicy;
use crate::runtime::backend::{BackendKind, BackendSpec};

pub fn run(args: &Args) -> Result<()> {
    let backend = BackendSpec::parse(args.str_or("backend", "native"))?;
    ensure!(
        backend.kind == BackendKind::Native,
        "fig_adaptive is native-only (adaptive V migration; DESIGN.md §18)"
    );
    let model = args.str_or("model", "gpt_micro").to_string();
    let lr = args.f64_or("lr", 1e-3)?;
    let steps = super::steps_or(args, 300);
    let policy = AdaptivePolicy::parse(args.str_or("adaptive", ""))?;
    let dir = results_dir("fig_adaptive")?;

    let mk = |engine: &str, adaptive: Option<AdaptivePolicy>| {
        let mut cfg = TrainConfig::auto(&model, "adam", lr, steps);
        cfg.backend = backend;
        cfg.engine = EngineKind::Fused(engine.to_string());
        cfg.adaptive = adaptive;
        cfg
    };

    println!(
        "fig_adaptive: {model} @ lr {lr:.0e}, {steps} steps, policy {}",
        policy.spec()
    );
    let adam = run_config(&mk("adam", None))?;
    let slim = run_config(&mk("slimadam", None))?;
    let adaptive = run_config(&mk("slimadam", Some(policy)))?;
    let report = adaptive
        .adaptive
        .clone()
        .ok_or_else(|| anyhow::anyhow!("adaptive run produced no report"))?;

    let full = report.full_v_elems as f64;
    let v_of = |s: &crate::coordinator::RunSummary| {
        s.memory.as_ref().map(|m| m.v_elems).unwrap_or(0)
    };

    let mut rows = CsvWriter::create(
        dir.join("rows.csv"),
        &["run", "final_train_loss", "diverged", "v_elems", "saved_frac"],
    )?;
    let mut md = String::from("# fig_adaptive — self-tuning SlimAdam\n\n");
    md.push_str(&format!(
        "{model} @ lr {lr:.0e}, {steps} steps; policy `{}` \
         ({} evals, {} switches)\n\n",
        policy.spec(),
        report.evals,
        report.decisions.len()
    ));
    md.push_str("| run | final loss | V elems | saved |\n|---|---|---|---|\n");
    for (name, s, v) in [
        ("adam", &adam, v_of(&adam)),
        ("slimadam", &slim, v_of(&slim)),
        ("adaptive", &adaptive, report.final_v_elems),
    ] {
        let saved = 1.0 - v as f64 / full.max(1.0);
        rows.row(&[
            name.to_string(),
            format!("{:.5}", s.result.final_train_loss),
            s.result.diverged.to_string(),
            v.to_string(),
            format!("{saved:.4}"),
        ])?;
        md.push_str(&format!(
            "| {name} | {} | {v} | {:.0}% |\n",
            if s.result.diverged {
                "div".to_string()
            } else {
                format!("{:.4}", s.result.final_train_loss)
            },
            100.0 * saved
        ));
    }
    md.push_str(&format!(
        "\nfinal compressed element fraction: {:.0}%\n",
        100.0 * report.compressed_frac
    ));

    let mut tl = CsvWriter::create(
        dir.join("timeline.csv"),
        &["step", "v_elems", "saved_frac"],
    )?;
    for &(step, v) in &report.timeline {
        tl.row(&[
            step.to_string(),
            v.to_string(),
            format!("{:.4}", 1.0 - v as f64 / full.max(1.0)),
        ])?;
    }

    let mut log = JsonlWriter::create(dir.join("decisions.jsonl"))?;
    for d in &report.decisions {
        log.write(&d.to_json())?;
    }

    super::save_summaries("fig_adaptive", &[&adam, &slim, &adaptive])?;
    println!("{md}");
    super::write_summary_md(&dir, &md)?;
    Ok(())
}
