//! Figure 7 / Figure 29 (§4.1): heavy-tailed token distributions make the
//! token dimension incompressible. Two-layer linear model (Tok.Embd + LM
//! Head) on the BPE'd repo corpus at varying vocabulary sizes:
//!
//! * left — token-dimension SNR of both layers drops as vocab grows;
//! * right — loss gap ΔL vs Adam for shared second moments along
//!   (K_embd, K_head): token-dim compression hurts at large vocab,
//!   embedding-dim compression stays free.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{DataSpec, TrainConfig};
use crate::metrics::{results_dir, CsvWriter};
use crate::rules::RuleSet;
use crate::runtime::KMode;

use super::{probed_run, steps_or, sweep_scheduler, write_summary_md};

/// In our (vocab, d) storage: token axis = fan_out (axis 0); embedding
/// axis = fan_in (axis 1). "Compress along the token dimension" means
/// averaging over it -> K = FanOut.
const K_TOKEN: KMode = KMode::FanOut;
const K_EMBD: KMode = KMode::FanIn;

pub fn run(args: &Args) -> Result<()> {
    let vocabs: Vec<usize> = args
        .str_list("vocabs", &["64", "256", "1024", "4096"])
        .iter()
        .map(|s| s.parse().unwrap_or(64))
        .collect();
    let steps = steps_or(args, 80);
    let lr = args.f64_or("lr", 1e-3)?;
    let dir = results_dir("fig7")?;

    // ---- left: token-dim SNR vs vocab -------------------------------
    let mut w = CsvWriter::create(
        dir.join("snr_vs_vocab.csv"),
        &["vocab", "layer", "snr_token_dim", "snr_embd_dim"],
    )?;
    let mut md = String::from(
        "# Fig. 7 / Fig. 29 — vocabulary size vs token-dim compressibility\n\n\
         | vocab | layer | SNR(token dim) | SNR(embd dim) |\n|---|---|---|---|\n",
    );
    let mut token_snrs = Vec::new();
    for &v in &vocabs {
        let model = format!("linear2_v{v}");
        let mut cfg = TrainConfig::lm(&model, "adam", lr, steps);
        super::apply_common(args, &mut cfg)?;
        cfg.data = DataSpec::Corpus;
        cfg.hypers.beta2 = 0.999; // paper App. B.2
        cfg.hypers.weight_decay = 1e-4;
        println!("fig7: probing {model} on repo corpus");
        let (_, snr) = probed_run(cfg)?;
        for (avg, info) in snr.per_param.iter().zip(&snr.metas) {
            let tok = avg.get(K_TOKEN);
            let emb = avg.get(K_EMBD);
            w.row(&[
                v.to_string(),
                info.name.clone(),
                format!("{tok:.4}"),
                format!("{emb:.4}"),
            ])?;
            md.push_str(&format!(
                "| {v} | {} | {tok:.3} | {emb:.3} |\n",
                info.name
            ));
            if info.name == "lm_head" {
                token_snrs.push((v, tok));
            }
        }
    }

    // paper check: token-dim SNR decreases with vocab
    let decreasing = token_snrs.windows(2).filter(|w| w[1].1 <= w[0].1).count();
    md.push_str(&format!(
        "\nLM-head token-dim SNR decreasing across vocab steps: {}/{} \
         (paper: monotone decline)\n",
        decreasing,
        token_snrs.len().saturating_sub(1)
    ));

    // ---- right: ΔL_Adam heatmap over (K_embd, K_head) ----------------
    println!("fig7: ΔL grid over (K_embd, K_head)");
    let combos: Vec<(&str, KMode, KMode)> = vec![
        ("adam", KMode::None, KMode::None),
        ("embd_dim", K_EMBD, K_EMBD),
        ("token_dim", K_TOKEN, K_TOKEN),
        ("both_dims", KMode::Both, KMode::Both),
    ];
    let mut configs = Vec::new();
    for &v in &vocabs {
        let model = format!("linear2_v{v}");
        for (_, ke, kh) in &combos {
            let mut cfg = TrainConfig::lm(&model, "adam", lr, steps);
            super::apply_common(args, &mut cfg)?;
            cfg.data = DataSpec::Corpus;
            cfg.hypers.beta2 = 0.999;
            cfg.hypers.weight_decay = 1e-4;
            let mut rules = std::collections::BTreeMap::new();
            rules.insert("tok_embd".to_string(), *ke);
            rules.insert("lm_head".to_string(), *kh);
            cfg.ruleset = Some(RuleSet {
                label: format!("v{v}"),
                cutoff: 1.0,
                derived_at_lr: None,
                rules,
            });
            configs.push(cfg);
        }
    }
    let (scheduler, _workers) = sweep_scheduler(args, "fig7", configs.len())?;
    let sums = scheduler.run(&configs)?;

    let mut w2 = CsvWriter::create(
        dir.join("loss_gap.csv"),
        &["vocab", "k_embd_k_head", "eval_loss", "delta_vs_adam"],
    )?;
    md.push_str("\n## ΔL vs Adam (eval loss gap)\n\n| vocab |");
    for (name, _, _) in &combos {
        md.push_str(&format!(" {name} |"));
    }
    md.push_str("\n|---|");
    for _ in &combos {
        md.push_str("---|");
    }
    md.push('\n');
    for (vi, &v) in vocabs.iter().enumerate() {
        let base = sums[vi * combos.len()].result.eval_loss;
        md.push_str(&format!("| {v} |"));
        for (ci, (name, _, _)) in combos.iter().enumerate() {
            let s = &sums[vi * combos.len() + ci];
            let delta = s.result.eval_loss - base;
            w2.row(&[
                v.to_string(),
                name.to_string(),
                format!("{:.5}", s.result.eval_loss),
                format!("{delta:.5}"),
            ])?;
            md.push_str(&format!(" {delta:+.4} |"));
        }
        md.push('\n');
    }
    md.push_str(
        "\n(paper: token-dim column grows with vocab; embd-dim column stays ≈ 0)\n",
    );
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
