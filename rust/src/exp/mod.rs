//! Paper reproduction experiments — one module per figure/table of
//! "When Can You Get Away with Low Memory Adam?". See DESIGN.md §4 for
//! the experiment index (paper artifact → module → command).
//!
//! Every experiment writes machine-readable rows under `results/<id>/`
//! and prints the paper-comparable series (ASCII charts for quick visual
//! comparison with the paper's plots). Scales are reduced per DESIGN.md
//! §3; the *shape* of each result (who wins, preferred compression
//! dimensions, crossovers) is the reproduction target, not absolute
//! values.

pub mod fig01_lr_sensitivity;
pub mod fig02_snr_trajectories;
pub mod fig03_snr_depth;
pub mod fig04_finetune_snr;
pub mod fig05_resnet_snr;
pub mod fig06_vit_snr;
pub mod fig07_vocab_sweep;
pub mod fig08_lr_vs_snr;
pub mod fig09_init;
pub mod fig10_savings;
pub mod fig11_stability;
pub mod fig12_baseline_ablations;
pub mod fig27_ft_loss;
pub mod fig30_mean_rules;
pub mod fig_adaptive;
pub mod tables;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coordinator::{run_config, RunSummary, SweepScheduler, TrainConfig};
use crate::json::Value;
use crate::metrics::{results_dir, JsonlWriter};
use crate::runtime::backend::{BackendKind, BackendSpec};
use crate::runtime::Manifest;
use crate::snr::{ProbeSchedule, SnrSummary};

/// Dispatch an experiment id to its module.
pub fn run(id: &str, args: &Args) -> Result<()> {
    // zero-padded spellings (fig03, fig05, …) are accepted as aliases
    match id {
        "fig1" | "fig01" => fig01_lr_sensitivity::run(args),
        "fig2" | "fig02" => fig02_snr_trajectories::run(args),
        "fig3" | "fig03" => fig03_snr_depth::run(args),
        "fig4" | "fig04" | "fig18" => fig04_finetune_snr::run(args),
        "fig5" | "fig05" | "fig19" | "fig20" => fig05_resnet_snr::run(args),
        "fig6" | "fig06" | "fig21" | "fig22" | "fig23" => fig06_vit_snr::run(args),
        "fig7" | "fig07" | "fig29" => fig07_vocab_sweep::run(args),
        "fig8" | "fig08" | "fig24" => fig08_lr_vs_snr::run(args),
        "fig9" | "fig09" | "fig25" => fig09_init::run(args),
        "fig10" | "fig26" => fig10_savings::run(args),
        "fig11" => fig11_stability::run(args),
        "fig12" => fig12_baseline_ablations::run(args),
        "fig27" | "fig28" => fig27_ft_loss::run(args),
        "fig30" => fig30_mean_rules::run(args),
        "fig_adaptive" | "adaptive" => fig_adaptive::run(args),
        "table1" => tables::table1(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "appc1" => {
            fig02_snr_trajectories::run(args)?;
            fig03_snr_depth::run(args)
        }
        "appc3" => {
            fig05_resnet_snr::run(args)?;
            fig06_vit_snr::run(args)
        }
        "all" => run_all(args),
        other => bail!(
            "unknown experiment {other:?} — see `slimadam exp --help` / DESIGN.md §4"
        ),
    }
}

/// Run the full reproduction suite in dependency-friendly order.
pub fn run_all(args: &Args) -> Result<()> {
    for id in [
        "fig2", "fig3", "fig5", "fig6", "fig4", "fig7", "fig8", "fig9",
        "fig1", "fig10", "fig11", "fig12", "fig27", "fig30", "table1",
        "table2", "table3",
    ] {
        println!("\n================ exp {id} ================");
        run(id, args)?;
    }
    Ok(())
}

pub const IDS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
    "fig10", "fig11", "fig12", "fig27", "fig30", "fig_adaptive", "table1",
    "table2", "table3", "appc1", "appc3", "all",
];

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

/// Default probe cadence for experiment runs.
pub fn probe() -> ProbeSchedule {
    ProbeSchedule::default()
}

/// The execution backend an experiment was asked to run on
/// (`--backend pjrt|native[+f32][@device]`, default pjrt). Every
/// figure/table driver threads this into its configs so the whole
/// reproduction suite can run offline on the native interpreter.
///
/// `--precision f32|f64` overrides the spec's compute precision
/// (equivalent to the `+f32` spec suffix; DESIGN.md §14). The resulting
/// spec — precision included — is what lands in config/cache/store keys,
/// so f32 rows never collide with the f64 reference.
pub fn backend_spec(args: &Args) -> Result<BackendSpec> {
    let mut spec = BackendSpec::parse(args.str_or("backend", "pjrt"))?;
    if let Some(p) = args.get("precision") {
        spec.precision = crate::runtime::backend::Precision::parse(p)?;
    }
    Ok(spec)
}

/// Apply the shared cross-driver options (`--backend`) to a base config.
pub fn apply_common(args: &Args, cfg: &mut TrainConfig) -> Result<()> {
    cfg.backend = backend_spec(args)?;
    Ok(())
}

/// Steps default honoring `--steps` (quick CI runs use small values).
pub fn steps_or(args: &Args, default: usize) -> usize {
    args.usize_or("steps", default).unwrap_or(default)
}

pub fn workers_or_default(args: &Args, jobs: usize) -> usize {
    args.usize_or("workers", 0)
        .ok()
        .filter(|&w| w > 0)
        .unwrap_or_else(|| crate::pool::default_workers(jobs))
}

/// Streaming sweep scheduler for an experiment grid: honors `--workers`
/// and appends one JSONL row per job to `results/<id>/stream.jsonl` as
/// jobs finish (partial sweeps keep every completed row). With
/// `--resume <dir>` (conventionally the experiment's own `results/<id>`)
/// the scheduler opens that run store first and skips every grid point
/// already completed there — a killed figure reproduction restarts where
/// it died (DESIGN.md §10). Returns the scheduler plus the resolved
/// worker count for banner lines.
pub fn sweep_scheduler(
    args: &Args,
    id: &str,
    jobs: usize,
) -> Result<(SweepScheduler, usize)> {
    let workers = workers_or_default(args, jobs);
    let meta = crate::runstore::StoreMeta {
        schema_version: crate::runstore::SCHEMA_VERSION,
        base_seed: 0,
        backend: backend_spec(args)?.key(),
    };
    let scheduler = match args.get("resume") {
        Some(dir) => {
            let store = crate::runstore::RunStore::open_with(dir, &meta)?;
            SweepScheduler::new(workers)
                .resume_from(&store)?
                .stream_to(store.primary())
        }
        None => {
            let store = crate::runstore::RunStore::open_with(results_dir(id)?, &meta)?;
            SweepScheduler::new(workers).stream_to(store.primary())
        }
    };
    Ok((scheduler, workers))
}

/// Run one probe-enabled config and return (summary, snr).
pub fn probed_run(mut cfg: TrainConfig) -> Result<(RunSummary, SnrSummary)> {
    cfg.probe = Some(probe());
    let s = run_config(&cfg)?;
    let snr = s
        .snr
        .clone()
        .ok_or_else(|| anyhow::anyhow!("probe produced no SNR"))?;
    Ok((s, snr))
}

/// Write a SNR summary as JSONL rows (one per parameter).
pub fn write_snr(dir: &std::path::Path, name: &str, snr: &SnrSummary) -> Result<()> {
    let mut w = JsonlWriter::create(dir.join(name))?;
    if let Value::Arr(rows) = snr.to_json() {
        for r in &rows {
            w.write(r)?;
        }
    }
    Ok(())
}

/// Pretty per-layer-type SNR table (depth-averaged), printed and returned.
pub fn layer_type_table(snr: &SnrSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:14} {:>10} {:>10} {:>10}  preferred\n",
        "layer_type", "K=fan_out", "K=fan_in", "K=both"
    ));
    for (lt, avg) in snr.by_layer_type() {
        let (k, best) = avg.best();
        out.push_str(&format!(
            "{:14} {:>10.3} {:>10.3} {:>10.3}  {} ({})\n",
            lt,
            avg.fan_out,
            avg.fan_in,
            avg.both,
            k.as_str(),
            if best >= 1.0 { "compressible" } else { "averse" },
        ));
    }
    out
}

/// Load a model manifest from the artifacts dir (for rule accounting).
pub fn manifest(model: &str) -> Result<Manifest> {
    Manifest::load(format!("artifacts/{model}.grad.manifest.json"))
}

/// Backend-aware manifest lookup: native models generate their builtin
/// manifest; PJRT models read `make artifacts` output.
pub fn manifest_for(spec: &BackendSpec, model: &str) -> Result<Manifest> {
    match spec.kind {
        BackendKind::Native => crate::runtime::backend::native::grad_manifest(model),
        BackendKind::Pjrt => manifest(model),
    }
}

/// Save summaries to `results/<id>/summaries.jsonl` + return the dir.
pub fn save_summaries(id: &str, sums: &[&RunSummary]) -> Result<std::path::PathBuf> {
    let dir = results_dir(id)?;
    let mut w = JsonlWriter::create(dir.join("summaries.jsonl"))?;
    for s in sums {
        w.write(&s.to_json())?;
    }
    Ok(dir)
}

/// Write a markdown summary file for EXPERIMENTS.md consumption.
pub fn write_summary_md(dir: &std::path::Path, text: &str) -> Result<()> {
    std::fs::write(dir.join("summary.md"), text)?;
    Ok(())
}
