//! Figure 10 / Figure 26 (§5): the headline SlimAdam result.
//!
//! Top: fraction of second moments reducible as a function of learning
//! rate and SNR cutoff, per training regime — GPT/ViT compress ~98% at
//! small LR shrinking to ~35% at large LR; ResNets stay compressible
//! everywhere; fine-tuning compresses least.
//!
//! Bottom: loss-vs-LR comparison between Adam, SlimAdam (rules derived at
//! a LR ~10x below optimal — the paper's implicit-bias finding), AdaLayer,
//! AdaLayer+LN+TL, and Adam-mini v1/v2. SlimAdam should trace Adam's
//! curve while the others destabilize at large LR.

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{run_config, run_grid, TrainConfig};
use crate::metrics::{results_dir, CsvWriter};
use crate::rules::RuleSet;
use crate::runtime::backend::BackendKind;

use super::{probe, steps_or, workers_or_default, write_summary_md};

struct Regime {
    id: &'static str,
    model: &'static str,
    base: fn(&str, &str, f64, usize) -> TrainConfig,
    lrs: &'static [f64],
    /// LR at which SlimAdam rules are derived (≈ optimal / 10)
    rule_lr: f64,
    finetune: bool,
}

const REGIMES: &[Regime] = &[
    Regime {
        id: "gpt",
        model: "gpt_nano",
        base: TrainConfig::lm,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3, 1e-2],
        rule_lr: 3e-4,
        finetune: false,
    },
    Regime {
        id: "resnet",
        model: "resnet_mini_c10",
        base: TrainConfig::vision,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3, 1e-2],
        rule_lr: 3e-4,
        finetune: false,
    },
    Regime {
        id: "vit",
        model: "vit_mini_c10",
        base: TrainConfig::vision,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3],
        rule_lr: 3e-4,
        finetune: false,
    },
    Regime {
        id: "finetune",
        model: "llama_tiny",
        base: TrainConfig::finetune,
        lrs: &[1e-5, 3e-5, 1e-4, 3e-4],
        rule_lr: 1e-5,
        finetune: true,
    },
];

/// `--backend native` swaps the regime table for the builtin zoo
/// (DESIGN.md §13): the same top/bottom panels are produced end to end
/// offline — no artifacts — over the native GPT, deep-transformer and
/// conv families. The fine-tuning regime needs a pre-trained PJRT
/// checkpoint and stays on the artifact path.
const NATIVE_REGIMES: &[Regime] = &[
    Regime {
        id: "gpt",
        model: "gpt_micro",
        base: TrainConfig::lm,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3, 1e-2],
        rule_lr: 3e-4,
        finetune: false,
    },
    Regime {
        id: "deep",
        model: "gpt_deep",
        base: TrainConfig::lm,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3],
        rule_lr: 3e-4,
        finetune: false,
    },
    Regime {
        id: "conv",
        model: "conv_mini",
        base: TrainConfig::vision,
        lrs: &[1e-4, 3e-4, 1e-3, 3e-3],
        rule_lr: 3e-4,
        finetune: false,
    },
];

const CUTOFFS: &[f64] = &[0.6, 0.8, 1.0, 1.5, 2.0];

const BOTTOM_OPTS: &[&str] = &[
    "adam",
    "slimadam", // replaced by derived rules below
    "adalayer",
    "adalayer_ln_tl",
    "adam_mini_v1",
    "adam_mini_v2",
];

pub fn run(args: &Args) -> Result<()> {
    let steps = steps_or(args, 100);
    let dir = results_dir("fig10")?;
    let only: Option<String> = args.get("regime").map(|s| s.to_string());
    let all = args.flag("all");
    let backend = super::backend_spec(args)?;
    let regimes: &[Regime] = if backend.kind == BackendKind::Native {
        NATIVE_REGIMES
    } else {
        REGIMES
    };

    let mut top = CsvWriter::create(
        dir.join("savings_grid.csv"),
        &["regime", "lr", "cutoff", "fraction_saved", "diverged"],
    )?;
    let mut md = String::from("# Fig. 10 — SNR-predicted savings & SlimAdam performance\n\n");

    for regime in regimes {
        if let Some(o) = &only {
            if o != regime.id {
                continue;
            }
        }
        if regime.finetune && !all && only.is_none() {
            // fine-tuning regime needs a pre-trained checkpoint; included
            // with --all or --regime finetune
            continue;
        }
        println!("== fig10 regime {} ({}) ==", regime.id, regime.model);
        let man = super::manifest_for(&backend, regime.model)?;
        let warm = if regime.finetune {
            Some(Arc::new(super::fig04_finetune_snr::pretrained_params(
                &backend,
                regime.model,
                200,
                false,
            )?))
        } else {
            None
        };

        // ---- top panel: probe at every LR, derive at every cutoff ----
        let mut rules_at_rule_lr: Option<RuleSet> = None;
        md.push_str(&format!(
            "## {} — fraction of second moments saved\n\n| lr \\ cutoff |",
            regime.id
        ));
        for c in CUTOFFS {
            md.push_str(&format!(" {c} |"));
        }
        md.push_str("\n|---|");
        for _ in CUTOFFS {
            md.push_str("---|");
        }
        md.push('\n');

        for &lr in regime.lrs {
            let mut cfg = (regime.base)(regime.model, "adam", lr, steps);
            cfg.backend = backend;
            cfg.probe = Some(probe());
            cfg.warm_start = warm.clone();
            let s = run_config(&cfg)?;
            let snr = s.snr.unwrap();
            md.push_str(&format!("| {lr:.0e} |"));
            for &cutoff in CUTOFFS {
                let rs = RuleSet::derive(&snr, cutoff, format!("{}@{lr:e}", regime.id), Some(lr));
                let saving = if s.result.diverged {
                    f64::NAN
                } else {
                    rs.saving(&man)
                };
                top.row(&[
                    regime.id.into(),
                    format!("{lr:e}"),
                    cutoff.to_string(),
                    format!("{saving:.4}"),
                    s.result.diverged.to_string(),
                ])?;
                md.push_str(&format!(
                    " {} |",
                    if saving.is_finite() {
                        format!("{:.0}%", 100.0 * saving)
                    } else {
                        "div".into()
                    }
                ));
                if (lr - regime.rule_lr).abs() < 1e-12 && (cutoff - 1.0).abs() < 1e-9 {
                    rules_at_rule_lr = Some(rs);
                }
            }
            md.push('\n');
        }
        md.push('\n');

        // ---- bottom panel: optimizer comparison across LRs ----
        let rules = rules_at_rule_lr
            .unwrap_or_else(|| RuleSet::table3_default(&man));
        rules.save(dir.join(format!("{}.rules.json", regime.id)))?;
        println!(
            "  SlimAdam rules from lr {:.0e}: {} compressed tensors, {:.1}% saved",
            regime.rule_lr,
            rules.rules.len(),
            100.0 * rules.saving(&man)
        );

        let mut configs = Vec::new();
        for opt in BOTTOM_OPTS {
            for &lr in regime.lrs {
                let mut cfg = (regime.base)(regime.model, opt, lr, steps);
                cfg.backend = backend;
                cfg.warm_start = warm.clone();
                if *opt == "slimadam" {
                    cfg.ruleset = Some(rules.clone());
                }
                configs.push(cfg);
            }
        }
        let workers = workers_or_default(args, configs.len());
        let sums = run_grid(&configs, workers)?;

        let mut bot = CsvWriter::create(
            dir.join(format!("{}.performance.csv", regime.id)),
            &["optimizer", "lr", "eval_loss", "train_loss", "diverged", "v_saving"],
        )?;
        md.push_str(&format!(
            "## {} — loss vs LR (rules @ {:.0e})\n\n| optimizer |",
            regime.id, regime.rule_lr
        ));
        for &lr in regime.lrs {
            md.push_str(&format!(" {lr:.0e} |"));
        }
        md.push_str(" saved |\n|---|");
        for _ in regime.lrs {
            md.push_str("---|");
        }
        md.push_str("---|\n");
        for (oi, opt) in BOTTOM_OPTS.iter().enumerate() {
            md.push_str(&format!("| {opt} |"));
            let mut saving = f64::NAN;
            for (li, &lr) in regime.lrs.iter().enumerate() {
                let s = &sums[oi * regime.lrs.len() + li];
                let metric = crate::sweep::LrSweep::metric(s);
                bot.row(&[
                    opt.to_string(),
                    format!("{lr:e}"),
                    if s.result.eval_loss.is_finite() {
                        format!("{:.5}", s.result.eval_loss)
                    } else {
                        "inf".into()
                    },
                    format!("{:.5}", s.result.final_train_loss),
                    s.result.diverged.to_string(),
                    s.memory
                        .as_ref()
                        .map(|m| format!("{:.4}", m.v_saving))
                        .unwrap_or_default(),
                ])?;
                md.push_str(&format!(
                    " {} |",
                    if metric.is_finite() {
                        format!("{metric:.3}")
                    } else {
                        "div".into()
                    }
                ));
                if let Some(m) = &s.memory {
                    saving = m.v_saving;
                }
            }
            md.push_str(&format!(" {:.0}% |\n", 100.0 * saving));
        }
        md.push('\n');
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
