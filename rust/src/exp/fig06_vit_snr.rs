//! Figure 6 (+ App. C.3 Figs. 21-23): ViT image-classification SNR.
//! Paper shapes: GPT-like attention trends (K/Q prefer fan_in, V/proj
//! fan_out) at *higher* absolute SNR; MLP.Up flips to fan_in (unlike GPT);
//! patch embedding prefers fan_in; LayerNorms are surprisingly
//! compressible.

//! Offline: `--backend native` probes the builtin `gpt_deep` transformer
//! instead — no patch embedding exists natively, so the ViT-specific
//! check is marked n/a, but the attention-trend checks (K/Q fan_in,
//! V fan_out) still run on real multi-block attention SNR.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::results_dir;
use crate::runtime::backend::BackendKind;
use crate::runtime::KMode;

use super::{probed_run, steps_or, write_snr, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 1e-3)?;
    let dir = results_dir("fig6")?;
    let native = super::backend_spec(args)?.kind == BackendKind::Native;
    let mut md = String::from("# Fig. 6 / Figs. 21-23 — ViT SNR\n\n");
    if native {
        md.push_str(
            "*Native offline run: builtin `gpt_deep` (4-block causal \
             transformer) stands in for the ViT artifacts — attention \
             trends are real, patch-embedding checks are n/a.*\n\n",
        );
    }

    let runs: Vec<(String, String)> = if native {
        vec![("gpt_deep".into(), "snr_gpt_deep.jsonl".into())]
    } else {
        vec![
            ("vit_mini_c10".into(), "snr_c10.jsonl".into()),
            ("vit_mini_c100".into(), "snr_c100.jsonl".into()),
        ]
    };
    for (model, snr_file) in runs {
        println!("fig6: probing {model} ({steps} steps)");
        let mut cfg = TrainConfig::auto(&model, "adam", lr, steps);
        super::apply_common(args, &mut cfg)?;
        let (_, snr) = probed_run(cfg)?;
        write_snr(&dir, &snr_file, &snr)?;
        let table = super::layer_type_table(&snr);
        println!("{table}");

        let types = snr.by_layer_type();
        let pref = |lt: &str, k: KMode| -> bool {
            types.get(lt).map(|a| a.best().0 == k).unwrap_or(false)
        };
        let patch_check = if native {
            ("patch_embd prefers fan_in (n/a on native stand-in)", true)
        } else {
            (
                "patch_embd prefers fan_in",
                types
                    .get("patch_embd")
                    .map(|a| a.fan_in > a.fan_out)
                    .unwrap_or(false),
            )
        };
        let checks = [
            ("K prefers fan_in", pref("attn_k", KMode::FanIn)),
            ("Q prefers fan_in", pref("attn_q", KMode::FanIn)),
            (
                "V prefers fan_out",
                types
                    .get("attn_v")
                    .map(|a| a.fan_out > a.fan_in)
                    .unwrap_or(false),
            ),
            patch_check,
        ];
        md.push_str(&format!("## {model}\n"));
        for (name, ok) in checks {
            md.push_str(&format!(
                "- {name}: {}\n",
                if ok { "yes (matches paper)" } else { "no" }
            ));
        }
        md.push_str(&format!("\n```\n{table}```\n\n"));
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
