//! Figure 6 (+ App. C.3 Figs. 21-23): ViT image-classification SNR.
//! Paper shapes: GPT-like attention trends (K/Q prefer fan_in, V/proj
//! fan_out) at *higher* absolute SNR; MLP.Up flips to fan_in (unlike GPT);
//! patch embedding prefers fan_in; LayerNorms are surprisingly
//! compressible.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::results_dir;
use crate::runtime::KMode;

use super::{probed_run, steps_or, write_snr, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 1e-3)?;
    let dir = results_dir("fig6")?;
    let mut md = String::from("# Fig. 6 / Figs. 21-23 — ViT SNR\n\n");

    for classes in [10usize, 100] {
        let model = format!("vit_mini_c{classes}");
        println!("fig6: probing {model} ({steps} steps)");
        let mut cfg = TrainConfig::vision(&model, "adam", lr, steps);
        super::apply_common(args, &mut cfg)?;
        let (_, snr) = probed_run(cfg)?;
        write_snr(&dir, &format!("snr_c{classes}.jsonl"), &snr)?;
        let table = super::layer_type_table(&snr);
        println!("{table}");

        let types = snr.by_layer_type();
        let pref = |lt: &str, k: KMode| -> bool {
            types.get(lt).map(|a| a.best().0 == k).unwrap_or(false)
        };
        let checks = [
            ("K prefers fan_in", pref("attn_k", KMode::FanIn)),
            ("Q prefers fan_in", pref("attn_q", KMode::FanIn)),
            (
                "V prefers fan_out",
                types
                    .get("attn_v")
                    .map(|a| a.fan_out > a.fan_in)
                    .unwrap_or(false),
            ),
            (
                "patch_embd prefers fan_in",
                types
                    .get("patch_embd")
                    .map(|a| a.fan_in > a.fan_out)
                    .unwrap_or(false),
            ),
        ];
        md.push_str(&format!("## classes={classes}\n"));
        for (name, ok) in checks {
            md.push_str(&format!(
                "- {name}: {}\n",
                if ok { "yes (matches paper)" } else { "no" }
            ));
        }
        md.push_str(&format!("\n```\n{table}```\n\n"));
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
