//! Figure 12 (App. A): baseline hyperparameter ablations — SM3 beta in
//! {0, 0.95}, Lion, Adafactor v1 vs v2 — against Adam and SlimAdam on the
//! GPT pre-training task, extended into the low-memory bake-off: SGDM
//! and rank-4 factored-V Adam (`lowrank_v`) ride the same LR grid so the
//! summary pairs each optimizer's best loss with its state memory.
//! Paper: SM3 beta=0.95 > beta=0; both Adafactor variants lag Adam
//! significantly. `--backend native` runs the whole grid offline on the
//! builtin zoo (default model gpt_micro).

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::results_dir;
use crate::runtime::backend::BackendKind;
use crate::sweep::{log_grid, LrSweep};

use super::{steps_or, workers_or_default, write_summary_md};

const OPTS: &[&str] = &[
    "adam",
    "slimadam",
    "sm3",
    "sm3_b0",
    "lion",
    "adafactor",
    "adafactor_v2",
    "sgdm",
    "lowrank_v",
];

pub fn run(args: &Args) -> Result<()> {
    let backend = super::backend_spec(args)?;
    let default_model = if backend.kind == BackendKind::Native {
        "gpt_micro"
    } else {
        "gpt_nano"
    };
    let model = args.str_or("model", default_model).to_string();
    let steps = steps_or(args, 100);
    let lrs = args.f64_list("lrs", &log_grid(1e-4, 3e-2, 6))?;
    let dir = results_dir("fig12")?;

    let mut base = TrainConfig::lm(&model, "adam", 1e-3, steps);
    super::apply_common(args, &mut base)?;
    let workers = workers_or_default(args, OPTS.len() * lrs.len());
    println!("fig12: baseline ablations on {model}");
    let sweep = LrSweep::run(&base, OPTS, &lrs, workers)?;
    sweep.write_csv(dir.join("rows.csv"))?;

    let chart = sweep.chart("Fig. 12 — baseline ablations (loss vs LR)");
    println!("{chart}");

    let mut md = String::from(
        "# Fig. 12 — baseline hyperparameter ablations\n\n\
         | optimizer | best lr | best loss | state elems | state vs adamw |\n\
         |---|---|---|---|---|\n",
    );
    for (i, name) in sweep.optimizers.iter().enumerate() {
        let (lr, loss) = sweep.best(i);
        let (state, saved) = sweep.summaries[i]
            .iter()
            .find_map(|s| s.memory.as_ref())
            .map(|m| (m.state_elems.to_string(), format!("-{:.0}%", 100.0 * m.state_saving)))
            .unwrap_or_default();
        md.push_str(&format!("| {name} | {lr:.1e} | {loss:.4} | {state} | {saved} |\n"));
    }
    let best = |name: &str| {
        sweep
            .optimizers
            .iter()
            .position(|o| o == name)
            .map(|i| sweep.best(i).1)
            .unwrap_or(f64::NAN)
    };
    md.push_str(&format!(
        "\n- SM3 beta=0.95 better than beta=0: {} (paper: yes)\n\
         - Adafactor variants worse than Adam: {} (paper: yes)\n",
        best("sm3") < best("sm3_b0"),
        best("adafactor").min(best("adafactor_v2")) > best("adam")
    ));
    md.push_str(&format!("\n```\n{chart}```\n"));
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
