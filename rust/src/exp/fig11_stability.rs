//! Figure 11: training stability at small vs large learning rates.
//! Paper result: at small LR every low-memory Adam variant tracks Adam;
//! at Adam's optimal (large) LR, SlimAdam stays glued to Adam's
//! trajectory while AdaLayer / Adam-mini destabilize — compressing the
//! *correct* dimensions preserves the preconditioner's local stability
//! threshold.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{run_grid, TrainConfig};
use crate::metrics::{ascii_chart, results_dir, JsonlWriter};

use super::{steps_or, workers_or_default, write_summary_md};

const OPTS: &[&str] = &["adam", "slimadam", "adalayer", "adam_mini_v2"];

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_mini").to_string();
    let steps = steps_or(args, 150);
    let small_lr = args.f64_or("small-lr", 3e-4)?;
    let large_lr = args.f64_or("large-lr", 3e-3)?;
    let dir = results_dir("fig11")?;

    let mut configs = Vec::new();
    for &lr in &[small_lr, large_lr] {
        for opt in OPTS {
            let mut cfg = TrainConfig::lm(&model, opt, lr, steps);
            super::apply_common(args, &mut cfg)?;
            cfg.eval_batches = 0;
            configs.push(cfg);
        }
    }
    println!(
        "fig11: {model} trajectories at lr {small_lr:.0e} and {large_lr:.0e} ({} runs)",
        configs.len()
    );
    let workers = workers_or_default(args, configs.len());
    let sums = run_grid(&configs, workers)?;

    let mut w = JsonlWriter::create(dir.join("trajectories.jsonl"))?;
    for s in &sums {
        for &(step, loss) in &s.result.losses {
            let mut v = crate::json::Value::obj();
            v.set("optimizer", s.optimizer.clone())
                .set("lr", s.lr)
                .set("step", step)
                .set("loss", loss as f64);
            w.write(&v)?;
        }
    }

    let mut md = String::from("# Fig. 11 — stability at small vs large LR\n\n");
    for (li, (&lr, label)) in [(&small_lr, "small"), (&large_lr, "large")]
        .iter()
        .enumerate()
    {
        // moving average of 10 like the paper
        let series: Vec<(String, Vec<(f64, f64)>)> = OPTS
            .iter()
            .enumerate()
            .map(|(oi, name)| {
                let s = &sums[li * OPTS.len() + oi];
                let pts: Vec<(f64, f64)> = moving_avg(&s.result.losses, 10);
                (name.to_string(), pts)
            })
            .collect();
        let refs: Vec<(&str, &[(f64, f64)])> = series
            .iter()
            .map(|(n, p)| (n.as_str(), p.as_slice()))
            .collect();
        let chart = ascii_chart(
            &format!("Fig. 11 ({label} lr = {lr:.0e}) — loss vs step"),
            &refs,
            64,
            14,
            false,
            false,
        );
        println!("{chart}");
        md.push_str(&format!("## {label} LR = {lr:.0e}\n\n| optimizer | final loss | max loss spike | diverged |\n|---|---|---|---|\n"));
        let adam_final = sums[li * OPTS.len()].result.final_train_loss;
        for (oi, name) in OPTS.iter().enumerate() {
            let s = &sums[li * OPTS.len() + oi];
            let max_spike = s
                .result
                .losses
                .iter()
                .skip(steps / 4)
                .map(|&(_, l)| l)
                .fold(f32::MIN, f32::max);
            md.push_str(&format!(
                "| {name} | {:.4} (Δadam {:+.4}) | {max_spike:.3} | {} |\n",
                s.result.final_train_loss,
                s.result.final_train_loss - adam_final,
                s.result.diverged
            ));
        }
        md.push_str(&format!("\n```\n{chart}```\n\n"));
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}

fn moving_avg(losses: &[(usize, f32)], window: usize) -> Vec<(f64, f64)> {
    losses
        .iter()
        .enumerate()
        .map(|(i, &(step, _))| {
            let lo = i.saturating_sub(window - 1);
            let avg = losses[lo..=i].iter().map(|&(_, l)| l as f64).sum::<f64>()
                / (i - lo + 1) as f64;
            (step as f64, avg)
        })
        .collect()
}
