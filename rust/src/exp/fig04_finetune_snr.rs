//! Figure 4 / Figure 18 (App. C.2): SNR during fine-tuning. The paper's
//! finding: fine-tuning a converged model on a shifted distribution shows
//! globally *lower* SNR than pre-training — keys/queries fall well below
//! 1.0, MLP.Down stays the most compressible matrix family.
//!
//! Protocol here (DESIGN.md §3): pre-train a Llama-style tiny model on
//! Markov distribution A, checkpoint, then fine-tune on shifted
//! distribution B at low LR with App. B.3 hypers — probing the
//! fine-tuning phase.

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::results_dir;
use crate::train::checkpoint;

use super::{probed_run, steps_or, write_snr, write_summary_md};

/// Pre-train `model` on the Markov base distribution and return its
/// parameters, caching the checkpoint under `results/fig4/`. Shared by the
/// fine-tuning experiments (fig4, fig10 --all, fig27).
pub fn pretrained_params(
    spec: &crate::runtime::backend::BackendSpec,
    model: &str,
    pre_steps: usize,
    force: bool,
) -> Result<Vec<crate::tensor::Tensor>> {
    let dir = results_dir("fig4")?;
    let ckpt = dir.join(format!("{model}.pretrained.npz"));
    let man = super::manifest_for(spec, model)?;
    if ckpt.exists() && !force {
        println!("fig4: reusing checkpoint {ckpt:?}");
        return checkpoint::load(&ckpt, &man.params);
    }
    println!("fig4: pre-training {model} for {pre_steps} steps");
    let mut pre = TrainConfig::lm(model, "adam", 1e-3, pre_steps);
    pre.backend = *spec;
    // run_config does not expose final parameters, so drive the split
    // engine directly and checkpoint the result.
    let engine = crate::coordinator::exec_cache::grad_engine(spec, "artifacts", model)?;
    let mut rng = crate::rng::Rng::new(7u64.wrapping_add(17));
    let mut p: Vec<crate::tensor::Tensor> = man
        .params
        .iter()
        .map(|pi| pi.init_mitchell.materialize(&pi.shape, &mut rng))
        .collect();
    let mut opt = crate::optim::presets::build("adam", &man, pre.hypers)?;
    let mut data = crate::coordinator::make_data(&man, &pre.data, 7)?;
    let schedule = crate::train::Schedule::new(pre.lr, pre.warmup, pre.steps);
    let res = crate::train::train_split(
        &engine,
        opt.as_mut(),
        &mut p,
        data.as_mut(),
        &schedule,
        pre.steps,
        None,
        1,
        0,
    )?;
    anyhow::ensure!(!res.diverged, "pre-training diverged");
    println!(
        "  pre-train loss {:.4} -> {:.4}",
        res.losses[0].1, res.final_train_loss
    );
    checkpoint::save(&ckpt, &man.params, &p)?;
    Ok(p)
}

pub fn run(args: &Args) -> Result<()> {
    let backend = super::backend_spec(args)?;
    let model = args.str_or("model", "llama_tiny").to_string();
    let pre_steps = steps_or(args, 200);
    let ft_steps = args.usize_or("ft-steps", 120)?;
    let dir = results_dir("fig4")?;

    // Phase 1: pre-train (cached)
    let params = pretrained_params(&backend, &model, pre_steps, args.flag("repretrain"))?;

    // Phase 2: fine-tune on shifted distribution with probe
    println!("fig4: fine-tuning on shifted distribution ({ft_steps} steps)");
    let mut ft = TrainConfig::finetune(&model, "adam", 1e-4, ft_steps);
    ft.backend = backend;
    ft.warm_start = Some(Arc::new(params));
    ft.seed = 8;
    let (_, ft_snr) = probed_run(ft)?;

    // Reference: pre-training-phase SNR for the comparison table
    println!("fig4: probing pre-training SNR for comparison");
    let mut pre_probe = TrainConfig::lm(&model, "adam", 1e-3, ft_steps);
    pre_probe.backend = backend;
    pre_probe.seed = 7;
    let (_, pre_snr) = probed_run(pre_probe)?;

    write_snr(&dir, "snr_finetune.jsonl", &ft_snr)?;
    write_snr(&dir, "snr_pretrain.jsonl", &pre_snr)?;

    let ft_table = super::layer_type_table(&ft_snr);
    let pre_table = super::layer_type_table(&pre_snr);
    println!("--- fine-tuning SNR ---\n{ft_table}");
    println!("--- pre-training SNR ---\n{pre_table}");

    // Paper check: fine-tuning SNR lower overall; K/Q below 1.
    let ft_types = ft_snr.by_layer_type();
    let pre_types = pre_snr.by_layer_type();
    let mut lower = 0;
    let mut total = 0;
    for (lt, ft_avg) in &ft_types {
        if let Some(pre_avg) = pre_types.get(lt) {
            total += 1;
            if ft_avg.best().1 < pre_avg.best().1 {
                lower += 1;
            }
        }
    }
    let kq_below = ["attn_k", "attn_q"]
        .iter()
        .filter(|lt| ft_types.get(**lt).map(|a| a.best().1 < 1.0).unwrap_or(false))
        .count();
    let md = format!(
        "# Fig. 4 — fine-tuning SNR vs pre-training SNR\n\n\
         - layer types with lower SNR in fine-tuning: {lower}/{total} \
           (paper: fine-tuning is less compressible overall)\n\
         - K/Q layer types with best-SNR < 1.0 during fine-tuning: {kq_below}/2 \
           (paper: keys and queries fall well below 1.0)\n\n\
         ## fine-tuning\n```\n{ft_table}```\n\n## pre-training\n```\n{pre_table}```\n"
    );
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
