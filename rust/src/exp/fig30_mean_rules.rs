//! Figure 30 (App. H): SlimAdam-mean — compression rules derived from
//! depth-averaged SNR per layer type perform identically to per-layer
//! rules, which is what makes rules transferable across widths/datasets.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{run_grid, TrainConfig};
use crate::metrics::results_dir;
use crate::rules::RuleSet;

use super::{probed_run, steps_or, workers_or_default, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 100);
    let rule_lr = args.f64_or("rule-lr", 3e-4)?;
    let lrs = args.f64_list("lrs", &[3e-4, 1e-3, 3e-3, 1e-2])?;
    let dir = results_dir("fig30")?;

    println!("fig30: deriving per-layer and depth-averaged rules at lr {rule_lr:.0e}");
    let backend = super::backend_spec(args)?;
    let mut probe_cfg = TrainConfig::lm(&model, "adam", rule_lr, steps);
    probe_cfg.backend = backend;
    let (_, snr) = probed_run(probe_cfg)?;
    let per_layer = RuleSet::derive(&snr, 1.0, "per_layer", Some(rule_lr));
    let mean = RuleSet::derive_depth_averaged(&snr, 1.0, "depth_mean", Some(rule_lr));
    per_layer.save(dir.join("per_layer.rules.json"))?;
    mean.save(dir.join("depth_mean.rules.json"))?;

    let man = super::manifest_for(&backend, &model)?;
    println!(
        "  per-layer: {} tensors compressed ({:.1}% saved); depth-mean: {} ({:.1}%)",
        per_layer.rules.len(),
        100.0 * per_layer.saving(&man),
        mean.rules.len(),
        100.0 * mean.saving(&man)
    );
    let diffs = per_layer.diff(&mean);

    let mut configs = Vec::new();
    for rules in [&per_layer, &mean] {
        for &lr in &lrs {
            let mut cfg = TrainConfig::lm(&model, "slimadam", lr, steps);
            cfg.backend = backend;
            cfg.ruleset = Some(rules.clone());
            configs.push(cfg);
        }
    }
    let workers = workers_or_default(args, configs.len());
    let sums = run_grid(&configs, workers)?;

    let mut md = String::from(
        "# Fig. 30 — SlimAdam-mean vs per-layer rules\n\n\
         | lr | per-layer loss | depth-mean loss | |Δ| |\n|---|---|---|---|\n",
    );
    let mut max_gap = 0.0f64;
    for (li, &lr) in lrs.iter().enumerate() {
        let a = crate::sweep::LrSweep::metric(&sums[li]);
        let b = crate::sweep::LrSweep::metric(&sums[lrs.len() + li]);
        let gap = (a - b).abs();
        if gap.is_finite() {
            max_gap = max_gap.max(gap);
        }
        md.push_str(&format!(
            "| {lr:.0e} | {a:.4} | {b:.4} | {gap:.4} |\n"
        ));
    }
    md.push_str(&format!(
        "\n- rule differences between variants: {} tensors\n\
         - max loss gap across LRs: {max_gap:.4} (paper: identical performance)\n",
        diffs.len()
    ));
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
