//! Figure 5 (+ App. C.3 Figs. 19/20): ResNet image-classification SNR.
//! Paper shapes: intermediate conv layers show exceptionally high SNR on
//! both dimensions (increasing with depth); the first conv resists
//! fan_out compression; the final layer hovers near 1.0.
//!
//! Offline: `--backend native` probes the builtin `conv_mini` classifier
//! (two convs + head over the same synthetic image stream) instead of the
//! ResNet artifacts, so the conv-SNR figure data exists without `make
//! artifacts`.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::{results_dir, CsvWriter};
use crate::runtime::backend::BackendKind;

use super::{probed_run, steps_or, write_snr, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 1e-3)?;
    let dir = results_dir("fig5")?;
    let native = super::backend_spec(args)?.kind == BackendKind::Native;
    let mut md = String::from("# Fig. 5 / Figs. 19-20 — ResNet SNR\n\n");
    if native {
        md.push_str(
            "*Native offline run: builtin `conv_mini` stands in for the \
             ResNet artifacts (same conv/head layer types, reduced depth).*\n\n",
        );
    }

    let models: Vec<(String, usize)> = if native {
        vec![("conv_mini".into(), 10)]
    } else {
        vec![
            ("resnet_mini_c10".into(), 10),
            ("resnet_mini_c100".into(), 100),
        ]
    };
    for (model, classes) in models {
        println!("fig5: probing {model} ({steps} steps)");
        let mut cfg = TrainConfig::vision(&model, "adam", lr, steps);
        super::apply_common(args, &mut cfg)?;
        let (_, snr) = probed_run(cfg)?;
        write_snr(&dir, &format!("snr_c{classes}.jsonl"), &snr)?;

        let mut w = CsvWriter::create(
            dir.join(format!("conv_depth_c{classes}.csv")),
            &["name", "depth", "fan_out", "fan_in", "both"],
        )?;
        let mut conv_snrs = Vec::new();
        for (avg, info) in snr.per_param.iter().zip(&snr.metas) {
            if info.layer_type != "conv" && info.layer_type != "head" {
                continue;
            }
            w.row(&[
                info.name.clone(),
                info.depth.to_string(),
                format!("{:.4}", avg.fan_out),
                format!("{:.4}", avg.fan_in),
                format!("{:.4}", avg.both),
            ])?;
            if info.layer_type == "conv" && info.depth >= 0 {
                conv_snrs.push((info.depth, avg.best().1));
            }
        }

        let table = super::layer_type_table(&snr);
        println!("{table}");

        // paper checks
        let high_conv = conv_snrs.iter().filter(|(_, s)| *s > 1.0).count();
        let head = snr
            .per_param
            .iter()
            .zip(&snr.metas)
            .find(|(_, i)| i.layer_type == "head")
            .map(|(a, _)| a.best().1)
            .unwrap_or(f64::NAN);
        md.push_str(&format!(
            "## classes={classes}\n\
             - intermediate convs with SNR > 1: {high_conv}/{} (paper: nearly all)\n\
             - final-layer best SNR: {head:.3} (paper: close to 1.0)\n\n```\n{table}```\n\n",
            conv_snrs.len()
        ));
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
