//! Figure 8 / Figure 24 (§4.2): large learning rates reduce
//! compressibility. For each layer type, the best-K time-averaged SNR
//! declines monotonically as LR grows; at the optimal LR, Tok.Embd / LN /
//! K / Q / MLP.Up sit at or below 1 while V / proj / MLP.Down stay above.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::{ascii_chart, results_dir, CsvWriter};
use crate::pool::parallel_map;

use super::{probe, steps_or, workers_or_default, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 150);
    let lrs = args.f64_list("lrs", &[1e-4, 3e-4, 1e-3, 3e-3, 1e-2])?;
    let dir = results_dir("fig8")?;

    println!("fig8: SNR vs learning rate on {model} ({} LRs)", lrs.len());
    let workers = workers_or_default(args, lrs.len());
    let backend = super::backend_spec(args)?;
    let snrs = parallel_map(&lrs, workers, |_, &lr| {
        let mut cfg = TrainConfig::lm(&model, "adam", lr, steps);
        cfg.backend = backend;
        cfg.probe = Some(probe());
        let s = crate::coordinator::run_config(&cfg)?;
        Ok((lr, s.snr.unwrap(), s.result.diverged))
    })?;

    let mut w = CsvWriter::create(
        dir.join("rows.csv"),
        &["lr", "layer_type", "best_k", "avg_snr", "diverged"],
    )?;
    // layer_type -> (lr, best snr) series
    let mut series: std::collections::BTreeMap<String, Vec<(f64, f64)>> =
        Default::default();
    for (lr, snr, diverged) in &snrs {
        for (lt, avg) in snr.by_layer_type() {
            let (k, best) = avg.best();
            w.row(&[
                format!("{lr:e}"),
                lt.clone(),
                k.as_str(),
                format!("{best:.4}"),
                diverged.to_string(),
            ])?;
            series.entry(lt).or_default().push((*lr, best));
        }
    }

    let plot: Vec<(&str, &[(f64, f64)])> = series
        .iter()
        .map(|(k, v)| (k.as_str(), v.as_slice()))
        .collect();
    let chart = ascii_chart(
        "Fig. 8 — best-K averaged SNR vs LR (log-log)",
        &plot,
        64,
        14,
        true,
        true,
    );
    println!("{chart}");

    // paper checks: monotone decline per type; category split at lr=1e-3
    let mut md = String::from(
        "# Fig. 8 / Fig. 24 — large LRs reduce compressibility\n\n\
         | layer_type | SNR@minLR | SNR@maxLR | declines? |\n|---|---|---|---|\n",
    );
    for (lt, pts) in &series {
        let first = pts.first().unwrap().1;
        let last = pts.last().unwrap().1;
        md.push_str(&format!(
            "| {lt} | {first:.3} | {last:.3} | {} |\n",
            if last < first { "yes" } else { "NO" }
        ));
    }
    md.push_str("\n```\n");
    md.push_str(&chart);
    md.push_str("```\n");
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
