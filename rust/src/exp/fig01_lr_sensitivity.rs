//! Figure 1: learning-rate sensitivity of low-memory optimizers on GPT
//! pre-training. The paper's headline qualitative result: SlimAdam traces
//! Adam's U-shaped curve almost exactly; Adam-mini/AdaLayer stay close at
//! small LR but destabilize near Adam's optimum; Lion/SM3/Adafactor are
//! shifted, different curves entirely.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::results_dir;
use crate::sweep::{log_grid, LrSweep};

use super::{steps_or, sweep_scheduler, write_summary_md};

pub const OPTIMIZERS: &[&str] = &[
    "adam",
    "slimadam",
    "adam_mini_v2",
    "adalayer",
    "lion",
    "sm3",
];

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 120);
    let lrs = args.f64_list("lrs", &log_grid(1e-4, 3e-2, 7))?;
    let opts: Vec<String> =
        args.str_list("optimizers", OPTIMIZERS);
    let opt_refs: Vec<&str> = opts.iter().map(|s| s.as_str()).collect();

    let mut base = TrainConfig::lm(&model, "adam", 1e-3, steps);
    super::apply_common(args, &mut base)?;
    let (scheduler, workers) = sweep_scheduler(args, "fig1", opts.len() * lrs.len())?;
    println!(
        "fig1: {model}, {} optimizers x {} LRs x {steps} steps ({workers} workers, \
         streaming results/fig1/stream.jsonl)",
        opts.len(),
        lrs.len()
    );
    let sweep = LrSweep::run_with(&base, &opt_refs, &lrs, &scheduler)?;

    let dir = results_dir("fig1")?;
    sweep.write_csv(dir.join("rows.csv"))?;
    std::fs::write(dir.join("series.json"), sweep.to_json().dump_pretty())?;

    let chart = sweep.chart("Fig.1 — final loss vs learning rate (log x)");
    println!("\n{chart}");

    let mut md = String::from(
        "# Fig. 1 — LR sensitivity (paper: SlimAdam ≈ Adam U-curve)\n\n\
         | optimizer | best lr | best loss | curve vs Adam |\n|---|---|---|---|\n",
    );
    let (adam_lr, adam_loss) = sweep.best(0);
    for (i, name) in sweep.optimizers.iter().enumerate() {
        let (lr, loss) = sweep.best(i);
        let drift = (lr / adam_lr).log10().abs();
        let verdict = if i == 0 {
            "reference".to_string()
        } else if drift < 0.34 && (loss - adam_loss).abs() < 0.15 {
            "matches".to_string()
        } else {
            format!("shifted ({:+.1} dex, Δloss {:+.3})", (lr / adam_lr).log10(), loss - adam_loss)
        };
        md.push_str(&format!(
            "| {name} | {lr:.1e} | {loss:.4} | {verdict} |\n"
        ));
    }
    println!("{md}");
    write_summary_md(&dir, &(md + "\n```\n" + &chart + "\n```\n"))?;
    Ok(())
}
