//! Figure 9 / Figure 25 (§4.3): initialization affects compressibility.
//! Mitchell init (residual-stream projections scaled by 1/sqrt(2L)) yields
//! higher SNR than PyTorch-default init, most dramatically for Attn.Proj
//! and MLP.Down — empirical support for the 1/depth scaling.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::TrainConfig;
use crate::metrics::{results_dir, CsvWriter};
use crate::pool::parallel_map;

use super::{probe, steps_or, workers_or_default, write_summary_md};

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 150);
    let lrs = args.f64_list("lrs", &[3e-4, 1e-3, 3e-3])?;
    let dir = results_dir("fig9")?;

    let mut jobs = Vec::new();
    for &lr in &lrs {
        for init in ["mitchell", "default"] {
            jobs.push((lr, init.to_string()));
        }
    }
    println!("fig9: init comparison on {model} ({} runs)", jobs.len());
    let workers = workers_or_default(args, jobs.len());
    let backend = super::backend_spec(args)?;
    let outs = parallel_map(&jobs, workers, |_, (lr, init)| {
        let mut cfg = TrainConfig::lm(&model, "adam", *lr, steps);
        cfg.backend = backend;
        cfg.init = init.clone();
        cfg.probe = Some(probe());
        let s = crate::coordinator::run_config(&cfg)?;
        Ok((*lr, init.clone(), s.snr.unwrap()))
    })?;

    let mut w = CsvWriter::create(
        dir.join("rows.csv"),
        &["lr", "init", "layer_type", "best_snr"],
    )?;
    let mut md = String::from(
        "# Fig. 9 / Fig. 25 — Mitchell vs PyTorch-default init\n\n\
         | lr | layer_type | SNR mitchell | SNR default | mitchell higher? |\n\
         |---|---|---|---|---|\n",
    );
    for &lr in &lrs {
        let mitchell = outs
            .iter()
            .find(|(l, i, _)| *l == lr && i == "mitchell")
            .unwrap();
        let default = outs
            .iter()
            .find(|(l, i, _)| *l == lr && i == "default")
            .unwrap();
        let mt = mitchell.2.by_layer_type();
        let dt = default.2.by_layer_type();
        for (lt, mavg) in &mt {
            let ms = mavg.best().1;
            let ds = dt.get(lt).map(|a| a.best().1).unwrap_or(f64::NAN);
            w.row(&[
                format!("{lr:e}"),
                "mitchell".into(),
                lt.clone(),
                format!("{ms:.4}"),
            ])?;
            w.row(&[
                format!("{lr:e}"),
                "default".into(),
                lt.clone(),
                format!("{ds:.4}"),
            ])?;
            let mark = if matches!(lt.as_str(), "attn_proj" | "mlp_down") {
                " **(residual-stream)**"
            } else {
                ""
            };
            md.push_str(&format!(
                "| {lr:.0e} | {lt}{mark} | {ms:.3} | {ds:.3} | {} |\n",
                if ms > ds { "yes" } else { "no" }
            ));
        }
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
