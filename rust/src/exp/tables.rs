//! Tables 1-3 (App. H): robustness of SlimAdam's compression rules.
//!
//! * Table 1 — rule differences across datasets (synthetic Markov vs the
//!   real repo corpus) for the same model.
//! * Table 2 — rule differences across widths (d_model 64 vs 192).
//! * Table 3 — recommended K* per layer type aggregated across regimes,
//!   with inconsistency markers.

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{DataSpec, TrainConfig};
use crate::metrics::results_dir;
use crate::rules::{recommend, RuleSet};

use super::{probed_run, steps_or, write_summary_md};

fn derive_rules(
    args: &Args,
    model: &str,
    data: DataSpec,
    lr: f64,
    steps: usize,
    label: &str,
    vision: bool,
) -> Result<RuleSet> {
    let mut cfg = if vision {
        TrainConfig::vision(model, "adam", lr, steps)
    } else {
        TrainConfig::lm(model, "adam", lr, steps)
    };
    super::apply_common(args, &mut cfg)?;
    cfg.data = data;
    let (_, snr) = probed_run(cfg)?;
    Ok(RuleSet::derive(&snr, 1.0, label, Some(lr)))
}

fn diff_table(title: &str, left_name: &str, right_name: &str, a: &RuleSet, b: &RuleSet) -> String {
    let diffs = a.diff(b);
    let mut md = format!(
        "# {title}\n\n{} differing matrices of {} rules\n\n\
         | layer | {left_name} | {right_name} |\n|---|---|---|\n",
        diffs.len(),
        a.rules.len().max(b.rules.len()),
    );
    for d in &diffs {
        md.push_str(&format!(
            "| {} | {} | {} |\n",
            d.name,
            d.left.as_str(),
            d.right.as_str()
        ));
    }
    md
}

/// Table 1: dataset dependency (Markov vs repo corpus).
pub fn table1(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 3e-4)?;
    println!("table1: rules on synthetic Markov vs repo corpus ({model})");
    let markov = derive_rules(
        args,
        &model,
        DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 1234 },
        lr,
        steps,
        "markov",
        false,
    )?;
    let corpus = derive_rules(args, &model, DataSpec::Corpus, lr, steps, "corpus", false)?;
    let dir = results_dir("table1")?;
    markov.save(dir.join("markov.rules.json"))?;
    corpus.save(dir.join("corpus.rules.json"))?;
    let md = diff_table(
        "Table 1 — rule differences across datasets",
        "markov",
        "repo-corpus",
        &markov,
        &corpus,
    ) + "\n(paper: only ~5 matrices differ, mostly early MLP layers)\n";
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}

/// Table 2: width dependency (d_model 64 vs 192, paper's 256 vs 768).
pub fn table2(args: &Args) -> Result<()> {
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 3e-4)?;
    println!("table2: rules at width 64 vs width 192");
    let data = DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 1234 };
    let narrow = derive_rules(args, "gpt_nano", data.clone(), lr, steps, "w64", false)?;
    let wide = derive_rules(args, "gpt_nano_w192", data, lr, steps, "w192", false)?;
    let dir = results_dir("table2")?;
    narrow.save(dir.join("w64.rules.json"))?;
    wide.save(dir.join("w192.rules.json"))?;
    let md = diff_table(
        "Table 2 — rule differences across widths",
        "d=64",
        "d=192",
        &narrow,
        &wide,
    ) + "\n(paper: ~12 matrices differ, mostly early/middle MLPs and attention)\n";
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}

/// Table 3: recommended compression dimensions across regimes.
pub fn table3(args: &Args) -> Result<()> {
    let steps = steps_or(args, 120);
    println!("table3: aggregating rules across training regimes");
    let lm_data = DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 1234 };

    let gpt = derive_rules(args, "gpt_nano", lm_data.clone(), 3e-4, steps, "gpt", false)?;
    let llama = derive_rules(args, "llama_tiny", lm_data, 3e-4, steps, "llama", false)?;
    let vit = derive_rules(
        args,
        "vit_mini_c10",
        DataSpec::Images { noise: 0.3, seed: 99 },
        3e-4,
        steps,
        "vit",
        true,
    )?;
    let resnet = derive_rules(
        args,
        "resnet_mini_c10",
        DataSpec::Images { noise: 0.3, seed: 99 },
        3e-4,
        steps,
        "resnet",
        true,
    )?;

    let gpt_man = super::manifest("gpt_nano")?;
    let llama_man = super::manifest("llama_tiny")?;
    let vit_man = super::manifest("vit_mini_c10")?;
    let resnet_man = super::manifest("resnet_mini_c10")?;
    let recs = recommend(&[
        (&gpt, &gpt_man),
        (&llama, &llama_man),
        (&vit, &vit_man),
        (&resnet, &resnet_man),
    ]);

    // paper's Table 3 expectations in this repo's storage convention
    let expected: &[(&str, &str)] = &[
        ("attn_k", "fan_in"),
        ("attn_q", "fan_in"),
        ("attn_v", "fan_out"),
        ("attn_proj", "fan_out"),
        ("mlp_down", "fan_out"),
        ("tok_embd", "fan_in"),
        ("lm_head", "fan_in"),
        ("patch_embd", "fan_in"),
    ];

    let dir = results_dir("table3")?;
    let mut md = String::from(
        "# Table 3 — recommended compression dimension per layer type\n\n\
         | layer type | K* (derived) | inconsistent? | paper K* | match |\n\
         |---|---|---|---|---|\n",
    );
    for (lt, (k, inconsistent)) in &recs {
        let paper = expected
            .iter()
            .find(|(e, _)| e == lt)
            .map(|(_, k)| *k)
            .unwrap_or("-");
        md.push_str(&format!(
            "| {lt} | {} | {} | {paper} | {} |\n",
            k.as_str(),
            if *inconsistent { "*" } else { "" },
            if paper == "-" {
                "n/a".to_string()
            } else {
                (k.as_str() == paper).to_string()
            }
        ));
    }
    for (name, rs) in [("gpt", &gpt), ("llama", &llama), ("vit", &vit), ("resnet", &resnet)] {
        rs.save(dir.join(format!("{name}.rules.json")))?;
    }
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
