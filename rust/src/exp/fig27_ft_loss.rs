//! Figures 27-28 (App. F): fine-tuning loss and downstream performance.
//! Fine-tune the pre-trained tiny-Llama with Adam vs SlimAdam vs AdaLayer;
//! report training-loss trajectories and held-out eval loss on the shifted
//! distribution (the downstream-task proxy for HellaSwag/TruthfulQA —
//! DESIGN.md §3).

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{run_grid, TrainConfig};
use crate::metrics::{ascii_chart, results_dir, JsonlWriter};

use super::{steps_or, workers_or_default, write_summary_md};

const OPTS: &[&str] = &["adam", "slimadam", "adalayer", "adam_mini_v2"];

pub fn run(args: &Args) -> Result<()> {
    let model = args.str_or("model", "llama_tiny").to_string();
    let steps = steps_or(args, 150);
    let lr = args.f64_or("lr", 1e-4)?;
    let dir = results_dir("fig27")?;

    let backend = super::backend_spec(args)?;
    let warm = Arc::new(super::fig04_finetune_snr::pretrained_params(
        &backend, &model, 200, false,
    )?);

    let mut configs = Vec::new();
    for opt in OPTS {
        let mut cfg = TrainConfig::finetune(&model, opt, lr, steps);
        cfg.backend = backend;
        cfg.warm_start = Some(warm.clone());
        cfg.eval_batches = 16;
        configs.push(cfg);
    }
    println!("fig27: fine-tuning {model} with {} optimizers", OPTS.len());
    let workers = workers_or_default(args, configs.len());
    let sums = run_grid(&configs, workers)?;

    let mut w = JsonlWriter::create(dir.join("trajectories.jsonl"))?;
    for s in &sums {
        for &(step, loss) in &s.result.losses {
            let mut v = crate::json::Value::obj();
            v.set("optimizer", s.optimizer.clone())
                .set("step", step)
                .set("loss", loss as f64);
            w.write(&v)?;
        }
    }

    let series: Vec<(String, Vec<(f64, f64)>)> = sums
        .iter()
        .map(|s| {
            (
                s.optimizer.clone(),
                s.result
                    .losses
                    .iter()
                    .map(|&(t, l)| (t as f64, l as f64))
                    .collect(),
            )
        })
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> = series
        .iter()
        .map(|(n, p)| (n.as_str(), p.as_slice()))
        .collect();
    let chart = ascii_chart("Fig. 27 — fine-tuning loss", &refs, 64, 14, false, false);
    println!("{chart}");

    let adam_eval = sums[0].result.eval_loss;
    let mut md = String::from(
        "# Fig. 27/28 — fine-tuning loss + downstream proxy (held-out eval)\n\n\
         | optimizer | final train loss | eval loss | Δ eval vs Adam | v saved |\n\
         |---|---|---|---|---|\n",
    );
    for s in &sums {
        md.push_str(&format!(
            "| {} | {:.4} | {:.4} | {:+.4} | {} |\n",
            s.optimizer,
            s.result.final_train_loss,
            s.result.eval_loss,
            s.result.eval_loss - adam_eval,
            s.memory
                .as_ref()
                .map(|m| format!("{:.0}%", 100.0 * m.v_saving))
                .unwrap_or_default()
        ));
    }
    md.push_str(&format!("\n```\n{chart}```\n"));
    println!("{md}");
    write_summary_md(&dir, &md)?;
    Ok(())
}
