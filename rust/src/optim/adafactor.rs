//! Adafactor (Shazeer & Stern 2018): factored second moments. For a matrix
//! the second moment is approximated from exponential moving averages of
//! row sums `R` and column sums `C` of the squared gradients:
//!
//! ```text
//! v_ij ≈ R_i * C_j / sum(R)
//! ```
//!
//! with the time-dependent decay `beta2_t = 1 - t^{-0.8}` and RMS update
//! clipping (threshold d = 1.0). Vectors keep full per-element moments.
//!
//! Two variants per the paper's App. A:
//! * **v1** (PyTorch-style): no momentum on the update.
//! * **v2** (fairseq-style): EMA of updates with beta1 = 0.9
//!   (`relative_step=False`; the external LR schedule is used as-is).

use crate::tensor::Tensor;

use super::{Optimizer, ParamInfo};

const EPS1: f32 = 1e-30; // inside g^2 (Adafactor's epsilon_1)
const CLIP_D: f32 = 1.0;

pub struct Adafactor {
    metas: Vec<ParamInfo>,
    use_momentum: bool, // v2
    beta1: f32,
    weight_decay: f32,
    state: Vec<FactorState>,
    m: Vec<Tensor>, // only allocated for v2
}

enum FactorState {
    Factored { r: Vec<f32>, c: Vec<f32>, rows: usize, cols: usize },
    Exact(Vec<f32>),
}

impl Adafactor {
    pub fn new(metas: Vec<ParamInfo>, use_momentum: bool, weight_decay: f64) -> Adafactor {
        let state = metas
            .iter()
            .map(|p| {
                let (rows, cols) = p.matrix_dims();
                if p.is_vector() {
                    FactorState::Exact(vec![0.0; p.numel()])
                } else {
                    FactorState::Factored {
                        r: vec![0.0; rows],
                        c: vec![0.0; cols],
                        rows,
                        cols,
                    }
                }
            })
            .collect();
        let m = if use_momentum {
            metas.iter().map(|p| Tensor::zeros(&p.shape)).collect()
        } else {
            Vec::new()
        };
        Adafactor {
            metas,
            use_momentum,
            beta1: 0.9,
            weight_decay: weight_decay as f32,
            state,
            m,
        }
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> &str {
        if self.use_momentum {
            "adafactor_v2"
        } else {
            "adafactor"
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], t: usize, lr: f32) {
        let beta2t = 1.0 - (t as f32).powf(-0.8);
        for i in 0..params.len() {
            let info = &self.metas[i];
            let wd = if info.wd { self.weight_decay } else { 0.0 };
            let w = &mut params[i].data;

            // Compute the unclipped update u into a scratch buffer.
            let mut u = vec![0.0f32; w.len()];
            match &mut self.state[i] {
                FactorState::Exact(v) => {
                    let g = &grads[i].data;
                    for j in 0..w.len() {
                        let g2 = g[j] * g[j] + EPS1;
                        v[j] = beta2t * v[j] + (1.0 - beta2t) * g2;
                        u[j] = g[j] / v[j].sqrt();
                    }
                }
                FactorState::Factored { r, c, rows, cols } => {
                    let gmat = grads[i].matrix_view(info.fan_out_axis);
                    let (rows, cols) = (*rows, *cols);
                    // row/col sums of g^2
                    let mut rsum = vec![0.0f32; rows];
                    let mut csum = vec![0.0f32; cols];
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let g2 = gmat.at(ri, ci).powi(2) + EPS1;
                            rsum[ri] += g2;
                            csum[ci] += g2;
                        }
                    }
                    for (ri, s) in r.iter_mut().zip(&rsum) {
                        *ri = beta2t * *ri + (1.0 - beta2t) * s;
                    }
                    for (ci, s) in c.iter_mut().zip(&csum) {
                        *ci = beta2t * *ci + (1.0 - beta2t) * s;
                    }
                    let rtot: f32 = r.iter().sum();
                    let is_borrowed =
                        matches!(gmat.data, std::borrow::Cow::Borrowed(_));
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let v = (r[ri] * c[ci] / rtot.max(EPS1)).max(EPS1);
                            let raw = if is_borrowed {
                                ri * cols + ci
                            } else {
                                super::raw_index(info, ri, ci)
                            };
                            u[raw] = gmat.at(ri, ci) / v.sqrt();
                        }
                    }
                }
            }

            // RMS clipping: u /= max(1, RMS(u)/d)
            let rms = (u.iter().map(|x| (x * x) as f64).sum::<f64>()
                / u.len() as f64)
                .sqrt() as f32;
            let scale = 1.0 / (rms / CLIP_D).max(1.0);

            if self.use_momentum {
                let m = &mut self.m[i].data;
                for j in 0..w.len() {
                    let uj = u[j] * scale;
                    m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * uj;
                    w[j] -= lr * (m[j] + wd * w[j]);
                }
            } else {
                for j in 0..w.len() {
                    w[j] -= lr * (u[j] * scale + wd * w[j]);
                }
            }
        }
    }

    fn second_moment(&self, i: usize) -> Option<Tensor> {
        let info = &self.metas[i];
        match &self.state[i] {
            FactorState::Exact(v) => Some(Tensor::from_vec(&info.shape, v.clone())),
            FactorState::Factored { r, c, rows, cols } => {
                let rtot: f32 = r.iter().sum::<f32>().max(EPS1);
                let mut full = Tensor::zeros(&info.shape);
                for ri in 0..*rows {
                    for ci in 0..*cols {
                        let raw = if info.shape.len() <= 2 {
                            ri * cols + ci
                        } else {
                            super::raw_index(info, ri, ci)
                        };
                        full.data[raw] = r[ri] * c[ci] / rtot;
                    }
                }
                Some(full)
            }
        }
    }

    fn second_moment_elems(&self) -> usize {
        self.state
            .iter()
            .map(|s| match s {
                FactorState::Exact(v) => v.len(),
                FactorState::Factored { r, c, .. } => r.len() + c.len(),
            })
            .sum()
    }

    fn first_moment_elems(&self) -> usize {
        self.m.iter().map(|m| m.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: false,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn factored_memory() {
        let opt = Adafactor::new(vec![meta(&[32, 64])], false, 0.0);
        assert_eq!(opt.second_moment_elems(), 32 + 64);
        assert_eq!(opt.first_moment_elems(), 0);
        let opt2 = Adafactor::new(vec![meta(&[32, 64])], true, 0.0);
        assert_eq!(opt2.first_moment_elems(), 32 * 64);
    }

    #[test]
    fn rank_one_gradients_are_exactly_factored() {
        // g = a b^T  =>  v_ij proportional to (a_i^2)(b_j^2): the factored
        // approximation is exact for rank-1 g^2 structure.
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 1.0, 2.0];
        let mut g = Tensor::zeros(&[2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                g.data[i * 3 + j] = a[i] * b[j];
            }
        }
        let mut opt = Adafactor::new(vec![meta(&[2, 3])], false, 0.0);
        let mut p = vec![Tensor::zeros(&[2, 3])];
        opt.step(&mut p, &[g.clone()], 1, 0.0);
        let v = opt.second_moment(0).unwrap();
        // compare v against normalized g^2 up to global scale
        let g2: Vec<f32> = g.data.iter().map(|x| x * x).collect();
        let ratio0 = v.data[0] / g2[0];
        for j in 1..6 {
            let r = v.data[j] / g2[j];
            assert!((r - ratio0).abs() / ratio0 < 1e-3, "{r} vs {ratio0}");
        }
    }

    #[test]
    fn rms_clipping_bounds_update() {
        let mut opt = Adafactor::new(vec![meta(&[4, 4])], false, 0.0);
        let mut p = vec![Tensor::zeros(&[4, 4])];
        let mut rng = crate::rng::Rng::new(1);
        let g = Tensor::from_vec(&[4, 4], (0..16).map(|_| rng.normal() as f32).collect());
        opt.step(&mut p, &[g], 1, 1.0);
        // with lr=1 and d=1, RMS of the applied update <= ~1
        let rms = (p[0].data.iter().map(|x| (x * x) as f64).sum::<f64>() / 16.0).sqrt();
        assert!(rms <= 1.0 + 1e-5, "{rms}");
    }

    #[test]
    fn stays_finite_over_steps() {
        let mut opt = Adafactor::new(vec![meta(&[8, 8]), meta(&[8])], true, 0.01);
        let mut rng = crate::rng::Rng::new(2);
        let mut p = vec![
            Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect()),
            Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
        ];
        for t in 1..=30 {
            let g = vec![
                Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect()),
                Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
            ];
            opt.step(&mut p, &g, t, 1e-2);
        }
        assert!(p[0].data.iter().all(|x| x.is_finite()));
        assert!(p[1].data.iter().all(|x| x.is_finite()));
    }
}
