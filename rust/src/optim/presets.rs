//! Named optimizer factory: maps the paper's optimizer names to concrete
//! instances over a model manifest. This is the single place where the
//! baselines' partitioning conventions (App. A) are encoded.

use anyhow::{bail, Result};

use crate::runtime::Manifest;
use crate::rules::RuleSet;

use super::adafactor::Adafactor;
use super::adamk::AdamK;
use super::lion::Lion;
use super::lowrank_v::{self, LowRankV};
use super::sgdm::SgdM;
use super::sm3::Sm3;
use super::{Hypers, KMode, Optimizer, ParamInfo};

/// Layer types treated as "LayerNorm-like" across architectures.
///
/// ```
/// use slimadam::optim::presets::is_norm;
/// assert!(is_norm("ln_attn") && is_norm("bn"));
/// assert!(!is_norm("conv") && !is_norm("attn_q"));
/// ```
pub fn is_norm(layer_type: &str) -> bool {
    matches!(layer_type, "ln_attn" | "ln_mlp" | "ln_final" | "bn")
}

/// Layer types carrying the token dimension (the paper's incompressible
/// direction — Tok.Embd / LM Head).
pub fn is_token_layer(layer_type: &str) -> bool {
    matches!(layer_type, "tok_embd" | "lm_head")
}

fn n_heads(man: &Manifest) -> usize {
    man.meta
        .opt("n_heads")
        .and_then(|v| v.as_usize().ok())
        .unwrap_or(1)
}

/// Build an optimizer by name. Recognized names:
///
/// * `adam` — AdamW (K = ∅ everywhere)
/// * `slimadam` — paper Table-3 recommended rules (or pass an explicit
///   [`RuleSet`] via [`build_slimadam`])
/// * `adalayer` / `adalayer_ln_tl` — Zhao et al. 2024
/// * `adam_mini_v1` / `adam_mini_v2` — Zhang et al. 2024b
/// * `sm3` / `sm3_b0` — Anil et al. 2019 (beta 0.95 / 0.0)
/// * `lion` — Chen et al. 2023
/// * `adafactor` / `adafactor_v2` — Shazeer & Stern 2018
/// * `sgdm` — SGD + momentum 0.9
/// * `lowrank_v` / `lowrank_v<r>` — rank-r sketched second moments in
///   the Adapprox spirit (default rank 4)
///
/// Works over any manifest — PJRT artifacts and the native model zoo
/// alike (conv weights compress per output filter under `slimadam`):
///
/// ```
/// use slimadam::optim::{presets, Optimizer};
/// use slimadam::runtime::backend::native;
///
/// let man = native::grad_manifest("conv_mini").unwrap();
/// let adam = presets::build("adam", &man, Default::default()).unwrap();
/// let slim = presets::build("slimadam", &man, Default::default()).unwrap();
/// assert_eq!(adam.second_moment_elems(), man.total_param_elems());
/// assert!(slim.second_moment_elems() < adam.second_moment_elems() / 10);
/// ```
pub fn build(name: &str, man: &Manifest, hypers: Hypers) -> Result<Box<dyn Optimizer>> {
    let metas: Vec<ParamInfo> = man.params.clone();
    let heads = n_heads(man);
    Ok(match name {
        "adam" => Box::new(AdamK::new(
            "adam",
            metas.clone(),
            vec![KMode::None; man.n_params()],
            hypers,
        )),
        "slimadam" => {
            let rules = RuleSet::table3_default(man);
            Box::new(build_slimadam(man, &rules, hypers))
        }
        "adalayer" => Box::new(AdamK::new(
            "adalayer",
            metas.clone(),
            vec![KMode::Both; man.n_params()],
            hypers,
        )),
        "adalayer_ln_tl" => {
            let modes = metas
                .iter()
                .map(|p| {
                    if is_norm(&p.layer_type) || is_token_layer(&p.layer_type) {
                        KMode::None
                    } else {
                        KMode::Both
                    }
                })
                .collect();
            Box::new(AdamK::new("adalayer_ln_tl", metas.clone(), modes, hypers))
        }
        "adam_mini_v1" => {
            // v1: PyTorch default block partitioning (one moment per
            // tensor), except per-param Tok.Embd/LM-Head and per-head Q/K.
            let modes = metas
                .iter()
                .map(|p| {
                    if is_token_layer(&p.layer_type) {
                        KMode::None
                    } else if matches!(p.layer_type.as_str(), "attn_q" | "attn_k") {
                        KMode::Blocks(heads)
                    } else {
                        KMode::Both
                    }
                })
                .collect();
            Box::new(AdamK::new("adam_mini_v1", metas.clone(), modes, hypers))
        }
        "adam_mini_v2" => {
            // v2: one moment per output neuron (mean over fan_in), except
            // per-head Q/K and per-token-row Tok/LM-Head (which per-output-
            // neuron already gives); LayerNorms compressed.
            let modes = metas
                .iter()
                .map(|p| {
                    if matches!(p.layer_type.as_str(), "attn_q" | "attn_k") {
                        KMode::Blocks(heads)
                    } else if is_norm(&p.layer_type) {
                        KMode::Both
                    } else if p.is_vector() {
                        KMode::Both
                    } else {
                        KMode::FanIn
                    }
                })
                .collect();
            Box::new(AdamK::new("adam_mini_v2", metas.clone(), modes, hypers))
        }
        "sm3" => Box::new(Sm3::new(metas, 0.95, 0.9, hypers.weight_decay)),
        "sm3_b0" => Box::new(Sm3::new(metas, 0.0, 0.9, hypers.weight_decay)),
        "lion" => Box::new(Lion::new(metas, 0.9, 0.95, hypers.weight_decay)),
        "adafactor" => Box::new(Adafactor::new(metas, false, hypers.weight_decay)),
        "adafactor_v2" => Box::new(Adafactor::new(metas, true, hypers.weight_decay)),
        "sgdm" => Box::new(SgdM::new(metas, 0.9, hypers.weight_decay)),
        other => match lowrank_v::parse_token(other) {
            Some(rank) => Box::new(LowRankV::new(metas, rank, hypers)),
            None => bail!("unknown optimizer {other:?}"),
        },
    })
}

/// The resolved hyperparameter spec behind a preset name, for run
/// identity (`runstore::config_key`). Presets that bake in their own
/// constants — betas, momentum, rank — return a canonical spec string;
/// the AdamW family returns `None` because its hyperparameters already
/// travel in the config's [`Hypers`]. Keys for adam/slimadam/adalayer
/// configs therefore stay byte-identical to earlier schema versions.
pub fn spec_key(name: &str) -> Option<String> {
    Some(match name {
        "sm3" => "sm3:b=0.95,mom=0.9,eps=1e-8".to_string(),
        "sm3_b0" => "sm3:b=0,mom=0.9,eps=1e-8".to_string(),
        "lion" => "lion:b1=0.9,b2=0.95".to_string(),
        "adafactor" => "adafactor:v1,d=1".to_string(),
        "adafactor_v2" => "adafactor:v2,b1=0.9,d=1".to_string(),
        "sgdm" => "sgdm:mom=0.9".to_string(),
        other => {
            let rank = lowrank_v::parse_token(other)?;
            format!("lowrank_v:r={rank}")
        }
    })
}

/// SlimAdam from an explicit SNR-derived rule set.
pub fn build_slimadam(man: &Manifest, rules: &RuleSet, hypers: Hypers) -> AdamK {
    let modes = rules.modes_for(man);
    AdamK::new(
        format!("slimadam[{}]", rules.label),
        man.params.clone(),
        modes,
        hypers,
    )
}

/// All optimizer names exercised by the Fig. 1 / Fig. 10 comparisons.
pub const ALL: &[&str] = &[
    "adam",
    "slimadam",
    "adalayer",
    "adalayer_ln_tl",
    "adam_mini_v1",
    "adam_mini_v2",
    "sm3",
    "lion",
    "adafactor",
    "adafactor_v2",
    "sgdm",
    "lowrank_v",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn manifest() -> Manifest {
        // A minimal GPT-ish manifest for preset construction.
        let src = r#"{
          "kind": "grad_step",
          "model": {"name": "t", "family": "gpt", "vocab": 64, "n_heads": 4},
          "params": [
            {"name": "tok_embd", "shape": [64, 16], "layer_type": "tok_embd",
             "depth": -1, "init_mitchell": {"scheme": "normal", "std": 0.02},
             "init_default": {"scheme": "normal", "std": 1.0}, "wd": true,
             "fan_out_axis": 0},
            {"name": "h0.attn_q", "shape": [16, 16], "layer_type": "attn_q",
             "depth": 0, "init_mitchell": {"scheme": "normal", "std": 0.02},
             "init_default": {"scheme": "uniform", "limit": 0.25}, "wd": true,
             "fan_out_axis": 0},
            {"name": "h0.ln_attn", "shape": [16], "layer_type": "ln_attn",
             "depth": 0, "init_mitchell": {"scheme": "ones"},
             "init_default": {"scheme": "ones"}, "wd": false,
             "fan_out_axis": 0}
          ],
          "batch": [{"name": "x", "shape": [2, 8], "dtype": "s32"}],
          "inputs": ["param:tok_embd", "param:h0.attn_q", "param:h0.ln_attn",
                     "batch:x"],
          "outputs": ["loss", "grad:tok_embd", "grad:h0.attn_q",
                      "grad:h0.ln_attn"]
        }"#;
        Manifest::parse(src).unwrap()
    }

    #[test]
    fn all_presets_construct() {
        let man = manifest();
        for name in ALL {
            let opt = build(name, &man, Hypers::default()).unwrap();
            assert!(!opt.name().is_empty(), "{name}");
        }
        assert!(build("bogus", &man, Hypers::default()).is_err());
    }

    #[test]
    fn adam_memory_dominates() {
        let man = manifest();
        let total: usize = man.total_param_elems();
        let adam = build("adam", &man, Hypers::default()).unwrap();
        assert_eq!(adam.second_moment_elems(), total);
        for name in ["slimadam", "adalayer", "adam_mini_v1", "adam_mini_v2", "sm3"] {
            let opt = build(name, &man, Hypers::default()).unwrap();
            assert!(
                opt.second_moment_elems() < total,
                "{name} should save memory"
            );
        }
    }

    #[test]
    fn adam_mini_partitions() {
        let man = manifest();
        let v1 = build("adam_mini_v1", &man, Hypers::default()).unwrap();
        // tok_embd per-param (64*16) + q per-head (4) + ln one (1)
        assert_eq!(v1.second_moment_elems(), 64 * 16 + 4 + 1);
        let v2 = build("adam_mini_v2", &man, Hypers::default()).unwrap();
        // tok per row (64) + q per head (4) + ln compressed (1)
        assert_eq!(v2.second_moment_elems(), 64 + 4 + 1);
    }

    #[test]
    fn lowrank_tokens_build_with_rank() {
        let man = manifest();
        let opt = build("lowrank_v", &man, Hypers::default()).unwrap();
        assert_eq!(opt.name(), "lowrank_v");
        let opt8 = build("lowrank_v8", &man, Hypers::default()).unwrap();
        assert_eq!(opt8.name(), "lowrank_v8");
        assert!(
            opt8.second_moment_elems() > opt.second_moment_elems(),
            "higher rank stores more sketch state"
        );
        assert!(build("lowrank_v0", &man, Hypers::default()).is_err());
    }

    #[test]
    fn spec_keys_cover_hardcoded_presets_only() {
        // AdamW-family names: hypers travel in the config, no spec key.
        for name in ["adam", "slimadam", "adalayer", "adalayer_ln_tl"] {
            assert!(spec_key(name).is_none(), "{name}");
        }
        // Baselines with baked-in constants get a canonical spec.
        for name in ["sm3", "sm3_b0", "lion", "adafactor", "adafactor_v2", "sgdm"] {
            assert!(spec_key(name).is_some(), "{name}");
        }
        assert_eq!(spec_key("lowrank_v").as_deref(), Some("lowrank_v:r=4"));
        assert_eq!(spec_key("lowrank_v2").as_deref(), Some("lowrank_v:r=2"));
        assert_ne!(spec_key("sm3"), spec_key("sm3_b0"));
    }

    #[test]
    fn adalayer_ln_tl_exempts() {
        let man = manifest();
        let opt = build("adalayer_ln_tl", &man, Hypers::default()).unwrap();
        // tok_embd uncompressed (1024) + q scalar (1) + ln uncompressed (16)
        assert_eq!(opt.second_moment_elems(), 1024 + 1 + 16);
    }
}
