//! SGD with momentum — the non-adaptive baseline the paper contrasts Adam
//! against (decoupled weight decay to match the AdamW convention).

use crate::tensor::Tensor;

use super::{Optimizer, ParamInfo};

pub struct SgdM {
    metas: Vec<ParamInfo>,
    momentum: f32,
    weight_decay: f32,
    buf: Vec<Tensor>,
}

impl SgdM {
    pub fn new(metas: Vec<ParamInfo>, momentum: f64, weight_decay: f64) -> SgdM {
        let buf = metas.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        SgdM {
            metas,
            momentum: momentum as f32,
            weight_decay: weight_decay as f32,
            buf,
        }
    }
}

impl Optimizer for SgdM {
    fn name(&self) -> &str {
        "sgdm"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], _t: usize, lr: f32) {
        for i in 0..params.len() {
            let wd = if self.metas[i].wd { self.weight_decay } else { 0.0 };
            let w = &mut params[i].data;
            let g = &grads[i].data;
            let b = &mut self.buf[i].data;
            for j in 0..w.len() {
                b[j] = self.momentum * b[j] + g[j];
                w[j] -= lr * (b[j] + wd * w[j]);
            }
        }
    }

    fn second_moment(&self, _i: usize) -> Option<Tensor> {
        None
    }

    fn second_moment_elems(&self) -> usize {
        0
    }

    fn first_moment_elems(&self) -> usize {
        self.buf.iter().map(|b| b.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn plain_sgd_step() {
        let mut opt = SgdM::new(vec![meta(&[2])], 0.0, 0.0);
        let mut p = vec![Tensor::from_vec(&[2], vec![1.0, 2.0])];
        let g = Tensor::from_vec(&[2], vec![0.5, -0.5]);
        opt.step(&mut p, &[g], 1, 0.1);
        assert!((p[0].data[0] - 0.95).abs() < 1e-7);
        assert!((p[0].data[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdM::new(vec![meta(&[1])], 0.9, 0.0);
        let mut p = vec![Tensor::zeros(&[1])];
        let g = Tensor::from_vec(&[1], vec![1.0]);
        opt.step(&mut p, &[g.clone()], 1, 1.0); // buf=1, w=-1
        opt.step(&mut p, &[g], 2, 1.0); // buf=1.9, w=-2.9
        assert!((p[0].data[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn no_second_moments() {
        let opt = SgdM::new(vec![meta(&[4, 4])], 0.9, 0.1);
        assert_eq!(opt.second_moment_elems(), 0);
        assert!(opt.second_moment(0).is_none());
        assert_eq!(opt.first_moment_elems(), 16);
    }
}
