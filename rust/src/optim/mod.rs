//! The paper's optimizer family — Layer-3 implementation used by the
//! split engine (HLO computes loss+grads; these optimizers apply updates).
//!
//! * [`adamk::AdamK`] — AdamW generalized with per-tensor sharing
//!   dimensions K (Eq. 2). Instantiates **Adam** (all K=∅), **SlimAdam**
//!   (SNR-derived rules), **AdaLayer** (all K=(0,1)), **AdaLayer+LN+TL**,
//!   and **Adam-mini v1/v2** (block partitions via `KMode::Blocks`).
//! * [`lion::Lion`], [`sm3::Sm3`], [`adafactor::Adafactor`],
//!   [`sgdm::SgdM`] — the "different algorithm" baselines of Fig. 1.
//! * [`memory`] — exact optimizer-state accounting (the "saves 98% of
//!   second moments" numbers).
//! * [`presets`] — name → optimizer factory used by the CLI and sweeps.

pub mod adafactor;
pub mod adamk;
pub mod lion;
pub mod lowrank_v;
pub mod memory;
pub mod presets;
pub mod sgdm;
pub mod sm3;

use crate::tensor::Tensor;

pub use crate::runtime::manifest::{Hypers, KMode, ParamInfo};

/// A stateful optimizer over a fixed parameter list.
pub trait Optimizer {
    fn name(&self) -> &str;

    /// Apply one update in place. `t` is the 1-based step index (bias
    /// correction); `lr` is the already-scheduled learning rate. `grads`
    /// must already be clipped (the train loop owns clipping, matching the
    /// paper's global-norm-1.0 setup).
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], t: usize, lr: f32);

    /// Materialize the *full-shape* second moment of parameter `i` if this
    /// optimizer maintains an Adam-style V (broadcast from the reduced
    /// storage). Returns `None` for optimizers without a V (SGD-M, Lion).
    /// The SNR probe (Eq. 3) consumes this.
    fn second_moment(&self, i: usize) -> Option<Tensor>;

    /// Exact stored second-moment element count (the memory headline).
    fn second_moment_elems(&self) -> usize;

    /// Exact stored first-moment element count.
    fn first_moment_elems(&self) -> usize;
}

/// Raw (row-major) index of matrix-view element `(row, col)` for a tensor
/// with an arbitrary `fan_out_axis` — the inverse of the view permutation
/// used by `Tensor::matrix_view`. Shared by SM3 / Adafactor, whose factored
/// state lives in view coordinates.
pub(crate) fn raw_index(info: &ParamInfo, row: usize, col: usize) -> usize {
    let stride_fo: usize = info.shape[info.fan_out_axis + 1..].iter().product();
    let fo = info.shape[info.fan_out_axis];
    (col / stride_fo) * stride_fo * fo + row * stride_fo + (col % stride_fo)
}

/// Global-norm gradient clipping (paper: max norm 1.0). Returns the
/// pre-clip norm.
///
/// Degenerate steps are contained here rather than propagated into
/// optimizer state: a non-finite norm (any NaN/Inf gradient element)
/// zeroes the gradients — `g * (max_norm / inf)` would still leave
/// NaNs in place and a NaN norm fails every comparison, so without the
/// guard one overflowed batch poisons V for the rest of the run. An
/// all-zero gradient passes through untouched (no division by the zero
/// norm).
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f64) -> f64 {
    let mut sq = 0.0f64;
    for g in grads.iter() {
        for &x in &g.data {
            sq += (x as f64) * (x as f64);
        }
    }
    let norm = sq.sqrt();
    if !norm.is_finite() {
        for g in grads.iter_mut() {
            for x in &mut g.data {
                *x = 0.0;
            }
        }
    } else if norm > max_norm && norm > 0.0 {
        let scale = (max_norm / norm) as f32;
        for g in grads.iter_mut() {
            for x in &mut g.data {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_down_only() {
        let mut g = vec![Tensor::from_vec(&[2], vec![3.0, 4.0])]; // norm 5
        let n = clip_global_norm(&mut g, 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        let after: f64 = g[0].l2_norm();
        assert!((after - 1.0).abs() < 1e-5);

        let mut small = vec![Tensor::from_vec(&[2], vec![0.3, 0.4])];
        clip_global_norm(&mut small, 1.0);
        assert!((small[0].data[0] - 0.3).abs() < 1e-7);
    }

    #[test]
    fn clip_zero_gradients_pass_through() {
        let mut g = vec![Tensor::zeros(&[4]), Tensor::zeros(&[2, 2])];
        let n = clip_global_norm(&mut g, 1.0);
        assert_eq!(n, 0.0);
        for t in &g {
            assert!(t.data.iter().all(|&x| x == 0.0 && x.is_finite()));
        }
    }

    #[test]
    fn clip_nonfinite_gradients_clip_to_zero() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut g = vec![
                Tensor::from_vec(&[2], vec![3.0, 4.0]),
                Tensor::from_vec(&[2], vec![bad, 1.0]),
            ];
            let n = clip_global_norm(&mut g, 1.0);
            assert!(!n.is_finite(), "norm should report the blow-up: {n}");
            for t in &g {
                assert!(
                    t.data.iter().all(|&x| x == 0.0),
                    "degenerate step must clip to zero, got {:?}",
                    t.data
                );
            }
        }
    }

    #[test]
    fn clip_spans_tensors() {
        let mut g = vec![
            Tensor::from_vec(&[1], vec![3.0]),
            Tensor::from_vec(&[1], vec![4.0]),
        ];
        let n = clip_global_norm(&mut g, 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((g[0].data[0] - 0.6).abs() < 1e-6);
        assert!((g[1].data[0] - 0.8).abs() < 1e-6);
    }
}
