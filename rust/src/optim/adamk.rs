//! The generalized low-memory Adam family (paper Eq. 2):
//!
//! ```text
//! V_{t+1} = beta2 * V_t + (1 - beta2) * E_K[G_t^2]
//! ```
//!
//! with per-tensor sharing dimensions K. V is **stored at the reduced
//! shape** — one f32 per sharing group — which is exactly where the memory
//! saving comes from. K = ∅ recovers AdamW bit-for-bit; K = (0,1) for every
//! tensor is AdaLayer; SNR-derived per-tensor K is SlimAdam; row-block K
//! (`KMode::Blocks`) expresses Adam-mini's per-head partitions.
//!
//! Group indexing works on the canonical matrix view (fan_out × fan_in)
//! without materializing it: for element `idx` of the raw tensor with
//! fan_out extent `fo` at stride `stride_fo`,
//!
//!   row(idx) = (idx / stride_fo) % fo
//!   col(idx) = (idx / (stride_fo * fo)) * stride_fo + (idx % stride_fo)
//!
//! which is O(1) per element for any fan_out_axis (2-D weights use axis 0,
//! HWIO convs axis 3).

use crate::tensor::Tensor;

use super::{Hypers, KMode, Optimizer, ParamInfo};

/// Per-tensor geometry for group indexing.
#[derive(Debug, Clone, Copy)]
struct Geom {
    fo: usize,
    cols: usize,
    stride_fo: usize,
}

impl Geom {
    fn new(info: &ParamInfo) -> Geom {
        let (fo, cols) = info.matrix_dims();
        let stride_fo: usize = info.shape[info.fan_out_axis + 1..].iter().product();
        Geom { fo, cols, stride_fo }
    }

    #[inline(always)]
    fn row(&self, idx: usize) -> usize {
        (idx / self.stride_fo) % self.fo
    }

    #[inline(always)]
    fn col(&self, idx: usize) -> usize {
        (idx / (self.stride_fo * self.fo)) * self.stride_fo + (idx % self.stride_fo)
    }
}

/// Resolve the effective K for a tensor: vectors can only be `None` or
/// `Both` (a vector is a 1-row matrix, so FanIn/FanOut degenerate).
pub fn effective_k(info: &ParamInfo, k: KMode) -> KMode {
    if info.is_vector() {
        match k {
            KMode::None => KMode::None,
            _ => KMode::Both,
        }
    } else {
        k
    }
}

/// Stored V length for a tensor under mode `k`.
pub fn v_len(info: &ParamInfo, k: KMode) -> usize {
    let (r, c) = info.matrix_dims();
    effective_k(info, k).v_elems(r, c)
}

/// Group id of raw element `idx` under mode `k` (the shared O(1) mapping
/// the optimizer, the native kernels, and the migration helpers agree on).
#[inline(always)]
fn group_of(geom: &Geom, k: KMode, idx: usize) -> usize {
    match k {
        KMode::None => idx,
        KMode::FanIn => geom.row(idx),
        KMode::FanOut => geom.col(idx),
        KMode::Both => 0,
        KMode::Blocks(n) => geom.row(idx) * n / geom.fo,
    }
}

/// Collapse a full-shape second moment to the reduced storage of mode `k`
/// by the paper's rule: each stored value is the *mean* of the full-V
/// elements in its sharing group (Eq. 2's E_K applied to V itself). This
/// is the compress half of an adaptive mode switch (DESIGN.md §18); it is
/// exact when the full V is already group-constant (e.g. right after
/// [`expand_v`]) up to the usual float-summation rounding.
pub fn collapse_v(info: &ParamInfo, k: KMode, full: &[f32]) -> Vec<f32> {
    let k = effective_k(info, k);
    if k == KMode::None {
        return full.to_vec();
    }
    let geom = Geom::new(info);
    let len = v_len(info, k);
    let mut sums = vec![0.0f64; len];
    let mut counts = vec![0u32; len];
    for (j, &vj) in full.iter().enumerate() {
        let g = group_of(&geom, k, j);
        sums[g] += vj as f64;
        counts[g] += 1;
    }
    sums.iter()
        .zip(&counts)
        .map(|(&s, &n)| (s / n.max(1) as f64) as f32)
        .collect()
}

/// Expand a reduced second moment back to the full parameter shape by
/// broadcast: every element gets its group's stored value. The decompress
/// half of an adaptive mode switch; `collapse_v(expand_v(v)) == v` up to
/// summation rounding (locked by tests below and `kernel_equivalence.rs`).
pub fn expand_v(info: &ParamInfo, k: KMode, reduced: &[f32]) -> Vec<f32> {
    let k = effective_k(info, k);
    if k == KMode::None {
        return reduced.to_vec();
    }
    let geom = Geom::new(info);
    let numel: usize = info.shape.iter().product();
    debug_assert_eq!(reduced.len(), v_len(info, k));
    (0..numel)
        .map(|j| reduced[group_of(&geom, k, j)])
        .collect()
}

pub struct AdamK {
    label: String,
    pub hypers: Hypers,
    metas: Vec<ParamInfo>,
    modes: Vec<KMode>,
    m: Vec<Tensor>,
    /// reduced-storage second moments, in matrix-view group order
    v: Vec<Vec<f32>>,
    /// reusable scratch for grouped reductions (no per-step allocation on
    /// the hot path — see EXPERIMENTS.md §Perf)
    scratch: Vec<f32>,
}

impl AdamK {
    pub fn new(
        label: impl Into<String>,
        metas: Vec<ParamInfo>,
        modes: Vec<KMode>,
        hypers: Hypers,
    ) -> AdamK {
        assert_eq!(metas.len(), modes.len());
        let modes: Vec<KMode> = metas
            .iter()
            .zip(modes)
            .map(|(info, k)| effective_k(info, k))
            .collect();
        let m = metas.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let v = metas
            .iter()
            .zip(&modes)
            .map(|(p, &k)| vec![0.0f32; v_len(p, k)])
            .collect();
        let scratch_len = metas
            .iter()
            .zip(&modes)
            .map(|(p, &k)| v_len(p, k))
            .max()
            .unwrap_or(0);
        AdamK {
            label: label.into(),
            hypers,
            metas,
            modes,
            m,
            v,
            scratch: vec![0.0; scratch_len],
        }
    }

    pub fn modes(&self) -> &[KMode] {
        &self.modes
    }

    pub fn metas(&self) -> &[ParamInfo] {
        &self.metas
    }

    /// Group id of raw element `idx` under mode `k`.
    #[inline(always)]
    fn group(geom: &Geom, k: KMode, idx: usize) -> usize {
        group_of(geom, k, idx)
    }

    fn group_size(geom: &Geom, k: KMode) -> f32 {
        match k {
            KMode::None => 1.0,
            KMode::FanIn => geom.cols as f32,
            KMode::FanOut => geom.fo as f32,
            KMode::Both => (geom.fo * geom.cols) as f32,
            KMode::Blocks(n) => ((geom.fo / n) * geom.cols) as f32,
        }
    }
}

impl Optimizer for AdamK {
    fn name(&self) -> &str {
        &self.label
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], t: usize, lr: f32) {
        let h = &self.hypers;
        let b1 = h.beta1 as f32;
        let b2 = h.beta2 as f32;
        let eps = h.eps as f32;
        let bc1 = 1.0 / (1.0 - (h.beta1 as f32).powi(t as i32));
        let bc2 = 1.0 / (1.0 - (h.beta2 as f32).powi(t as i32));

        for i in 0..params.len() {
            let info = &self.metas[i];
            let k = self.modes[i];
            let geom = Geom::new(info);
            let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
            let w = &mut params[i].data;
            let g = &grads[i].data;
            let m = &mut self.m[i].data;
            let v = &mut self.v[i];

            match k {
                KMode::None => {
                    // fused single pass (exact AdamW)
                    for j in 0..w.len() {
                        let gj = g[j];
                        m[j] = b1 * m[j] + (1.0 - b1) * gj;
                        v[j] = b2 * v[j] + (1.0 - b2) * gj * gj;
                        let mh = m[j] * bc1;
                        let vh = v[j] * bc2;
                        w[j] -= lr * (mh / (vh.sqrt() + eps) + wd * w[j]);
                    }
                }
                // Fast path: FanIn on a row-major matrix view — sharing
                // groups are contiguous rows, so the reduction and the
                // update fuse into one streaming pass per row.
                KMode::FanIn if geom.stride_fo == geom.cols => {
                    let cols = geom.cols;
                    let inv_cols = 1.0 / cols as f32;
                    for r in 0..geom.fo {
                        let lo = r * cols;
                        let hi = lo + cols;
                        let mut s = 0.0f32;
                        for &gj in &g[lo..hi] {
                            s += gj * gj;
                        }
                        let vv = b2 * v[r] + (1.0 - b2) * (s * inv_cols);
                        v[r] = vv;
                        let denom = (vv * bc2).sqrt() + eps;
                        let inv_denom = bc1 / denom;
                        for j in lo..hi {
                            let gj = g[j];
                            m[j] = b1 * m[j] + (1.0 - b1) * gj;
                            w[j] -= lr * (m[j] * inv_denom + wd * w[j]);
                        }
                    }
                }
                // Fast path: FanOut on a row-major matrix view — group id
                // is j % cols; precompute per-column denominators so the
                // update pass has no divisions.
                KMode::FanOut if geom.stride_fo == geom.cols => {
                    let cols = geom.cols;
                    let inv_rows = 1.0 / geom.fo as f32;
                    let sums = &mut self.scratch[..cols];
                    sums.fill(0.0);
                    let mut c = 0usize;
                    for &gj in g.iter() {
                        sums[c] += gj * gj;
                        c += 1;
                        if c == cols {
                            c = 0;
                        }
                    }
                    for (vi, s) in v.iter_mut().zip(sums.iter()) {
                        *vi = b2 * *vi + (1.0 - b2) * (s * inv_rows);
                    }
                    // reuse scratch as per-column bc1/denom
                    for (s, &vi) in sums.iter_mut().zip(v.iter()) {
                        *s = bc1 / ((vi * bc2).sqrt() + eps);
                    }
                    let mut c = 0usize;
                    for j in 0..w.len() {
                        let gj = g[j];
                        m[j] = b1 * m[j] + (1.0 - b1) * gj;
                        w[j] -= lr * (m[j] * sums[c] + wd * w[j]);
                        c += 1;
                        if c == cols {
                            c = 0;
                        }
                    }
                }
                // Fast path: Both — one scalar group, fully fused.
                KMode::Both => {
                    let mut s = 0.0f32;
                    for &gj in g.iter() {
                        s += gj * gj;
                    }
                    let vv = b2 * v[0] + (1.0 - b2) * (s / g.len() as f32);
                    v[0] = vv;
                    let inv_denom = bc1 / ((vv * bc2).sqrt() + eps);
                    for j in 0..w.len() {
                        let gj = g[j];
                        m[j] = b1 * m[j] + (1.0 - b1) * gj;
                        w[j] -= lr * (m[j] * inv_denom + wd * w[j]);
                    }
                }
                // Generic path (conv fan_out_axis != 0, Blocks): two passes
                // with O(1) group indexing.
                _ => {
                    let gsize = Self::group_size(&geom, k);
                    let sums = &mut self.scratch[..v.len()];
                    sums.fill(0.0);
                    for (j, &gj) in g.iter().enumerate() {
                        sums[Self::group(&geom, k, j)] += gj * gj;
                    }
                    for (vi, s) in v.iter_mut().zip(sums.iter()) {
                        *vi = b2 * *vi + (1.0 - b2) * (s / gsize);
                    }
                    for j in 0..w.len() {
                        let gj = g[j];
                        m[j] = b1 * m[j] + (1.0 - b1) * gj;
                        let mh = m[j] * bc1;
                        let vh = v[Self::group(&geom, k, j)] * bc2;
                        w[j] -= lr * (mh / (vh.sqrt() + eps) + wd * w[j]);
                    }
                }
            }
        }
    }

    fn second_moment(&self, i: usize) -> Option<Tensor> {
        let info = &self.metas[i];
        let k = self.modes[i];
        let geom = Geom::new(info);
        let v = &self.v[i];
        let mut full = Tensor::zeros(&info.shape);
        for j in 0..full.data.len() {
            full.data[j] = v[Self::group(&geom, k, j)];
        }
        Some(full)
    }

    fn second_moment_elems(&self) -> usize {
        self.v.iter().map(|v| v.len()).sum()
    }

    fn first_moment_elems(&self) -> usize {
        self.m.iter().map(|m| m.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn info(name: &str, shape: &[usize], fan_out_axis: usize) -> ParamInfo {
        ParamInfo {
            name: name.into(),
            shape: shape.to_vec(),
            layer_type: "attn_q".into(),
            depth: 0,
            init_mitchell: Init::Normal { std: 0.02 },
            init_default: Init::Normal { std: 0.02 },
            wd: true,
            fan_out_axis,
        }
    }

    fn hypers0() -> Hypers {
        Hypers {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.0,
            clip_norm: 1.0,
        }
    }

    /// Brute-force reference: full V EMA of grouped means.
    fn ref_update(
        w: &mut [f32],
        m: &mut [f32],
        v_full: &mut [f32],
        g: &[f32],
        rows: usize,
        cols: usize,
        k: KMode,
        h: &Hypers,
        t: usize,
        lr: f32,
    ) {
        let b1 = h.beta1 as f32;
        let b2 = h.beta2 as f32;
        let eps = h.eps as f32;
        // grouped mean of g^2, broadcast to full
        let mut ek = vec![0.0f32; g.len()];
        match k {
            KMode::None => {
                for j in 0..g.len() {
                    ek[j] = g[j] * g[j];
                }
            }
            KMode::FanIn => {
                for r in 0..rows {
                    let mean: f32 = (0..cols).map(|c| g[r * cols + c].powi(2)).sum::<f32>()
                        / cols as f32;
                    for c in 0..cols {
                        ek[r * cols + c] = mean;
                    }
                }
            }
            KMode::FanOut => {
                for c in 0..cols {
                    let mean: f32 = (0..rows).map(|r| g[r * cols + c].powi(2)).sum::<f32>()
                        / rows as f32;
                    for r in 0..rows {
                        ek[r * cols + c] = mean;
                    }
                }
            }
            KMode::Both => {
                let mean: f32 =
                    g.iter().map(|x| x * x).sum::<f32>() / g.len() as f32;
                ek.fill(mean);
            }
            KMode::Blocks(n) => {
                let rows_per = rows / n;
                for b in 0..n {
                    let mut s = 0.0f32;
                    for r in b * rows_per..(b + 1) * rows_per {
                        for c in 0..cols {
                            s += g[r * cols + c].powi(2);
                        }
                    }
                    let mean = s / (rows_per * cols) as f32;
                    for r in b * rows_per..(b + 1) * rows_per {
                        for c in 0..cols {
                            ek[r * cols + c] = mean;
                        }
                    }
                }
            }
        }
        let bc1 = 1.0 / (1.0 - b1.powi(t as i32));
        let bc2 = 1.0 / (1.0 - b2.powi(t as i32));
        for j in 0..w.len() {
            m[j] = b1 * m[j] + (1.0 - b1) * g[j];
            v_full[j] = b2 * v_full[j] + (1.0 - b2) * ek[j];
            w[j] -= lr * (m[j] * bc1) / ((v_full[j] * bc2).sqrt() + eps);
        }
    }

    #[test]
    fn matches_reference_all_modes() {
        let rows = 6;
        let cols = 8;
        let h = hypers0();
        for k in [
            KMode::None,
            KMode::FanIn,
            KMode::FanOut,
            KMode::Both,
            KMode::Blocks(2),
        ] {
            let meta = info("w", &[rows, cols], 0);
            let mut opt = AdamK::new("t", vec![meta], vec![k], h);
            let mut rng = crate::rng::Rng::new(9);
            let mut w = Tensor::from_vec(
                &[rows, cols],
                (0..rows * cols).map(|_| rng.normal() as f32).collect(),
            );
            let mut w_ref = w.data.clone();
            let mut m_ref = vec![0.0f32; rows * cols];
            let mut v_ref = vec![0.0f32; rows * cols];
            for t in 1..=4 {
                let g = Tensor::from_vec(
                    &[rows, cols],
                    (0..rows * cols).map(|_| rng.normal() as f32).collect(),
                );
                ref_update(
                    &mut w_ref, &mut m_ref, &mut v_ref, &g.data, rows, cols, k,
                    &h, t, 1e-2,
                );
                let mut params = vec![w.clone()];
                opt.step(&mut params, &[g], t, 1e-2);
                w = params.pop().unwrap();
                for (a, b) in w.data.iter().zip(&w_ref) {
                    assert!(
                        (a - b).abs() <= 1e-6 + 1e-5 * b.abs(),
                        "K={k:?} t={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn conv_fan_out_axis_grouping() {
        // HWIO (1,1,2,3): fan_out_axis=3 -> rows=3(o), cols=2(i).
        let meta = info("c", &[1, 1, 2, 3], 3);
        let h = hypers0();
        let mut opt = AdamK::new("t", vec![meta], vec![KMode::FanIn], h);
        // g laid out [i0o0, i0o1, i0o2, i1o0, i1o1, i1o2]
        let g = Tensor::from_vec(&[1, 1, 2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut params = vec![Tensor::zeros(&[1, 1, 2, 3])];
        opt.step(&mut params, &[g], 1, 0.0);
        // V per output channel o: mean over i of g^2:
        // o0: (1+16)/2, o1: (4+25)/2, o2: (9+36)/2, scaled by (1-beta2)
        let v = opt.second_moment(0).unwrap();
        let scale = 1.0 - 0.95;
        assert!((v.data[0] - scale * 8.5).abs() < 1e-5); // (i0,o0)
        assert!((v.data[3] - scale * 8.5).abs() < 1e-5); // (i1,o0) same group
        assert!((v.data[1] - scale * 14.5).abs() < 1e-5); // o1
        assert!((v.data[5] - scale * 22.5).abs() < 1e-5); // o2
    }

    #[test]
    fn vector_k_degenerates_to_both() {
        let meta = ParamInfo {
            shape: vec![8],
            ..info("ln", &[8], 0)
        };
        let opt = AdamK::new("t", vec![meta], vec![KMode::FanOut], hypers0());
        assert_eq!(opt.modes()[0], KMode::Both);
        assert_eq!(opt.second_moment_elems(), 1);
    }

    #[test]
    fn memory_accounting() {
        let metas = vec![info("a", &[4, 8], 0), info("b", &[16], 0)];
        let adam = AdamK::new(
            "adam",
            metas.clone(),
            vec![KMode::None, KMode::None],
            hypers0(),
        );
        assert_eq!(adam.second_moment_elems(), 32 + 16);
        let slim = AdamK::new(
            "slim",
            metas,
            vec![KMode::FanIn, KMode::None],
            hypers0(),
        );
        assert_eq!(slim.second_moment_elems(), 4 + 16);
    }

    #[test]
    fn second_moment_broadcast_shape() {
        let meta = info("w", &[4, 6], 0);
        let mut opt = AdamK::new("t", vec![meta], vec![KMode::FanOut], hypers0());
        let g = Tensor::ones(&[4, 6]);
        let mut p = vec![Tensor::zeros(&[4, 6])];
        opt.step(&mut p, &[g], 1, 1e-3);
        let v = opt.second_moment(0).unwrap();
        assert_eq!(v.shape, vec![4, 6]);
        // all-ones grads: every group mean is 1 * (1-b2)
        for &x in &v.data {
            assert!((x - 0.05).abs() < 1e-6);
        }
    }

    #[test]
    fn expand_then_collapse_is_identity() {
        // expanded V is group-constant, so collapsing it back is exact up
        // to summation rounding — including degenerate 1×N / N×1 shapes
        let mut rng = crate::rng::Rng::new(3);
        for shape in [&[6usize, 8][..], &[1, 8], &[8, 1], &[1, 1]] {
            let meta = info("w", shape, 0);
            for k in [KMode::FanIn, KMode::FanOut, KMode::Both, KMode::Blocks(2)] {
                if let KMode::Blocks(n) = k {
                    // Blocks stores `n` slots regardless of rows; with
                    // fewer rows than blocks some slots are unreachable
                    // and round-tripping them is meaningless
                    if shape[0] < n {
                        continue;
                    }
                }
                let reduced: Vec<f32> =
                    (0..v_len(&meta, k)).map(|_| rng.normal().abs() as f32).collect();
                let full = expand_v(&meta, k, &reduced);
                assert_eq!(full.len(), shape.iter().product::<usize>());
                let back = collapse_v(&meta, k, &full);
                assert_eq!(back.len(), reduced.len(), "shape {shape:?} K={k:?}");
                for (a, b) in back.iter().zip(&reduced) {
                    assert!(
                        (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                        "shape {shape:?} K={k:?}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn collapse_matches_group_means() {
        // 2×3 fan_in: stored value per row = mean of the row
        let meta = info("w", &[2, 3], 0);
        let full = vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0];
        let red = collapse_v(&meta, KMode::FanIn, &full);
        assert_eq!(red.len(), 2);
        assert!((red[0] - 2.0).abs() < 1e-6);
        assert!((red[1] - 20.0).abs() < 1e-6);
        // fan_out: per column = mean over rows
        let red = collapse_v(&meta, KMode::FanOut, &full);
        assert_eq!(red.len(), 3);
        assert!((red[0] - 5.5).abs() < 1e-6);
        // both: global mean
        let red = collapse_v(&meta, KMode::Both, &full);
        assert_eq!(red, vec![11.0]);
        // None: identity
        assert_eq!(collapse_v(&meta, KMode::None, &full), full);
        assert_eq!(expand_v(&meta, KMode::None, &full), full);
    }

    #[test]
    fn migration_respects_conv_fan_out_axis() {
        // HWIO (1,1,2,3), fan_out_axis=3: fan_in groups one V per output
        // channel o, elements laid out [i0o0 i0o1 i0o2 i1o0 i1o1 i1o2]
        let meta = info("c", &[1, 1, 2, 3], 3);
        let full = vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0];
        let red = collapse_v(&meta, KMode::FanIn, &full);
        assert_eq!(red.len(), 3);
        assert!((red[0] - 3.0).abs() < 1e-6); // mean(1, 5)
        assert!((red[1] - 4.0).abs() < 1e-6); // mean(2, 6)
        assert!((red[2] - 5.0).abs() < 1e-6); // mean(3, 7)
        let back = expand_v(&meta, KMode::FanIn, &red);
        assert_eq!(back, vec![3.0, 4.0, 5.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn vector_migration_degenerates_to_both() {
        let meta = info("ln", &[8], 0);
        let red = collapse_v(&meta, KMode::FanOut, &[2.0; 8]);
        assert_eq!(red, vec![2.0]); // effective K = Both
        assert_eq!(expand_v(&meta, KMode::FanOut, &red), vec![2.0; 8]);
    }

    #[test]
    fn property_v_nonnegative_and_none_equals_adamw() {
        crate::proptest::check(25, |gen| {
            let rows = gen.usize(1, 12);
            let cols = gen.usize(1, 12);
            let k = *gen.choice(&[KMode::None, KMode::FanIn, KMode::FanOut, KMode::Both]);
            let meta = info("w", &[rows, cols], 0);
            let mut opt = AdamK::new("p", vec![meta], vec![k], hypers0());
            let mut params = vec![Tensor::from_vec(
                &[rows, cols],
                gen.vec_normal(rows * cols, 1.0),
            )];
            for t in 1..=3 {
                let g = Tensor::from_vec(&[rows, cols], gen.vec_normal(rows * cols, 1.0));
                opt.step(&mut params, &[g], t, 1e-3);
            }
            let v = opt.second_moment(0).unwrap();
            crate::proptest::prop_assert(
                v.data.iter().all(|&x| x >= 0.0),
                "V must be nonnegative",
            )?;
            crate::proptest::prop_assert(
                params[0].data.iter().all(|x| x.is_finite()),
                "weights must stay finite",
            )
        });
    }
}
