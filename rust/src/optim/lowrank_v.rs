//! Low-rank factored second moments in the Adapprox spirit
//! (arXiv 2403.14958): instead of Adafactor's single rank-1 outer
//! product `R·Cᵀ/sum(R)`, keep `r` independent column buckets, each
//! with its own per-row accumulator — a rank-`r` sketch of V.
//!
//! Columns of the matrix view are assigned to buckets by a
//! *deterministic seeded sketch*: bucket(j) is a pure hash of
//! `(param name, rank, j)`, so the partition is reproducible across
//! runs, processes, and backends without storing it.
//!
//! ```text
//! b(j)    = H(name, r, j) mod r                   (fixed partition)
//! Y[i,b] += EMA_beta2 of sum_{j in b} (g_ij^2 + eps1)   (rows x r)
//! C[j]   += EMA_beta2 of sum_i (g_ij^2 + eps1)          (cols)
//! v_ij    = Y[i,b(j)] * C[j] / sum_{j' in b(j)} C[j']
//! ```
//!
//! The update itself is AdamW-shaped: full first moment, bias-corrected
//! `m/(sqrt(v)+eps)`, decoupled weight decay. `r = 1` collapses to
//! Adafactor's factorization (plus momentum and bias correction);
//! growing `r` towards the column count interpolates back to per-column
//! resolution. Vector parameters keep exact per-element moments.

use crate::tensor::Tensor;

use super::{raw_index, Hypers, Optimizer, ParamInfo};

/// Small epsilon added inside g² (Adafactor's epsilon_1) so all-zero
/// gradients keep the factored reconstruction well-defined.
const EPS1: f32 = 1e-30;

/// Default sketch rank (the CLI token `lowrank_v` without a suffix).
pub const DEFAULT_RANK: usize = 4;

/// Deterministic column→bucket assignment: a pure function of the
/// parameter name, the sketch rank, and the column index. The native
/// fused kernel uses the same function, so split and fused runs agree
/// on the partition by construction.
pub fn bucket_of(name: &str, rank: usize, col: usize) -> usize {
    let key = format!("lowrank_v|{name}|{rank}|{col}");
    (crate::rng::stable_hash64(key.as_bytes()) % rank as u64) as usize
}

/// Canonical optimizer token for a given rank (`lowrank_v` for the
/// default, `lowrank_v<r>` otherwise).
pub fn token(rank: usize) -> String {
    if rank == DEFAULT_RANK {
        "lowrank_v".to_string()
    } else {
        format!("lowrank_v{rank}")
    }
}

/// Parse a `lowrank_v` / `lowrank_v<r>` token into its rank.
pub fn parse_token(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("lowrank_v")?;
    if rest.is_empty() {
        Some(DEFAULT_RANK)
    } else {
        rest.parse::<usize>().ok().filter(|&r| r >= 1)
    }
}

pub struct LowRankV {
    metas: Vec<ParamInfo>,
    name: String,
    rank: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    state: Vec<Sketch>,
    m: Vec<Tensor>,
}

enum Sketch {
    /// `y` is rows x rank (row-major), `c` is per-column; `buckets[j]`
    /// caches `bucket_of` for each view column.
    Factored {
        y: Vec<f32>,
        c: Vec<f32>,
        buckets: Vec<usize>,
        rows: usize,
        cols: usize,
    },
    Exact(Vec<f32>),
}

impl LowRankV {
    pub fn new(metas: Vec<ParamInfo>, rank: usize, hypers: Hypers) -> LowRankV {
        assert!(rank >= 1, "lowrank_v rank must be >= 1");
        let state = metas
            .iter()
            .map(|p| {
                let (rows, cols) = p.matrix_dims();
                if p.is_vector() {
                    Sketch::Exact(vec![0.0; p.numel()])
                } else {
                    let buckets =
                        (0..cols).map(|j| bucket_of(&p.name, rank, j)).collect();
                    Sketch::Factored {
                        y: vec![0.0; rows * rank],
                        c: vec![0.0; cols],
                        buckets,
                        rows,
                        cols,
                    }
                }
            })
            .collect();
        let m = metas.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        LowRankV {
            name: token(rank),
            metas,
            rank,
            beta1: hypers.beta1 as f32,
            beta2: hypers.beta2 as f32,
            eps: hypers.eps as f32,
            weight_decay: hypers.weight_decay as f32,
            state,
            m,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }
}

impl Optimizer for LowRankV {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], t: usize, lr: f32) {
        let bc1 = 1.0 / (1.0 - self.beta1.powi(t as i32));
        let bc2 = 1.0 / (1.0 - self.beta2.powi(t as i32));
        for i in 0..params.len() {
            let info = &self.metas[i];
            let wd = if info.wd { self.weight_decay } else { 0.0 };
            let w = &mut params[i].data;
            let m = &mut self.m[i].data;
            match &mut self.state[i] {
                Sketch::Exact(v) => {
                    let g = &grads[i].data;
                    for j in 0..w.len() {
                        m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * g[j];
                        v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * g[j] * g[j];
                        let mh = m[j] * bc1;
                        let vh = v[j] * bc2;
                        w[j] -= lr * (mh / (vh.sqrt() + self.eps) + wd * w[j]);
                    }
                }
                Sketch::Factored { y, c, buckets, rows, cols } => {
                    let gmat = grads[i].matrix_view(info.fan_out_axis);
                    let (rows, cols) = (*rows, *cols);
                    let rank = self.rank;
                    // bucketed row sums and column sums of g^2
                    let mut ysum = vec![0.0f32; rows * rank];
                    let mut csum = vec![0.0f32; cols];
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let g2 = gmat.at(ri, ci).powi(2) + EPS1;
                            ysum[ri * rank + buckets[ci]] += g2;
                            csum[ci] += g2;
                        }
                    }
                    for (yk, s) in y.iter_mut().zip(&ysum) {
                        *yk = self.beta2 * *yk + (1.0 - self.beta2) * s;
                    }
                    for (ck, s) in c.iter_mut().zip(&csum) {
                        *ck = self.beta2 * *ck + (1.0 - self.beta2) * s;
                    }
                    // per-bucket column-mass normalizers
                    let mut bsum = vec![0.0f32; rank];
                    for ci in 0..cols {
                        bsum[buckets[ci]] += c[ci];
                    }
                    let is_borrowed =
                        matches!(gmat.data, std::borrow::Cow::Borrowed(_));
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let b = buckets[ci];
                            let v = (y[ri * rank + b] * c[ci]
                                / bsum[b].max(EPS1))
                            .max(EPS1);
                            let raw = if is_borrowed {
                                ri * cols + ci
                            } else {
                                raw_index(info, ri, ci)
                            };
                            let g = gmat.at(ri, ci);
                            m[raw] = self.beta1 * m[raw]
                                + (1.0 - self.beta1) * g;
                            let mh = m[raw] * bc1;
                            let vh = v * bc2;
                            w[raw] -= lr
                                * (mh / (vh.sqrt() + self.eps) + wd * w[raw]);
                        }
                    }
                }
            }
        }
    }

    fn second_moment(&self, i: usize) -> Option<Tensor> {
        let info = &self.metas[i];
        match &self.state[i] {
            Sketch::Exact(v) => Some(Tensor::from_vec(&info.shape, v.clone())),
            Sketch::Factored { y, c, buckets, rows, cols } => {
                let rank = self.rank;
                let mut bsum = vec![0.0f32; rank];
                for ci in 0..*cols {
                    bsum[buckets[ci]] += c[ci];
                }
                let mut full = Tensor::zeros(&info.shape);
                for ri in 0..*rows {
                    for ci in 0..*cols {
                        let b = buckets[ci];
                        let raw = if info.shape.len() <= 2 {
                            ri * cols + ci
                        } else {
                            raw_index(info, ri, ci)
                        };
                        full.data[raw] =
                            y[ri * rank + b] * c[ci] / bsum[b].max(EPS1);
                    }
                }
                Some(full)
            }
        }
    }

    fn second_moment_elems(&self) -> usize {
        self.state
            .iter()
            .map(|s| match s {
                Sketch::Exact(v) => v.len(),
                Sketch::Factored { y, c, .. } => y.len() + c.len(),
            })
            .sum()
    }

    fn first_moment_elems(&self) -> usize {
        self.m.iter().map(|m| m.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: false,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn token_roundtrip() {
        assert_eq!(parse_token("lowrank_v"), Some(DEFAULT_RANK));
        assert_eq!(parse_token("lowrank_v1"), Some(1));
        assert_eq!(parse_token("lowrank_v8"), Some(8));
        assert_eq!(parse_token("lowrank_v0"), None);
        assert_eq!(parse_token("lowrank"), None);
        assert_eq!(token(DEFAULT_RANK), "lowrank_v");
        assert_eq!(token(8), "lowrank_v8");
    }

    #[test]
    fn bucket_assignment_is_deterministic_and_covers() {
        let a: Vec<usize> = (0..64).map(|j| bucket_of("h0.mlp_up", 4, j)).collect();
        let b: Vec<usize> = (0..64).map(|j| bucket_of("h0.mlp_up", 4, j)).collect();
        assert_eq!(a, b, "sketch must be a pure function of (name, rank, col)");
        assert!(a.iter().all(|&x| x < 4));
        // with 64 columns over 4 buckets, every bucket should be hit
        for bucket in 0..4 {
            assert!(a.contains(&bucket), "bucket {bucket} empty");
        }
        // different parameter names get different partitions
        let other: Vec<usize> =
            (0..64).map(|j| bucket_of("h1.mlp_dn", 4, j)).collect();
        assert_ne!(a, other);
    }

    #[test]
    fn memory_is_rank_linear() {
        let opt = LowRankV::new(vec![meta(&[32, 64])], 4, Hypers::default());
        assert_eq!(opt.second_moment_elems(), 32 * 4 + 64);
        assert_eq!(opt.first_moment_elems(), 32 * 64);
        let opt1 = LowRankV::new(vec![meta(&[32, 64])], 1, Hypers::default());
        assert_eq!(opt1.second_moment_elems(), 32 + 64);
    }

    #[test]
    fn same_seed_same_trajectory() {
        let run = || {
            let mut opt =
                LowRankV::new(vec![meta(&[8, 8]), meta(&[8])], 4, Hypers::default());
            let mut rng = crate::rng::Rng::new(7);
            let mut p = vec![
                Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect()),
                Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
            ];
            for t in 1..=10 {
                let g = vec![
                    Tensor::from_vec(
                        &[8, 8],
                        (0..64).map(|_| rng.normal() as f32).collect(),
                    ),
                    Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
                ];
                opt.step(&mut p, &g, t, 1e-2);
            }
            let mut bits: Vec<u32> = Vec::new();
            for t in &p {
                bits.extend(t.data.iter().map(|x| x.to_bits()));
            }
            bits
        };
        assert_eq!(run(), run(), "same seed must give bit-identical params");
    }

    #[test]
    fn rank_one_matches_factored_structure() {
        // rank-1 gradients: g = a b^T means g^2 is rank-1, so the r=1
        // sketch reconstructs it exactly up to global scale.
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 1.0, 2.0];
        let mut g = Tensor::zeros(&[2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                g.data[i * 3 + j] = a[i] * b[j];
            }
        }
        let mut opt = LowRankV::new(vec![meta(&[2, 3])], 1, Hypers::default());
        let mut p = vec![Tensor::zeros(&[2, 3])];
        opt.step(&mut p, &[g.clone()], 1, 0.0);
        let v = opt.second_moment(0).unwrap();
        let g2: Vec<f32> = g.data.iter().map(|x| x * x).collect();
        let ratio0 = v.data[0] / g2[0];
        for j in 1..6 {
            let r = v.data[j] / g2[j];
            assert!((r - ratio0).abs() / ratio0 < 1e-3, "{r} vs {ratio0}");
        }
    }

    #[test]
    fn stays_finite_over_steps() {
        let mut opt =
            LowRankV::new(vec![meta(&[8, 8]), meta(&[8])], 4, Hypers::default());
        let mut rng = crate::rng::Rng::new(2);
        let mut p = vec![
            Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect()),
            Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
        ];
        for t in 1..=30 {
            let g = vec![
                Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect()),
                Tensor::from_vec(&[8], (0..8).map(|_| rng.normal() as f32).collect()),
            ];
            opt.step(&mut p, &g, t, 1e-2);
        }
        assert!(p[0].data.iter().all(|x| x.is_finite()));
        assert!(p[1].data.iter().all(|x| x.is_finite()));
    }
}
