//! Lion (Chen et al. 2023): momentum-only, sign-based updates. One of the
//! Fig. 1 baselines whose LR-sensitivity curve deviates substantially from
//! Adam's (it is a genuinely different algorithm, not an Adam compression).
//!
//! ```text
//! u   = sign(beta1 * m + (1 - beta1) * g)
//! w  -= lr * (u + wd * w)
//! m   = beta2 * m + (1 - beta2) * g
//! ```

use crate::tensor::Tensor;

use super::{Optimizer, ParamInfo};

pub struct Lion {
    metas: Vec<ParamInfo>,
    beta1: f32,
    beta2: f32,
    weight_decay: f32,
    m: Vec<Tensor>,
}

impl Lion {
    /// Paper App. A: beta1 = 0.9, beta2 = 0.95 works best for GPT
    /// pre-training; weight decay 0.1.
    pub fn new(metas: Vec<ParamInfo>, beta1: f64, beta2: f64, weight_decay: f64) -> Lion {
        let m = metas.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Lion {
            metas,
            beta1: beta1 as f32,
            beta2: beta2 as f32,
            weight_decay: weight_decay as f32,
            m,
        }
    }
}

impl Optimizer for Lion {
    fn name(&self) -> &str {
        "lion"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], _t: usize, lr: f32) {
        for i in 0..params.len() {
            let wd = if self.metas[i].wd { self.weight_decay } else { 0.0 };
            let w = &mut params[i].data;
            let g = &grads[i].data;
            let m = &mut self.m[i].data;
            for j in 0..w.len() {
                let interp = self.beta1 * m[j] + (1.0 - self.beta1) * g[j];
                let u = if interp > 0.0 {
                    1.0
                } else if interp < 0.0 {
                    -1.0
                } else {
                    0.0
                };
                w[j] -= lr * (u + wd * w[j]);
                m[j] = self.beta2 * m[j] + (1.0 - self.beta2) * g[j];
            }
        }
    }

    fn second_moment(&self, _i: usize) -> Option<Tensor> {
        None
    }

    fn second_moment_elems(&self) -> usize {
        0
    }

    fn first_moment_elems(&self) -> usize {
        self.m.iter().map(|m| m.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize], wd: bool) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn updates_are_sign_sized() {
        let mut opt = Lion::new(vec![meta(&[3], false)], 0.9, 0.95, 0.0);
        let mut p = vec![Tensor::zeros(&[3])];
        let g = Tensor::from_vec(&[3], vec![0.7, -123.0, 0.0]);
        opt.step(&mut p, &[g], 1, 0.01);
        assert!((p[0].data[0] + 0.01).abs() < 1e-7); // -lr * sign(+)
        assert!((p[0].data[1] - 0.01).abs() < 1e-7); // -lr * sign(-)
        assert_eq!(p[0].data[2], 0.0); // sign(0) = 0
    }

    #[test]
    fn momentum_drives_interpolation() {
        let mut opt = Lion::new(vec![meta(&[1], false)], 0.9, 0.95, 0.0);
        let mut p = vec![Tensor::zeros(&[1])];
        // build +momentum, then a small negative gradient should still give
        // a positive update through the beta1 interpolation
        opt.step(&mut p, &[Tensor::from_vec(&[1], vec![10.0])], 1, 0.0);
        let before = p[0].data[0];
        opt.step(&mut p, &[Tensor::from_vec(&[1], vec![-0.01])], 2, 0.01);
        assert!(p[0].data[0] < before); // update was positive-signed: w -= lr
    }

    #[test]
    fn decoupled_weight_decay() {
        let mut opt = Lion::new(vec![meta(&[1], true)], 0.9, 0.95, 0.1);
        let mut p = vec![Tensor::from_vec(&[1], vec![1.0])];
        opt.step(&mut p, &[Tensor::zeros(&[1])], 1, 0.01);
        // u = 0, so w -= lr * wd * w = 0.001
        assert!((p[0].data[0] - 0.999).abs() < 1e-7);
    }

    #[test]
    fn no_second_moment_memory() {
        let opt = Lion::new(vec![meta(&[8, 8], true)], 0.9, 0.95, 0.1);
        assert_eq!(opt.second_moment_elems(), 0);
        assert_eq!(opt.first_moment_elems(), 64);
    }
}
