//! SM3 (Anil et al. 2019) — memory-efficient adaptive optimization via
//! cover sets. For a matrix parameter the cover sets are rows and columns:
//! the optimizer stores one accumulator per row and one per column
//! (O(r + c) instead of O(r·c)) and reconstructs a per-parameter second
//! moment as the min over the sets containing it:
//!
//! ```text
//! nu_ij  = beta * min(mu_row[i], mu_col[j]) + (1 - beta) * g_ij^2
//! mu_row[i] = max_j nu_ij      mu_col[j] = max_i nu_ij
//! ```
//!
//! (beta = 0 recovers the paper's additive Adagrad-style variant; the
//! paper's App. A finds beta = 0.95 best for GPT pre-training.) Vectors
//! keep exact per-element accumulators. A momentum buffer smooths the
//! preconditioned gradient as in the reference PyTorch-SM3 implementation.

use crate::tensor::Tensor;

use super::{Optimizer, ParamInfo};

pub struct Sm3 {
    metas: Vec<ParamInfo>,
    beta: f32,
    momentum: f32,
    eps: f32,
    weight_decay: f32,
    /// per-param accumulators: matrices -> (row, col); vectors -> exact
    acc: Vec<Acc>,
    buf: Vec<Tensor>,
}

enum Acc {
    Factored { rows: Vec<f32>, cols: Vec<f32>, r: usize, c: usize },
    Exact(Vec<f32>),
}

impl Sm3 {
    pub fn new(
        metas: Vec<ParamInfo>,
        beta: f64,
        momentum: f64,
        weight_decay: f64,
    ) -> Sm3 {
        let acc = metas
            .iter()
            .map(|p| {
                let (r, c) = p.matrix_dims();
                if p.is_vector() {
                    Acc::Exact(vec![0.0; p.numel()])
                } else {
                    Acc::Factored {
                        rows: vec![0.0; r],
                        cols: vec![0.0; c],
                        r,
                        c,
                    }
                }
            })
            .collect();
        let buf = metas.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Sm3 {
            metas,
            beta: beta as f32,
            momentum: momentum as f32,
            eps: 1e-8,
            weight_decay: weight_decay as f32,
            acc,
            buf,
        }
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> &str {
        "sm3"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], _t: usize, lr: f32) {
        for i in 0..params.len() {
            let info = &self.metas[i];
            let wd = if info.wd { self.weight_decay } else { 0.0 };
            let w = &mut params[i].data;
            let gmat = grads[i].matrix_view(info.fan_out_axis);
            let buf = &mut self.buf[i].data;
            match &mut self.acc[i] {
                Acc::Exact(v) => {
                    let g = &grads[i].data;
                    for j in 0..w.len() {
                        v[j] = self.beta * v[j] + (1.0 - self.beta) * g[j] * g[j];
                        let pg = g[j] / (v[j].sqrt() + self.eps);
                        buf[j] = self.momentum * buf[j] + (1.0 - self.momentum) * pg;
                        w[j] -= lr * (buf[j] + wd * w[j]);
                    }
                }
                Acc::Factored { rows, cols, r, c } => {
                    // The matrix view may be a permuted copy for conv
                    // tensors; we update through the view's layout and map
                    // indices back (2-D weights are the common, zero-copy
                    // case where view index == raw index).
                    let (r, c) = (*r, *c);
                    let mut new_rows = vec![0.0f32; r];
                    let mut new_cols = vec![0.0f32; c];
                    // nu and the weight update
                    let is_borrowed =
                        matches!(gmat.data, std::borrow::Cow::Borrowed(_));
                    for ri in 0..r {
                        for ci in 0..c {
                            let g = gmat.at(ri, ci);
                            let nu = self.beta * rows[ri].min(cols[ci])
                                + (1.0 - self.beta) * g * g;
                            new_rows[ri] = new_rows[ri].max(nu);
                            new_cols[ci] = new_cols[ci].max(nu);
                            let pg = g / (nu.sqrt() + self.eps);
                            // map view (ri,ci) back to raw index
                            let raw = if is_borrowed {
                                ri * c + ci
                            } else {
                                raw_index(&self.metas[i], ri, ci)
                            };
                            buf[raw] = self.momentum * buf[raw]
                                + (1.0 - self.momentum) * pg;
                            w[raw] -= lr * (buf[raw] + wd * w[raw]);
                        }
                    }
                    *rows = new_rows;
                    *cols = new_cols;
                }
            }
        }
    }

    fn second_moment(&self, i: usize) -> Option<Tensor> {
        // SM3's implied second moment: min(mu_row, mu_col) reconstruction.
        let info = &self.metas[i];
        match &self.acc[i] {
            Acc::Exact(v) => Some(Tensor::from_vec(&info.shape, v.clone())),
            Acc::Factored { rows, cols, r, c } => {
                let mut full = Tensor::zeros(&info.shape);
                for ri in 0..*r {
                    for ci in 0..*c {
                        let raw = if info.shape.len() <= 2 {
                            ri * c + ci
                        } else {
                            raw_index(info, ri, ci)
                        };
                        full.data[raw] = rows[ri].min(cols[ci]);
                    }
                }
                Some(full)
            }
        }
    }

    fn second_moment_elems(&self) -> usize {
        self.acc
            .iter()
            .map(|a| match a {
                Acc::Exact(v) => v.len(),
                Acc::Factored { rows, cols, .. } => rows.len() + cols.len(),
            })
            .sum()
    }

    fn first_moment_elems(&self) -> usize {
        self.buf.iter().map(|b| b.numel()).sum()
    }
}

use super::raw_index;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: false,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn accumulator_memory_is_sublinear() {
        let opt = Sm3::new(vec![meta(&[64, 128])], 0.95, 0.9, 0.0);
        assert_eq!(opt.second_moment_elems(), 64 + 128);
    }

    #[test]
    fn vector_is_exact() {
        let opt = Sm3::new(vec![meta(&[10])], 0.95, 0.9, 0.0);
        assert_eq!(opt.second_moment_elems(), 10);
    }

    #[test]
    fn uniform_grads_behave_like_adagrad_cell() {
        // With beta=0 and constant gradient 1 everywhere, nu = 1 after one
        // step; mu_row = mu_col = 1; implied v = 1.
        let mut opt = Sm3::new(vec![meta(&[4, 4])], 0.0, 0.0, 0.0);
        let mut p = vec![Tensor::zeros(&[4, 4])];
        opt.step(&mut p, &[Tensor::ones(&[4, 4])], 1, 0.1);
        let v = opt.second_moment(0).unwrap();
        for &x in &v.data {
            assert!((x - 1.0).abs() < 1e-6);
        }
        // update = g / sqrt(nu) = 1 -> w = -0.1
        for &x in &p[0].data {
            assert!((x + 0.1).abs() < 1e-6);
        }
    }

    #[test]
    fn min_cover_bounds_second_moment() {
        // One hot row: row accumulator large only for that row; implied v
        // for other rows stays small (the min over covers).
        let mut opt = Sm3::new(vec![meta(&[3, 3])], 0.0, 0.0, 0.0);
        let mut g = Tensor::zeros(&[3, 3]);
        for c in 0..3 {
            g.data[c] = 10.0; // row 0 hot
        }
        let mut p = vec![Tensor::zeros(&[3, 3])];
        opt.step(&mut p, &[g], 1, 0.0);
        let v = opt.second_moment(0).unwrap();
        assert!(v.data[0] >= 99.0); // row 0
        assert!(v.data[4] <= 1e-6); // row 1, col 1 never saw gradient
    }

    #[test]
    fn steps_stay_finite_under_noise() {
        let mut opt = Sm3::new(vec![meta(&[8, 8])], 0.95, 0.9, 0.1);
        let mut rng = crate::rng::Rng::new(3);
        let mut p = vec![Tensor::from_vec(
            &[8, 8],
            (0..64).map(|_| rng.normal() as f32).collect(),
        )];
        for t in 1..=20 {
            let g = Tensor::from_vec(&[8, 8], (0..64).map(|_| rng.normal() as f32).collect());
            opt.step(&mut p, &[g], t, 1e-2);
        }
        assert!(p[0].data.iter().all(|x| x.is_finite()));
    }
}
