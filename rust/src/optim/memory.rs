//! Optimizer-state memory accounting — produces the paper's headline
//! "fraction of second moments saved" numbers (Fig. 10 top, §5).
//!
//! Two entry points:
//! * [`report`] — exact accounting over a live [`Optimizer`] instance
//!   (the split-engine path). Each optimizer reports its *own* state
//!   elements through the trait, so Lion (no V), Adafactor (factored
//!   row+col accumulators, no momentum in v1) and SM3 (cover sets) all
//!   come out right rather than being assumed AdamW-shaped.
//! * [`report_manifest`] — the same numbers derived from a fused
//!   train-step manifest's `m_shapes`/`v_shapes`, for runs where the
//!   optimizer state lives in backend literals and no `Optimizer`
//!   object exists.

use super::Optimizer;
use crate::runtime::manifest::Manifest;

/// Exact state accounting for one optimizer instance.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryReport {
    pub name: String,
    pub param_elems: usize,
    pub m_elems: usize,
    pub v_elems: usize,
    /// v_elems / param_elems — Adam is 1.0; SlimAdam on GPT ≈ 0.02.
    pub v_fraction: f64,
    /// 1 - v_fraction: the "saves X% of second moments" headline.
    pub v_saving: f64,
    /// m_elems + v_elems: everything the optimizer stores beyond the
    /// parameters themselves.
    pub state_elems: usize,
    /// 1 - state_elems / (2 * param_elems): total optimizer-state saving
    /// relative to AdamW's full m + full v. Lion saves 0.5 (momentum
    /// only); SGD-M likewise; Adafactor v1 approaches 1.0.
    pub state_saving: f64,
}

fn assemble(name: String, param_elems: usize, m_elems: usize, v_elems: usize) -> MemoryReport {
    let v_fraction = if param_elems == 0 {
        0.0
    } else {
        v_elems as f64 / param_elems as f64
    };
    let state_elems = m_elems + v_elems;
    let state_saving = if param_elems == 0 {
        0.0
    } else {
        1.0 - state_elems as f64 / (2.0 * param_elems as f64)
    };
    MemoryReport {
        name,
        param_elems,
        m_elems,
        v_elems,
        v_fraction,
        v_saving: 1.0 - v_fraction,
        state_elems,
        state_saving,
    }
}

pub fn report(opt: &dyn Optimizer, param_elems: usize) -> MemoryReport {
    assemble(
        opt.name().to_string(),
        param_elems,
        opt.first_moment_elems(),
        opt.second_moment_elems(),
    )
}

/// Accounting for a fused train-step artifact: state element counts are
/// read off the manifest's stored-shape lists (`m_shapes` defaults to
/// one full moment per parameter, matching the engine's state layout).
/// Returns `None` for non-fused (grad-step) manifests.
pub fn report_manifest(man: &Manifest) -> Option<MemoryReport> {
    let v_shapes = man.v_shapes.as_ref()?;
    let v_elems = v_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    let m_elems = (0..man.n_params()).map(|i| man.m_shape(i).iter().product::<usize>()).sum();
    let name = match &man.optimizer {
        Some(opt) => opt.clone(),
        None => format!("adamw[{}]", man.ruleset.as_deref().unwrap_or("adam")),
    };
    Some(assemble(name, man.total_param_elems(), m_elems, v_elems))
}

impl MemoryReport {
    pub fn to_json(&self) -> crate::json::Value {
        let mut v = crate::json::Value::obj();
        v.set("name", self.name.clone())
            .set("param_elems", self.param_elems)
            .set("m_elems", self.m_elems)
            .set("v_elems", self.v_elems)
            .set("v_fraction", self.v_fraction)
            .set("v_saving", self.v_saving)
            .set("state_elems", self.state_elems)
            .set("state_saving", self.state_saving);
        v
    }

    pub fn row(&self) -> String {
        format!(
            "{:16} params={:>9} m={:>9} v={:>9} v/param={:>7.4} saving={:>6.2}% state={:>6.2}%",
            self.name,
            self.param_elems,
            self.m_elems,
            self.v_elems,
            self.v_fraction,
            100.0 * self.v_saving,
            100.0 * self.state_saving
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::adamk::AdamK;
    use super::super::{Hypers, KMode, ParamInfo};
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn adam_fraction_is_one() {
        let metas = vec![meta(&[8, 8]), meta(&[16])];
        let opt = AdamK::new(
            "adam",
            metas,
            vec![KMode::None, KMode::None],
            Hypers::default(),
        );
        let r = report(&opt, 80);
        assert_eq!(r.v_elems, 80);
        assert!((r.v_fraction - 1.0).abs() < 1e-12);
        assert!(r.v_saving.abs() < 1e-12);
        assert_eq!(r.state_elems, 160);
        assert!(r.state_saving.abs() < 1e-12);
    }

    #[test]
    fn compressed_fraction_drops() {
        let metas = vec![meta(&[64, 64])];
        let opt = AdamK::new("slim", metas, vec![KMode::FanIn], Hypers::default());
        let r = report(&opt, 4096);
        assert_eq!(r.v_elems, 64);
        assert!(r.v_saving > 0.98);
    }

    #[test]
    fn per_optimizer_shapes_are_not_assumed_adamw() {
        let man = crate::runtime::backend::native::grad_manifest("mlp_tiny").unwrap();
        let total = man.total_param_elems();

        // Lion: momentum only, no V at all.
        let lion = crate::optim::presets::build("lion", &man, Hypers::default()).unwrap();
        let r = report(lion.as_ref(), total);
        assert_eq!(r.v_elems, 0);
        assert_eq!(r.m_elems, total);
        assert!((r.v_saving - 1.0).abs() < 1e-12);
        assert!((r.state_saving - 0.5).abs() < 1e-12);

        // Adafactor v1: factored row+col accumulators, no momentum.
        let af = crate::optim::presets::build("adafactor", &man, Hypers::default()).unwrap();
        let r = report(af.as_ref(), total);
        assert_eq!(r.m_elems, 0);
        assert!(r.v_elems < total / 4, "factored V should be sublinear");
        assert!(r.state_saving > 0.9);

        // SM3: cover sets for matrices, full momentum buffer.
        let sm3 = crate::optim::presets::build("sm3", &man, Hypers::default()).unwrap();
        let r = report(sm3.as_ref(), total);
        assert_eq!(r.m_elems, total);
        assert!(r.v_elems < total / 4, "cover sets should be sublinear");
    }

    #[test]
    fn manifest_report_matches_engine_state_layout() {
        // AdamW fused artifact: full m, ruleset-reduced v.
        let man = crate::runtime::backend::native::train_manifest("mlp_tiny", "slimadam").unwrap();
        let r = report_manifest(&man).unwrap();
        assert_eq!(r.param_elems, man.total_param_elems());
        assert_eq!(r.m_elems, man.total_param_elems());
        let v_total: usize = man
            .v_shapes
            .as_ref()
            .unwrap()
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum();
        assert_eq!(r.v_elems, v_total);
        assert!(r.v_saving > 0.9);

        // Grad-step manifests carry no optimizer state.
        let grad = crate::runtime::backend::native::grad_manifest("mlp_tiny").unwrap();
        assert!(report_manifest(&grad).is_none());
    }
}
