//! Optimizer-state memory accounting — produces the paper's headline
//! "fraction of second moments saved" numbers (Fig. 10 top, §5).

use super::Optimizer;

/// Exact state accounting for one optimizer instance.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryReport {
    pub name: String,
    pub param_elems: usize,
    pub m_elems: usize,
    pub v_elems: usize,
    /// v_elems / param_elems — Adam is 1.0; SlimAdam on GPT ≈ 0.02.
    pub v_fraction: f64,
    /// 1 - v_fraction: the "saves X% of second moments" headline.
    pub v_saving: f64,
}

pub fn report(opt: &dyn Optimizer, param_elems: usize) -> MemoryReport {
    let v_elems = opt.second_moment_elems();
    let v_fraction = if param_elems == 0 {
        0.0
    } else {
        v_elems as f64 / param_elems as f64
    };
    MemoryReport {
        name: opt.name().to_string(),
        param_elems,
        m_elems: opt.first_moment_elems(),
        v_elems,
        v_fraction,
        v_saving: 1.0 - v_fraction,
    }
}

impl MemoryReport {
    pub fn to_json(&self) -> crate::json::Value {
        let mut v = crate::json::Value::obj();
        v.set("name", self.name.clone())
            .set("param_elems", self.param_elems)
            .set("m_elems", self.m_elems)
            .set("v_elems", self.v_elems)
            .set("v_fraction", self.v_fraction)
            .set("v_saving", self.v_saving);
        v
    }

    pub fn row(&self) -> String {
        format!(
            "{:16} params={:>9} m={:>9} v={:>9} v/param={:>7.4} saving={:>6.2}%",
            self.name,
            self.param_elems,
            self.m_elems,
            self.v_elems,
            self.v_fraction,
            100.0 * self.v_saving
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::adamk::AdamK;
    use super::super::{Hypers, KMode, ParamInfo};
    use super::*;
    use crate::tensor::Init;

    fn meta(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn adam_fraction_is_one() {
        let metas = vec![meta(&[8, 8]), meta(&[16])];
        let opt = AdamK::new(
            "adam",
            metas,
            vec![KMode::None, KMode::None],
            Hypers::default(),
        );
        let r = report(&opt, 80);
        assert_eq!(r.v_elems, 80);
        assert!((r.v_fraction - 1.0).abs() < 1e-12);
        assert!(r.v_saving.abs() < 1e-12);
    }

    #[test]
    fn compressed_fraction_drops() {
        let metas = vec![meta(&[64, 64])];
        let opt = AdamK::new("slim", metas, vec![KMode::FanIn], Hypers::default());
        let r = report(&opt, 4096);
        assert_eq!(r.v_elems, 64);
        assert!(r.v_saving > 0.98);
    }
}
