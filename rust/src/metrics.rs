//! Metric sinks: JSONL / CSV writers plus terminal ASCII charts.
//!
//! Every experiment writes machine-readable rows under `results/<exp>/`
//! and prints the paper-comparable series; the ASCII plots give a quick
//! visual check of the U-shaped LR-sensitivity curves and SNR trajectories
//! without any plotting dependency.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Value;

/// Append-only JSONL writer.
///
/// Rows are appended **line-atomically**: each row is serialized with its
/// trailing newline into one buffer and handed to the OS in a single
/// `write_all`, flushed per row. Appends below `PIPE_BUF`-scale sizes
/// land contiguously, so a crash (even `SIGKILL`) can tear at most the
/// *final* line of the file — the recovery invariant the run store's
/// reader depends on (`runstore::reader`, `Tolerance::TornTail`).
pub struct JsonlWriter {
    file: fs::File,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<JsonlWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let file = fs::File::create(&path)
            .with_context(|| format!("creating {path:?}"))?;
        Ok(JsonlWriter { file, path })
    }

    /// Open for appending (creating if absent): sinks whose rows must
    /// survive a re-run, e.g. the sweep scheduler's streamed results.
    ///
    /// If a previous crash left the file without a terminating newline
    /// (a torn final line), a newline is written first so the fragment
    /// stays confined to its own recoverable line — appending directly
    /// would splice the next row onto the fragment and silently corrupt
    /// a *complete* row.
    pub fn append(path: impl AsRef<Path>) -> Result<JsonlWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let torn_tail = fs::File::open(&path).ok().is_some_and(|mut f| {
            use std::io::{Read, Seek, SeekFrom};
            let mut last = [0u8; 1];
            f.seek(SeekFrom::End(-1)).is_ok()
                && f.read_exact(&mut last).is_ok()
                && last[0] != b'\n'
        });
        let mut file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("appending to {path:?}"))?;
        if torn_tail {
            file.write_all(b"\n")?;
        }
        Ok(JsonlWriter { file, path })
    }

    pub fn write(&mut self, v: &Value) -> Result<()> {
        // One write_all for row + newline (never `writeln!`, which issues
        // separate writes and could interleave or tear between them),
        // then flush, so every durable prefix of the file is valid JSONL
        // plus at most one torn final line.
        let mut line = v.dump();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.file.flush()?;
        Ok(())
    }
}

/// CSV writer with a fixed header.
pub struct CsvWriter {
    file: fs::File,
    n_cols: usize,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = fs::File::create(&path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, n_cols: header.len(), path })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        anyhow::ensure!(
            cells.len() == self.n_cols,
            "row has {} cells, header has {}",
            cells.len(),
            self.n_cols
        );
        let escaped: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.file, "{}", escaped.join(","))?;
        Ok(())
    }
}

/// Format helper for CSV rows.
pub fn cells(items: &[&dyn std::fmt::Display]) -> Vec<String> {
    items.iter().map(|x| x.to_string()).collect()
}

/// Render an ASCII line chart of (x, y) series. `log_x` / `log_y` put the
/// corresponding axis in log scale (LR grids, SNR magnitudes).
pub fn ascii_chart(
    title: &str,
    series: &[(&str, &[(f64, f64)])],
    width: usize,
    height: usize,
    log_x: bool,
    log_y: bool,
) -> String {
    let marks = ['o', 'x', '+', '*', '#', '@', '%', '&'];
    let tx = |x: f64| if log_x { x.max(1e-300).log10() } else { x };
    let ty = |y: f64| if log_y { y.max(1e-300).log10() } else { y };

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (_, pts) in series {
        for &(x, y) in *pts {
            if y.is_finite() && x.is_finite() {
                xs.push(tx(x));
                ys.push(ty(y));
            }
        }
    }
    if xs.is_empty() {
        return format!("{title}: <no finite data>\n");
    }
    let (xmin, xmax) = min_max(&xs);
    let (ymin, ymax) = min_max(&ys);
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in *pts {
            if !(y.is_finite() && x.is_finite()) {
                continue;
            }
            let cx = (((tx(x) - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let cy = (((ty(y) - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy.min(height - 1)][cx.min(width - 1)] = mark;
        }
    }

    let mut out = format!("{title}\n");
    let ylab = |v: f64| if log_y { format!("1e{v:.1}") } else { format!("{v:.3}") };
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            ylab(ymax)
        } else if r == height - 1 {
            ylab(ymin)
        } else {
            String::new()
        };
        out.push_str(&format!("{label:>9} |{}|\n", row.iter().collect::<String>()));
    }
    let xlab = |v: f64| if log_x { format!("1e{v:.1}") } else { format!("{v:.3}") };
    out.push_str(&format!(
        "{:>9}  {}{}\n",
        "",
        xlab(xmin),
        format!("{:>w$}", xlab(xmax), w = width.saturating_sub(xlab(xmin).len()))
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
        .collect();
    out.push_str(&format!("          {}\n", legend.join("   ")));
    out
}

fn min_max(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Results directory helper: `results/<exp_id>/`.
pub fn results_dir(exp_id: &str) -> Result<PathBuf> {
    let dir = PathBuf::from("results").join(exp_id);
    fs::create_dir_all(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_writes_lines() {
        let dir = std::env::temp_dir().join("slimadam_test_jsonl");
        let path = dir.join("x.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        let mut v = Value::obj();
        v.set("a", 1usize);
        w.write(&v).unwrap();
        w.write(&v).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_append_preserves_existing_rows() {
        let dir = std::env::temp_dir().join("slimadam_test_jsonl_append");
        let path = dir.join("x.jsonl");
        let mut v = Value::obj();
        v.set("a", 1usize);
        let mut w = JsonlWriter::append(&path).unwrap();
        w.write(&v).unwrap();
        drop(w);
        let mut w = JsonlWriter::append(&path).unwrap(); // reopen: no truncation
        w.write(&v).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_isolates_torn_tail_on_fresh_line() {
        // re-streaming into a crashed file without repair must not splice
        // the next row onto the torn fragment
        let dir = std::env::temp_dir().join("slimadam_test_jsonl_torn");
        let path = dir.join("x.jsonl");
        fs::create_dir_all(&dir).unwrap();
        fs::write(&path, "{\"a\":1}\n{\"b\":2,\"tor").unwrap();
        let mut w = JsonlWriter::append(&path).unwrap();
        let mut v = Value::obj();
        v.set("c", 3usize);
        w.write(&v).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1], "{\"b\":2,\"tor"); // fragment confined
        assert_eq!(lines[2], "{\"c\":3}"); // new row intact
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_rows_are_single_terminated_lines() {
        // the line-atomic contract: one row == one '\n'-terminated line,
        // even when values contain raw newlines (escaped by dump())
        let dir = std::env::temp_dir().join("slimadam_test_jsonl_atomic");
        let path = dir.join("x.jsonl");
        let mut w = JsonlWriter::append(&path).unwrap();
        let mut v = Value::obj();
        v.set("s", "two\nlines");
        w.write(&v).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(text.lines().count(), 1);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_schema_enforced() {
        let dir = std::env::temp_dir().join("slimadam_test_csv");
        let path = dir.join("x.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        w.row(&["with,comma".into(), "q\"uote".into()]).unwrap();
        drop(w);
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"with,comma\""));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chart_renders() {
        let pts: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, (i * i) as f64)).collect();
        let s = ascii_chart("parabola", &[("y=x^2", &pts)], 40, 10, false, false);
        assert!(s.contains("parabola"));
        assert!(s.contains('o'));
        assert!(s.lines().count() >= 12);
    }

    #[test]
    fn chart_log_axes() {
        let pts: Vec<(f64, f64)> = vec![(1e-4, 10.0), (1e-3, 3.0), (1e-2, 5.0)];
        let s = ascii_chart("ushape", &[("loss", &pts)], 30, 8, true, false);
        assert!(s.contains("1e-4"));
    }

    #[test]
    fn chart_handles_nan() {
        let pts: Vec<(f64, f64)> = vec![(1.0, f64::NAN), (2.0, 1.0)];
        let s = ascii_chart("nan", &[("x", &pts)], 20, 5, false, false);
        assert!(s.contains('o'));
    }
}
