//! # slimadam — reproduction of "When Can You Get Away with Low Memory Adam?"
//!
//! A three-layer Rust + JAX + Pallas system: Python (JAX + Pallas) authors
//! and AOT-lowers the model compute graphs to HLO text at build time; this
//! crate is the Layer-3 coordinator that loads those artifacts through the
//! PJRT C API (`xla` crate), owns the training loop, and implements the
//! paper's contribution — the SNR analysis of Adam's second moments
//! (Eq. 3/4), the generalized low-memory Adam family (Eq. 2), the
//! SNR-guided **SlimAdam** optimizer, and every baseline the paper compares
//! against (AdaLayer, Adam-mini v1/v2, SM3, Lion, Adafactor v1/v2, SGD-M).
//!
//! The crate is fully self-contained at run time: Python never executes on
//! the request path, and the only external crates are `xla` and `anyhow`.
//! Everything else — JSON, RNG, tensors, CLI, thread pool, property-test
//! and bench harnesses — is implemented in-repo (see DESIGN.md §2).
//!
//! Module map:
//!
//! * Substrates: [`json`], [`rng`], [`tensor`], [`cli`], [`pool`]
//!   (work-stealing sweep pool), [`proptest`], [`benchkit`], [`metrics`]
//! * Observability: [`obs`] (flight recorder — span tracing into
//!   `results/trace/`, the always-on metrics registry, and the opt-in
//!   live SNR telemetry tap — DESIGN.md §15)
//! * Runtime: [`runtime`] (manifests, engines, and the device-tagged
//!   backend layer — the PJRT path behind the `pjrt` feature and the
//!   pure-Rust native interpreter — DESIGN.md §11)
//! * The paper's system: [`optim`] (optimizer family), [`snr`] (Eq. 3/4),
//!   [`rules`] (SNR → compression rules)
//! * Workloads: [`data`] (corpora, images, BPE), [`train`] (loop driver),
//!   [`coordinator`] (job orchestration, the parallel sweep scheduler,
//!   its compile-once executable cache — DESIGN.md §9 — and the batched
//!   in-worker dispatch planner — §12), [`sweep`] (grids),
//!   [`runstore`] (crash-safe store of completed jobs + sweep resume —
//!   DESIGN.md §10)
//! * Service: [`serve`] (sweep-as-a-service — the long-lived `slimadam
//!   serve` daemon: durable journaled queue, per-tenant run stores,
//!   cross-request batched dispatch, streaming subscriptions, graceful
//!   drain — DESIGN.md §16)
//! * Reproduction: [`exp`] (one module per paper figure/table)

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod json;
pub mod metrics;
pub mod npy;
pub mod obs;
pub mod optim;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod rules;
pub mod runstore;
pub mod runtime;
pub mod serve;
pub mod snr;
pub mod sweep;
pub mod tensor;
pub mod train;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
