//! Deterministic RNG substrate — replaces the `rand` crate.
//!
//! `Rng` is xoshiro256** seeded through SplitMix64 (the reference seeding
//! recipe), with normal (Box–Muller), truncated-normal, uniform, Zipf and
//! categorical samplers. Every experiment in this repo is reproducible
//! from a single `u64` seed; sub-streams are derived with [`Rng::fork`]
//! so parallel sweep workers never share state.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for parallel workers / named substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire rejection for unbiasedness.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal truncated to +-2 sigma (matches `jax.random.truncated_normal`
    /// usage in the paper's App. B.2 init).
    pub fn trunc_normal(&mut self) -> f64 {
        loop {
            let x = self.normal();
            if x.abs() <= 2.0 {
                return x;
            }
        }
    }

    /// Zipf-distributed rank in [0, n): P(k) ∝ 1/(k+1)^alpha.
    /// Uses a precomputable CDF via [`ZipfTable`] for hot paths; this
    /// direct method is O(n) per sample and fine for table construction.
    pub fn zipf_once(&mut self, n: usize, alpha: f64) -> usize {
        ZipfTable::new(n, alpha).sample(self)
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

/// FNV-1a over raw bytes: the stable, dependency-free digest used for
/// executable-cache keys (manifest hashes) and run fingerprints. Not
/// cryptographic — collision resistance is "good enough for cache keys".
pub fn stable_hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Deterministic per-job seed: a SplitMix64 mix of a sweep's base seed
/// and the job's grid index. A pure function of the job spec — never of
/// worker assignment or completion order — so parallel and serial sweeps
/// draw byte-identical streams (see `rust/tests/scheduler_determinism.rs`).
pub fn job_seed(base: u64, job_index: u64) -> u64 {
    let mut s = base ^ job_index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// Precomputed Zipf CDF with O(log n) sampling — the unigram backbone of
/// the synthetic heavy-tailed corpus (paper §4.1).
#[derive(Debug, Clone)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, alpha: f64) -> ZipfTable {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// P(rank k).
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_hash_reference_values() {
        // FNV-1a offset basis for empty input; must never change across
        // refactors (executable-cache keys persist in stream logs).
        assert_eq!(stable_hash64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(stable_hash64(b"a"), stable_hash64(b"a"));
        assert_ne!(
            stable_hash64(b"gpt_nano.grad"),
            stable_hash64(b"gpt_nano.train.adam")
        );
    }

    #[test]
    fn job_seeds_pure_and_distinct() {
        let a: Vec<u64> = (0..64).map(|i| job_seed(42, i)).collect();
        let b: Vec<u64> = (0..64).map(|i| job_seed(42, i)).collect();
        assert_eq!(a, b);
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "job seed collision");
        assert_ne!(job_seed(42, 0), job_seed(43, 0));
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn trunc_normal_bounded() {
        let mut rng = Rng::new(13);
        for _ in 0..10_000 {
            assert!(rng.trunc_normal().abs() <= 2.0);
        }
    }

    #[test]
    fn zipf_heavier_head_with_larger_alpha() {
        let t1 = ZipfTable::new(1000, 0.5);
        let t2 = ZipfTable::new(1000, 1.5);
        assert!(t2.pmf(0) > t1.pmf(0));
        // pmf sums to 1
        let s: f64 = (0..1000).map(|k| t2.pmf(k)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zipf_sampling_matches_pmf() {
        let table = ZipfTable::new(50, 1.07);
        let mut rng = Rng::new(17);
        let mut counts = vec![0usize; 50];
        let n = 100_000;
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        for k in [0usize, 1, 5, 20] {
            let emp = counts[k] as f64 / n as f64;
            let exp = table.pmf(k);
            assert!((emp - exp).abs() < 0.01, "k={k} emp={emp} exp={exp}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(23);
        let mut hits = 0;
        for _ in 0..10_000 {
            if rng.categorical(&[1.0, 3.0]) == 1 {
                hits += 1;
            }
        }
        assert!((hits as f64 / 10_000.0 - 0.75).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(29);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
