//! Tiny CLI substrate — replaces `clap`.
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! and positional arguments, with generated `--help` text. Parsed values
//! are fetched through typed accessors with defaults, which is all the
//! `slimadam` launcher needs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declarative option spec for help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
///
/// ```
/// use slimadam::cli::Args;
///
/// let argv = ["--workers", "4", "--lrs=1e-4,1e-3", "--fused", "fig1"]
///     .map(String::from);
/// let args = Args::parse(argv, &["fused"]).unwrap();
/// assert_eq!(args.usize_or("workers", 0).unwrap(), 4);
/// assert_eq!(args.f64_list("lrs", &[]).unwrap(), vec![1e-4, 1e-3]);
/// assert!(args.flag("fused"));
/// assert_eq!(args.positional, vec!["fig1"]);
/// ```
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv items (after the subcommand) against known flags.
    /// Any `--name` in `flag_names` is boolean; all other `--key` consume a
    /// value (either `--key=value` or the following token).
    pub fn parse<I: IntoIterator<Item = String>>(
        items: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{stripped} expects a value"))?;
                    args.options.insert(stripped.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated f64 list, e.g. `--lrs 1e-4,3e-4,1e-3`.
    pub fn f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow!("bad number {s:?} in --{name}"))
                })
                .collect(),
        }
    }

    /// Comma-separated string list.
    pub fn str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Required positional argument (for subcommand actions like
    /// `slimadam runs <ls|report|compact>`), with a useful error.
    pub fn require_positional(&self, idx: usize, what: &str) -> Result<&str> {
        self.positional
            .get(idx)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing {what} (positional argument {idx})"))
    }
}

/// Render help for a subcommand.
pub fn render_help(bin: &str, cmd: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{bin} {cmd} — {about}\n\nOptions:\n");
    for o in opts {
        let head = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <v>", o.name)
        };
        let default = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{head:28} {}{default}\n", o.help));
    }
    s
}

/// Split argv into (subcommand, rest); errors when empty.
pub fn subcommand(mut argv: Vec<String>) -> Result<(String, Vec<String>)> {
    if argv.is_empty() {
        bail!("missing subcommand");
    }
    let cmd = argv.remove(0);
    Ok((cmd, argv))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(
            v(&["run", "--lr", "3e-4", "--steps=100", "--verbose", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 3e-4);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(v(&[]), &[]).unwrap();
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("name", "x"), "x");
        assert!(a.require("name").is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(v(&["--lrs", "1e-4, 3e-4,1e-3"]), &[]).unwrap();
        assert_eq!(a.f64_list("lrs", &[]).unwrap(), vec![1e-4, 3e-4, 1e-3]);
        let b = Args::parse(v(&["--opts", "adam,slimadam"]), &[]).unwrap();
        assert_eq!(b.str_list("opts", &[]), vec!["adam", "slimadam"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(v(&["--lr"]), &[]).is_err());
    }

    #[test]
    fn require_positional() {
        let a = Args::parse(v(&["ls", "results"]), &[]).unwrap();
        assert_eq!(a.require_positional(0, "action").unwrap(), "ls");
        assert_eq!(a.require_positional(1, "dir").unwrap(), "results");
        assert!(a.require_positional(2, "missing").is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(v(&["--lr", "abc"]), &[]).unwrap();
        assert!(a.f64_or("lr", 0.0).is_err());
    }

    #[test]
    fn subcommand_split() {
        let (cmd, rest) = subcommand(v(&["exp", "fig1"])).unwrap();
        assert_eq!(cmd, "exp");
        assert_eq!(rest, vec!["fig1"]);
        assert!(subcommand(vec![]).is_err());
    }

    #[test]
    fn help_renders() {
        let h = render_help(
            "slimadam",
            "train",
            "train a model",
            &[OptSpec { name: "lr", help: "learning rate", default: Some("3e-4"), is_flag: false }],
        );
        assert!(h.contains("--lr"));
        assert!(h.contains("default: 3e-4"));
    }
}
