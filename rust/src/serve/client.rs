//! Thin daemon client — the library behind `slimadam client …`
//! (DESIGN.md §16).
//!
//! A [`Client`] is one connection: a writer half and a framed reader half
//! over the same socket. Request/reply traffic ([`Client::request`]) and
//! streaming subscriptions ([`Client::next_event`]) share the frame
//! grammar; a subscribed connection should stick to events, since the
//! daemon interleaves `row` frames with any later replies.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::json::Value;

use super::proto::{self, Addr, Conn, FrameReader, Recv, Request};
use super::JobSpec;

/// Frames the daemon streams unprompted (vs direct request replies).
fn is_stream_frame(v: &Value) -> bool {
    matches!(
        v.opt("reply").and_then(|r| r.as_str().ok()),
        Some("row") | Some("job_done") | Some("bye")
    )
}

/// One client connection to a serve daemon.
pub struct Client {
    writer: Conn,
    reader: FrameReader<Conn>,
    /// Stream frames that arrived while waiting for a request's reply —
    /// a watched job's first rows can race the `queued` reply onto the
    /// wire. Drained by [`Client::next_event`] before the socket is read.
    pending: VecDeque<Value>,
}

impl Client {
    /// Connect to a daemon address (Unix socket path or `host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let conn = Addr::parse(addr).connect()?;
        let writer = conn.try_clone()?;
        Ok(Client {
            writer,
            reader: FrameReader::new(conn),
            pending: VecDeque::new(),
        })
    }

    /// Connect, retrying until `timeout` — for racing a daemon that is
    /// still binding its socket (tests, CI, scripted startup).
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Client> {
        let deadline = Instant::now() + timeout;
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!(
                            "no daemon answered on {addr} within {timeout:?}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    /// Send one request and read its reply, setting aside any stream
    /// frames (`row`/`job_done`/`bye`) that land first — they stay queued
    /// for [`Client::next_event`].
    pub fn request(&mut self, req: &Request) -> Result<Value> {
        proto::write_frame(&mut self.writer, &req.to_value())?;
        loop {
            match self.reader.read_frame() {
                Recv::Frame(v) if is_stream_frame(&v) => self.pending.push_back(v),
                Recv::Frame(v) => return Ok(v),
                Recv::Bad(reason) => bail!("daemon sent a malformed frame: {reason}"),
                Recv::Torn => bail!("connection torn mid-reply (daemon killed?)"),
                Recv::Eof => bail!("daemon closed the connection before replying"),
            }
        }
    }

    /// Liveness probe; `Ok(true)` on a `pong`.
    pub fn ping(&mut self) -> Result<bool> {
        let r = self.request(&Request::Ping)?;
        Ok(r.get("reply")?.as_str()? == "pong")
    }

    /// Submit one sweep under `tenant`. The reply is `queued` (carrying
    /// the job id), `overloaded`, `draining`, or `error`. With `watch`,
    /// an accepted submit also subscribes this connection to the job's
    /// result stream — follow with [`Client::wait_job`].
    pub fn submit(&mut self, tenant: &str, spec: &JobSpec, watch: bool) -> Result<Value> {
        self.request(&Request::Submit {
            tenant: tenant.to_string(),
            spec: spec.clone(),
            watch,
        })
    }

    /// Queue/running/done counts plus per-job states.
    pub fn status(&mut self) -> Result<Value> {
        self.request(&Request::Status)
    }

    /// Remove a still-queued job; `Ok(true)` if it was removed.
    pub fn cancel(&mut self, job: &str) -> Result<bool> {
        let r = self.request(&Request::Cancel { job: job.to_string() })?;
        Ok(r.opt("removed").and_then(|b| b.as_bool().ok()).unwrap_or(false))
    }

    /// Ask the daemon to drain: stop admitting, finish in-flight groups,
    /// flush, exit 0.
    pub fn drain(&mut self) -> Result<Value> {
        self.request(&Request::Drain)
    }

    /// Turn this connection into a result stream, filtered by tenant
    /// and/or job id (both `None` = everything).
    pub fn subscribe(&mut self, tenant: Option<&str>, job: Option<&str>) -> Result<()> {
        let r = self.request(&Request::Subscribe {
            tenant: tenant.map(String::from),
            job: job.map(String::from),
        })?;
        let kind = r.get("reply")?.as_str()?;
        if kind != "subscribed" {
            bail!("subscribe rejected: {}", r.dump());
        }
        Ok(())
    }

    /// Next streamed event (`row`, `job_done`, `bye`, …); `Ok(None)` when
    /// the daemon hangs up (clean EOF or a kill mid-frame). Events that
    /// arrived during a [`Client::request`] are delivered first.
    pub fn next_event(&mut self) -> Result<Option<Value>> {
        if let Some(v) = self.pending.pop_front() {
            return Ok(Some(v));
        }
        match self.reader.read_frame() {
            Recv::Frame(v) => Ok(Some(v)),
            Recv::Bad(reason) => bail!("daemon sent a malformed frame: {reason}"),
            Recv::Torn | Recv::Eof => Ok(None),
        }
    }

    /// Consume events until `job` completes (requires a subscription
    /// covering it — e.g. `submit(.., watch=true)`). Each `row` frame is
    /// handed to `on_row`; returns the `job_done` frame.
    pub fn wait_job(
        &mut self,
        job: &str,
        mut on_row: impl FnMut(&Value),
    ) -> Result<Value> {
        loop {
            let Some(event) = self.next_event()? else {
                bail!("daemon hung up before job {job} completed");
            };
            let kind = event.get("reply")?.as_str()?.to_string();
            let for_job = event
                .opt("job")
                .and_then(|j| j.as_str().ok())
                .map_or(false, |j| j == job);
            match kind.as_str() {
                "row" if for_job => on_row(&event),
                "job_done" if for_job => return Ok(event),
                "bye" => bail!("daemon drained before job {job} completed"),
                _ => {}
            }
        }
    }
}
