//! Durable FIFO queue — `queue.jsonl` journal + replay (DESIGN.md §16).
//!
//! Every admission decision is journaled through the run store's
//! line-atomic [`JsonlWriter`] *before* it is acknowledged, so the queue's
//! durable state is exactly the prefix of acknowledged events: a SIGKILL
//! tears at most the final line, and [`DurableQueue::open`] replays the
//! journal under [`Tolerance::SkipBad`] (the torn line is isolated by the
//! writer's next-append newline repair and skipped as one bad row).
//!
//! Journal rows:
//!
//! * `{"kind":"submit","seq":N,"id":H,"tenant":T,"spec":{…}}` — admission.
//! * `{"kind":"done","id":H,"ran":N,"skipped":M}` — all grid points of the
//!   job are in its tenant's run store.
//! * `{"kind":"cancel","id":H}` — removed while still queued.
//!
//! A job is **pending** iff its submit row has no matching done/cancel row
//! — including jobs that were mid-execution at kill time. Replayed pending
//! jobs re-dispatch from the front of the queue in original `seq` order;
//! zero re-execution is the run store's job (every completed grid point is
//! a resume hit), not the journal's.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Value;
use crate::metrics::JsonlWriter;
use crate::rng::stable_hash64;
use crate::runstore::reader::{read_stream_file, scan_jsonl, Tolerance};
use crate::serve::JobSpec;

/// One admitted job.
#[derive(Debug, Clone)]
pub struct QueueEntry {
    /// Stable job id: hash of `(tenant, seq, spec)`, hex-rendered.
    pub id: String,
    /// Tenant namespace (validated before admission).
    pub tenant: String,
    /// The sweep to run.
    pub spec: JobSpec,
    /// Admission sequence number — FIFO order across daemon lifetimes.
    pub seq: u64,
}

impl QueueEntry {
    fn to_row(&self) -> Value {
        let mut v = Value::obj();
        v.set("kind", "submit")
            .set("seq", self.seq as usize)
            .set("id", self.id.as_str())
            .set("tenant", self.tenant.as_str())
            .set("spec", self.spec.to_value());
        v
    }
}

/// Outcome of a submit attempt against the bounded queue.
#[derive(Debug)]
pub enum Admission {
    /// Journaled and queued.
    Queued(QueueEntry),
    /// The queue is at capacity — explicit backpressure, nothing written.
    Overloaded { queue_depth: usize },
}

/// The journaled bounded FIFO queue. All mutation goes through `&mut self`
/// (the daemon wraps it in a `Mutex`); every mutation journals first.
pub struct DurableQueue {
    path: PathBuf,
    writer: JsonlWriter,
    pending: VecDeque<QueueEntry>,
    /// Jobs handed to the dispatcher but not yet journaled done — they
    /// still count against capacity and replay after a kill.
    in_flight: usize,
    next_seq: u64,
    cap: usize,
    /// Replay statistics from open (bad rows skipped, rows read).
    pub replayed_rows: usize,
    pub replay_skipped: usize,
}

impl DurableQueue {
    /// Journal path inside a daemon state directory.
    pub fn journal_path(state_dir: &Path) -> PathBuf {
        state_dir.join("queue.jsonl")
    }

    /// Open (or create) the journal under `state_dir` and replay it.
    /// `cap` bounds admitted-but-incomplete jobs (`0` = 1).
    pub fn open(state_dir: &Path, cap: usize) -> Result<DurableQueue> {
        std::fs::create_dir_all(state_dir)
            .with_context(|| format!("creating serve state dir {state_dir:?}"))?;
        let path = Self::journal_path(state_dir);
        let mut pending: VecDeque<QueueEntry> = VecDeque::new();
        let mut by_id: HashMap<String, usize> = HashMap::new();
        let mut next_seq = 0u64;
        let mut replayed_rows = 0usize;
        let mut replay_skipped = 0usize;
        if path.exists() {
            let text = read_stream_file(&path)?;
            let stats = scan_jsonl(&text, Tolerance::SkipBad, |_, row| {
                let Some(kind) = row.str("kind") else { return Ok(()) };
                match kind {
                    "submit" => {
                        let (Some(id), Some(tenant), Some(seq)) =
                            (row.str("id"), row.str("tenant"), row.usize("seq"))
                        else {
                            return Ok(());
                        };
                        // re-parse the spec from the raw line: RowView is
                        // flat, the spec is nested
                        let Ok(full) = Value::parse(row.line) else {
                            return Ok(());
                        };
                        let Ok(spec) = full
                            .get("spec")
                            .and_then(JobSpec::from_value)
                        else {
                            return Ok(());
                        };
                        let entry = QueueEntry {
                            id: id.to_string(),
                            tenant: tenant.to_string(),
                            spec,
                            seq: seq as u64,
                        };
                        next_seq = next_seq.max(entry.seq + 1);
                        by_id.insert(entry.id.clone(), pending.len());
                        pending.push_back(entry);
                    }
                    "done" | "cancel" => {
                        if let Some(id) = row.str("id") {
                            if let Some(&i) = by_id.get(id) {
                                // tombstone; compacted below
                                pending[i].id.clear();
                                by_id.remove(id);
                            }
                        }
                    }
                    _ => {}
                }
                Ok(())
            })?;
            replayed_rows = stats.rows;
            replay_skipped = stats.skipped + stats.torn;
            pending.retain(|e| !e.id.is_empty());
        }
        let writer = JsonlWriter::append(&path)?;
        Ok(DurableQueue {
            path,
            writer,
            pending,
            in_flight: 0,
            next_seq,
            cap: cap.max(1),
            replayed_rows,
            replay_skipped,
        })
    }

    pub fn journal(&self) -> &Path {
        &self.path
    }

    /// Jobs admitted but not yet done/cancelled (queued + in flight) —
    /// the figure capacity bounds.
    pub fn live(&self) -> usize {
        self.pending.len() + self.in_flight
    }

    /// Jobs waiting for dispatch.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Grid points waiting for dispatch (the adaptive-batch signal).
    pub fn queued_configs(&self) -> usize {
        self.pending.iter().map(|e| e.spec.n_configs()).sum()
    }

    pub fn pending_entries(&self) -> impl Iterator<Item = &QueueEntry> {
        self.pending.iter()
    }

    /// Admit one job: journal the submit row, then queue it. At capacity,
    /// nothing is written and the caller replies `overloaded`.
    pub fn submit(&mut self, tenant: &str, spec: JobSpec) -> Result<Admission> {
        if self.live() >= self.cap {
            return Ok(Admission::Overloaded { queue_depth: self.live() });
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let id = format!(
            "{:016x}",
            stable_hash64(
                format!("{tenant}|{seq}|{}", spec.to_value().dump()).as_bytes()
            )
        );
        let entry = QueueEntry { id, tenant: tenant.to_string(), spec, seq };
        self.writer.write(&entry.to_row())?;
        self.pending.push_back(entry.clone());
        Ok(Admission::Queued(entry))
    }

    /// Hand every queued job to the dispatcher (FIFO). Taken jobs remain
    /// journal-pending (and capacity-counted) until [`DurableQueue::done`].
    pub fn take_all(&mut self) -> Vec<QueueEntry> {
        let wave: Vec<QueueEntry> = self.pending.drain(..).collect();
        self.in_flight += wave.len();
        wave
    }

    /// Journal a job's completion.
    pub fn done(&mut self, id: &str, ran: usize, skipped: usize) -> Result<()> {
        let mut v = Value::obj();
        v.set("kind", "done")
            .set("id", id)
            .set("ran", ran)
            .set("skipped", skipped);
        self.writer.write(&v)?;
        self.in_flight = self.in_flight.saturating_sub(1);
        Ok(())
    }

    /// Cancel a still-queued job. Returns `false` (and journals nothing)
    /// if the id is unknown or already dispatched.
    pub fn cancel(&mut self, id: &str) -> Result<bool> {
        let Some(pos) = self.pending.iter().position(|e| e.id == id) else {
            return Ok(false);
        };
        let mut v = Value::obj();
        v.set("kind", "cancel").set("id", id);
        self.writer.write(&v)?;
        self.pending.remove(pos);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "slimadam_serve_queue_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec(lr: f64) -> JobSpec {
        JobSpec::native("mlp_tiny", &["adam"], &[lr], 5)
    }

    #[test]
    fn submit_replay_done_cycle() {
        let dir = tmp_dir("cycle");
        let id = {
            let mut q = DurableQueue::open(&dir, 8).unwrap();
            let Admission::Queued(e) = q.submit("alpha", spec(1e-3)).unwrap() else {
                panic!("should queue");
            };
            let Admission::Queued(_) = q.submit("beta", spec(3e-3)).unwrap() else {
                panic!("should queue");
            };
            assert_eq!(q.queued(), 2);
            e.id
        };
        // reopen: both jobs replay in submit order
        let mut q = DurableQueue::open(&dir, 8).unwrap();
        let ids: Vec<String> = q.pending_entries().map(|e| e.id.clone()).collect();
        assert_eq!(q.queued(), 2);
        assert_eq!(ids[0], id, "FIFO order survives replay");
        // complete the first; only the second replays
        let wave = q.take_all();
        q.done(&wave[0].id, 1, 0).unwrap();
        drop(q);
        let q = DurableQueue::open(&dir, 8).unwrap();
        assert_eq!(q.queued(), 1, "done job must not replay");
        assert_eq!(q.pending_entries().next().unwrap().tenant, "beta");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn taken_but_unfinished_jobs_replay() {
        let dir = tmp_dir("inflight");
        {
            let mut q = DurableQueue::open(&dir, 8).unwrap();
            q.submit("alpha", spec(1e-3)).unwrap();
            let wave = q.take_all();
            assert_eq!(wave.len(), 1);
            assert_eq!(q.live(), 1, "in-flight still counts against cap");
            // no done row: simulate SIGKILL mid-wave by dropping here
        }
        let q = DurableQueue::open(&dir, 8).unwrap();
        assert_eq!(q.queued(), 1, "in-flight job must replay after a kill");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bounded_queue_overloads_without_journaling() {
        let dir = tmp_dir("cap");
        let mut q = DurableQueue::open(&dir, 2).unwrap();
        assert!(matches!(q.submit("a", spec(1e-3)).unwrap(), Admission::Queued(_)));
        assert!(matches!(q.submit("a", spec(2e-3)).unwrap(), Admission::Queued(_)));
        let Admission::Overloaded { queue_depth } = q.submit("a", spec(3e-3)).unwrap()
        else {
            panic!("third submit must overload");
        };
        assert_eq!(queue_depth, 2);
        drop(q);
        let q = DurableQueue::open(&dir, 2).unwrap();
        assert_eq!(q.queued(), 2, "rejected submit must not be journaled");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cancel_removes_only_queued_jobs() {
        let dir = tmp_dir("cancel");
        let mut q = DurableQueue::open(&dir, 8).unwrap();
        let Admission::Queued(a) = q.submit("a", spec(1e-3)).unwrap() else {
            panic!()
        };
        assert!(q.cancel(&a.id).unwrap());
        assert!(!q.cancel(&a.id).unwrap(), "second cancel is a no-op");
        assert!(!q.cancel("unknown").unwrap());
        drop(q);
        let q = DurableQueue::open(&dir, 8).unwrap();
        assert_eq!(q.queued(), 0, "cancelled job must not replay");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_journal_tail_is_skipped_on_replay() {
        let dir = tmp_dir("torn");
        {
            let mut q = DurableQueue::open(&dir, 8).unwrap();
            q.submit("a", spec(1e-3)).unwrap();
        }
        // tear the tail: append half a submit row, no newline
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(DurableQueue::journal_path(&dir))
            .unwrap();
        f.write_all(b"{\"kind\":\"submit\",\"seq\":1,\"id\":\"dead").unwrap();
        drop(f);
        let q = DurableQueue::open(&dir, 8).unwrap();
        assert_eq!(q.queued(), 1, "intact rows replay");
        assert_eq!(q.replay_skipped, 1, "torn tail counted, not fatal");
        std::fs::remove_dir_all(&dir).ok();
    }
}
