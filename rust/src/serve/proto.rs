//! Wire protocol — length-prefixed JSONL frames over a Unix socket or TCP
//! (DESIGN.md §16).
//!
//! A frame is one line: `<len> <payload>\n`, where `len` is the decimal
//! byte count of `payload` and `payload` is a single-line JSON object
//! serialized by [`crate::json::Value::dump`] (which never emits raw
//! newlines — control characters are `\u`-escaped). The framing is chosen
//! so that a *torn* frame — a client or daemon killed mid-write — has the
//! exact signature of a torn run-store JSONL tail: the stream's final line
//! lacks its `\n`. Recovery therefore reuses the same discipline as
//! [`crate::runstore::reader::Tolerance::TornTail`]: a malformed line is
//! rejected as one unit and the connection resynchronizes at the next
//! newline, never desyncing mid-stream (`rust/tests/serve_protocol.rs`
//! property-tests every split point).
//!
//! The length prefix is a cheap integrity check layered on top: a payload
//! whose byte count disagrees with its header is rejected before the JSON
//! parser runs, and a header promising more than [`MAX_FRAME`] bytes drops
//! the connection instead of buffering unboundedly.
//!
//! [`Addr`] abstracts the two transports: anything containing a `:` whose
//! tail parses as a port is TCP (`host:port`), everything else is a Unix
//! socket path.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::json::Value;

/// Upper bound on one frame's payload bytes. A submit of a full LR grid is
/// a few KiB; a megabyte means a confused or hostile peer.
pub const MAX_FRAME: usize = 1 << 20;

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

/// Serialize one frame: `<len> <payload>\n`.
pub fn encode(v: &Value) -> String {
    let payload = v.dump();
    format!("{} {payload}\n", payload.len())
}

/// Decode one complete line (without its trailing `\n`) into a frame
/// payload. Errors describe the rejection; the caller's stream position is
/// already past the line, so rejecting never desyncs the connection.
pub fn decode_line(line: &str) -> Result<Value> {
    let Some((len_str, payload)) = line.split_once(' ') else {
        bail!("frame has no length prefix: {:?}", truncate(line));
    };
    let len: usize = len_str
        .parse()
        .with_context(|| format!("bad frame length {:?}", truncate(len_str)))?;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}");
    }
    if payload.len() != len {
        bail!(
            "frame length mismatch: header promises {len} bytes, payload \
             carries {} — torn or interleaved write",
            payload.len()
        );
    }
    Value::parse(payload).with_context(|| format!("frame payload is not JSON: {:?}", truncate(payload)))
}

fn truncate(s: &str) -> String {
    if s.len() <= 80 {
        s.to_string()
    } else {
        let mut end = 80;
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        format!("{}…", &s[..end])
    }
}

/// One read attempt's outcome. `Bad` frames leave the connection usable
/// (the reader is positioned after the offending line); `Torn` and `Eof`
/// end it.
#[derive(Debug)]
pub enum Recv {
    /// A complete, well-formed frame.
    Frame(Value),
    /// A complete line that failed validation — rejected, stream intact.
    Bad(String),
    /// The stream ended mid-line (peer killed mid-write) or errored.
    Torn,
    /// Clean end-of-stream at a frame boundary.
    Eof,
}

/// Buffered frame reader over any byte stream.
pub struct FrameReader<R: Read> {
    inner: BufReader<R>,
}

impl<R: Read> FrameReader<R> {
    pub fn new(stream: R) -> FrameReader<R> {
        FrameReader { inner: BufReader::new(stream) }
    }

    /// Read the next frame. Never blocks past the underlying stream's own
    /// read timeout; never buffers more than [`MAX_FRAME`] + header bytes
    /// for one line.
    pub fn read_frame(&mut self) -> Recv {
        let mut line: Vec<u8> = Vec::new();
        // Bounded read_until: a line longer than the frame cap (plus
        // header slack) is abandoned as hostile.
        let cap = MAX_FRAME + 32;
        loop {
            let mut byte = [0u8; 1];
            match self.inner.read(&mut byte) {
                Ok(0) => {
                    return if line.is_empty() { Recv::Eof } else { Recv::Torn };
                }
                Ok(_) => {
                    if byte[0] == b'\n' {
                        break;
                    }
                    line.push(byte[0]);
                    if line.len() > cap {
                        return Recv::Bad(format!(
                            "line exceeds {cap} bytes without newline"
                        ));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Recv::Torn,
            }
        }
        let text = match std::str::from_utf8(&line) {
            Ok(t) => t,
            Err(_) => return Recv::Bad("frame is not UTF-8".into()),
        };
        match decode_line(text) {
            Ok(v) => Recv::Frame(v),
            Err(e) => Recv::Bad(format!("{e:#}")),
        }
    }
}

/// Write one frame (single `write_all` + flush, mirroring the run store's
/// line-atomic appends: a kill tears at most the final line).
pub fn write_frame<W: Write>(w: &mut W, v: &Value) -> Result<()> {
    w.write_all(encode(v).as_bytes())?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------------

/// A daemon address: Unix socket path or TCP `host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Addr {
    /// Filesystem Unix-domain socket.
    Unix(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl Addr {
    /// `host:port` if the tail after the last `:` parses as a port and the
    /// string is not a path; otherwise a Unix socket path.
    pub fn parse(s: &str) -> Addr {
        if !s.contains('/') {
            if let Some((_, port)) = s.rsplit_once(':') {
                if port.parse::<u16>().is_ok() {
                    return Addr::Tcp(s.to_string());
                }
            }
        }
        Addr::Unix(PathBuf::from(s))
    }

    /// Bind a listener. A stale Unix socket file (a SIGKILLed daemon never
    /// unlinks) is detected by a probe connect: if nothing answers, the
    /// file is removed and the bind retried; if something answers, a
    /// daemon is already serving there.
    pub fn bind(&self) -> Result<ServeListener> {
        match self {
            Addr::Tcp(hostport) => {
                let l = TcpListener::bind(hostport)
                    .with_context(|| format!("binding tcp {hostport}"))?;
                Ok(ServeListener::Tcp(l))
            }
            #[cfg(unix)]
            Addr::Unix(path) => {
                if let Some(dir) = path.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                match UnixListener::bind(path) {
                    Ok(l) => Ok(ServeListener::Unix(l)),
                    Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
                        if UnixStream::connect(path).is_ok() {
                            bail!(
                                "a daemon is already serving on {}",
                                path.display()
                            );
                        }
                        std::fs::remove_file(path)?;
                        let l = UnixListener::bind(path).with_context(|| {
                            format!("binding unix socket {}", path.display())
                        })?;
                        Ok(ServeListener::Unix(l))
                    }
                    Err(e) => Err(e).with_context(|| {
                        format!("binding unix socket {}", path.display())
                    }),
                }
            }
            #[cfg(not(unix))]
            Addr::Unix(path) => bail!(
                "unix socket {:?} unsupported on this platform — use host:port",
                path
            ),
        }
    }

    /// Connect a client.
    pub fn connect(&self) -> Result<Conn> {
        match self {
            Addr::Tcp(hostport) => {
                let s = TcpStream::connect(hostport)
                    .with_context(|| format!("connecting to tcp {hostport}"))?;
                s.set_nodelay(true).ok();
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Addr::Unix(path) => {
                let s = UnixStream::connect(path).with_context(|| {
                    format!("connecting to unix socket {}", path.display())
                })?;
                Ok(Conn::Unix(s))
            }
            #[cfg(not(unix))]
            Addr::Unix(path) => bail!(
                "unix socket {:?} unsupported on this platform — use host:port",
                path
            ),
        }
    }
}

/// Bound daemon listener (Unix or TCP).
pub enum ServeListener {
    #[cfg(unix)]
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl ServeListener {
    pub fn set_nonblocking(&self, on: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            ServeListener::Unix(l) => l.set_nonblocking(on)?,
            ServeListener::Tcp(l) => l.set_nonblocking(on)?,
        }
        Ok(())
    }

    /// Accept one connection; `Ok(None)` when nonblocking and nothing is
    /// waiting.
    pub fn accept(&self) -> Result<Option<Conn>> {
        let res = match self {
            #[cfg(unix)]
            ServeListener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
            ServeListener::Tcp(l) => l.accept().map(|(s, _)| {
                s.set_nodelay(true).ok();
                Conn::Tcp(s)
            }),
        };
        match res {
            Ok(c) => Ok(Some(c)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// One accepted or dialed connection.
#[derive(Debug)]
pub enum Conn {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Conn {
    /// Independent handle on the same socket (reader/writer split, or a
    /// subscriber sink written from worker threads).
    pub fn try_clone(&self) -> Result<Conn> {
        Ok(match self {
            #[cfg(unix)]
            Conn::Unix(s) => Conn::Unix(s.try_clone()?),
            Conn::Tcp(s) => Conn::Tcp(s.try_clone()?),
        })
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d)?,
            Conn::Tcp(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }

    pub fn set_nonblocking(&self, on: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(on)?,
            Conn::Tcp(s) => s.set_nonblocking(on)?,
        }
        Ok(())
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Client → daemon operations. Replies are plain [`Value`] objects tagged
/// by a `"reply"` field (see [`reply`]); the grammar is documented in
/// DESIGN.md §16.
#[derive(Debug, Clone)]
pub enum Request {
    /// Enqueue one sweep under a tenant namespace. `watch` turns the
    /// connection into a result subscription for the accepted job.
    Submit {
        tenant: String,
        spec: super::JobSpec,
        watch: bool,
    },
    /// Queue/running/done counts plus per-job states.
    Status,
    /// Stream result rows as they land, filtered by tenant and/or job id.
    Subscribe {
        tenant: Option<String>,
        job: Option<String>,
    },
    /// Remove a still-queued job (best-effort: running jobs finish).
    Cancel { job: String },
    /// Stop admitting, finish in-flight dispatch groups, flush, exit 0.
    Drain,
    /// Liveness probe.
    Ping,
}

impl Request {
    pub fn to_value(&self) -> Value {
        let mut v = Value::obj();
        match self {
            Request::Submit { tenant, spec, watch } => {
                v.set("op", "submit")
                    .set("tenant", tenant.as_str())
                    .set("spec", spec.to_value());
                if *watch {
                    v.set("watch", true);
                }
            }
            Request::Status => {
                v.set("op", "status");
            }
            Request::Subscribe { tenant, job } => {
                v.set("op", "subscribe");
                if let Some(t) = tenant {
                    v.set("tenant", t.as_str());
                }
                if let Some(j) = job {
                    v.set("job", j.as_str());
                }
            }
            Request::Cancel { job } => {
                v.set("op", "cancel").set("job", job.as_str());
            }
            Request::Drain => {
                v.set("op", "drain");
            }
            Request::Ping => {
                v.set("op", "ping");
            }
        }
        v
    }

    pub fn from_value(v: &Value) -> Result<Request> {
        let op = v.get("op")?.as_str()?;
        Ok(match op {
            "submit" => Request::Submit {
                tenant: v.get("tenant")?.as_str()?.to_string(),
                spec: super::JobSpec::from_value(v.get("spec")?)?,
                watch: v
                    .opt("watch")
                    .and_then(|w| w.as_bool().ok())
                    .unwrap_or(false),
            },
            "status" => Request::Status,
            "subscribe" => Request::Subscribe {
                tenant: v
                    .opt("tenant")
                    .and_then(|t| t.as_str().ok().map(String::from)),
                job: v.opt("job").and_then(|j| j.as_str().ok().map(String::from)),
            },
            "cancel" => Request::Cancel {
                job: v.get("job")?.as_str()?.to_string(),
            },
            "drain" => Request::Drain,
            "ping" => Request::Ping,
            other => bail!("unknown op {other:?}"),
        })
    }
}

/// Start a reply object: `{"reply": kind, ...}`. Reply kinds: `queued`,
/// `overloaded`, `draining`, `status`, `subscribed`, `cancelled`, `pong`,
/// `row`, `job_done`, `bye`, `error`.
pub fn reply(kind: &str) -> Value {
    let mut v = Value::obj();
    v.set("reply", kind);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut v = Value::obj();
        v.set("op", "ping").set("n", 3usize);
        let framed = encode(&v);
        assert!(framed.ends_with('\n'));
        let decoded = decode_line(framed.trim_end_matches('\n')).unwrap();
        assert_eq!(decoded.get("op").unwrap().as_str().unwrap(), "ping");
        assert_eq!(decoded.get("n").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn length_mismatch_rejected() {
        let err = decode_line("5 {\"op\":\"ping\"}").unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"), "{err:#}");
        assert!(decode_line("nope").is_err());
        assert!(decode_line(&format!("{} x", MAX_FRAME + 1)).is_err());
    }

    #[test]
    fn addr_parse_discriminates() {
        assert_eq!(Addr::parse("127.0.0.1:7070"), Addr::Tcp("127.0.0.1:7070".into()));
        assert_eq!(
            Addr::parse("results/serve/serve.sock"),
            Addr::Unix(PathBuf::from("results/serve/serve.sock"))
        );
        // a path with a colon is still a path
        assert_eq!(
            Addr::parse("/tmp/a:b/serve.sock"),
            Addr::Unix(PathBuf::from("/tmp/a:b/serve.sock"))
        );
    }
}
