//! Sweep-as-a-service (DESIGN.md §16).
//!
//! A long-lived `slimadam serve` daemon owning one warm executable cache
//! and a persistent worker pool, fed by many concurrent clients:
//!
//! * [`proto`] — length-prefixed JSONL wire protocol over a Unix socket or
//!   TCP (`submit` / `status` / `subscribe` / `cancel` / `drain` / `ping`),
//!   torn-frame tolerant with the run store's tail discipline.
//! * [`queue`] — durable FIFO queue journaled through the line-atomic
//!   JSONL writer: a SIGKILLed daemon restarts, replays `queue.jsonl`, and
//!   resumes in-flight sweeps through the run-store resume path with zero
//!   re-execution.
//! * [`daemon`] — accept loop, per-tenant run stores, the dispatcher that
//!   plans batched dispatch groups *across* queued requests (queue depth
//!   drives the batch size — the backpressure knob), streaming result
//!   subscriptions, and the graceful drain state machine.
//! * [`client`] — the thin client API behind `slimadam client
//!   submit|watch|status|drain|cancel`.
//!
//! ## Determinism contract
//!
//! A job's result rows are a pure function of its expanded
//! [`TrainConfig`]s — never of arrival order, tenant interleaving, batch
//! grouping, or which daemon lifetime executed them. A sweep submitted to
//! the daemon yields rows byte-identical to the one-shot `slimadam sweep`
//! CLI run of the same grid ([`JobSpec::expand`] mirrors the CLI's config
//! construction exactly; rows go through the scheduler's shared
//! `summary_row`). Tenants are isolated: each namespace owns a private run
//! store directory, and resume lookups never cross namespaces.

pub mod client;
pub mod daemon;
pub mod proto;
pub mod queue;

pub use client::Client;
pub use daemon::{run, spawn, ServeOpts, ServerHandle};

use anyhow::{bail, Result};

use crate::coordinator::{DataSpec, EngineKind, TrainConfig};
use crate::json::Value;
use crate::rng::job_seed;
use crate::runtime::backend::BackendSpec;

/// Tenant namespaces key run-store directories, so they are restricted to
/// one safe path segment.
pub fn valid_tenant(ns: &str) -> bool {
    !ns.is_empty()
        && ns.len() <= 64
        && ns
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// One submitted sweep: the `(optimizer × lr)` grid a single `slimadam
/// sweep` invocation would run. Expansion reproduces the CLI's config
/// construction field for field, which is what makes daemon-run
/// fingerprints byte-identical to one-shot sweeps of the same grid.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Model name (artifact or native builtin).
    pub model: String,
    /// Backend spec string, e.g. `native`, `native+f32`, `pjrt@cpu:0`.
    pub backend: String,
    /// Optimizer presets, grid-major over [`JobSpec::lrs`].
    pub optimizers: Vec<String>,
    /// Learning-rate grid.
    pub lrs: Vec<f64>,
    /// Training steps per run.
    pub steps: usize,
    /// Base seed (shared by every grid point unless `seed_jobs`).
    pub seed: u64,
    /// Gradient accumulation steps.
    pub accum: usize,
    /// `Some(ruleset)` selects the fused train-step engine.
    pub fused: Option<String>,
    /// Derive an independent seed per grid point (`sweep --seed-jobs`).
    pub seed_jobs: bool,
    /// Adaptive rule-switching policy spec (`--adaptive`, DESIGN.md §18):
    /// `enter:exit:patience[:every]`, or `""` for the defaults. Requires
    /// `fused` on the native backend.
    pub adaptive: Option<String>,
}

impl JobSpec {
    /// A minimal native-backend spec (tests and benches).
    pub fn native(model: &str, optimizers: &[&str], lrs: &[f64], steps: usize) -> JobSpec {
        JobSpec {
            model: model.to_string(),
            backend: "native".to_string(),
            optimizers: optimizers.iter().map(|s| s.to_string()).collect(),
            lrs: lrs.to_vec(),
            steps,
            seed: 0,
            accum: 1,
            fused: None,
            seed_jobs: false,
            adaptive: None,
        }
    }

    pub fn to_value(&self) -> Value {
        let mut v = Value::obj();
        v.set("model", self.model.as_str())
            .set("backend", self.backend.as_str())
            .set(
                "optimizers",
                Value::Arr(self.optimizers.iter().map(|s| s.as_str().into()).collect()),
            )
            .set("lrs", Value::Arr(self.lrs.iter().map(|&x| x.into()).collect()))
            .set("steps", self.steps)
            .set("seed", format!("{:016x}", self.seed))
            .set("accum", self.accum);
        if let Some(ruleset) = &self.fused {
            v.set("fused", ruleset.as_str());
        }
        if self.seed_jobs {
            v.set("seed_jobs", true);
        }
        // written only when present, so pre-adaptive daemons and queue
        // files keep reading/writing byte-identical specs
        if let Some(spec) = &self.adaptive {
            v.set("adaptive", spec.as_str());
        }
        v
    }

    pub fn from_value(v: &Value) -> Result<JobSpec> {
        let optimizers: Vec<String> = v
            .get("optimizers")?
            .as_arr()?
            .iter()
            .map(|o| o.as_str().map(String::from))
            .collect::<Result<_>>()?;
        let lrs: Vec<f64> = v
            .get("lrs")?
            .as_arr()?
            .iter()
            .map(|x| x.as_f64())
            .collect::<Result<_>>()?;
        let seed_hex = v.get("seed")?.as_str()?;
        let seed = u64::from_str_radix(seed_hex, 16)
            .map_err(|e| anyhow::anyhow!("bad seed {seed_hex:?}: {e}"))?;
        let spec = JobSpec {
            model: v.get("model")?.as_str()?.to_string(),
            backend: v.get("backend")?.as_str()?.to_string(),
            optimizers,
            lrs,
            steps: v.get("steps")?.as_usize()?,
            seed,
            accum: v.get("accum")?.as_usize()?,
            fused: v
                .opt("fused")
                .and_then(|r| r.as_str().ok().map(String::from)),
            seed_jobs: v
                .opt("seed_jobs")
                .and_then(|b| b.as_bool().ok())
                .unwrap_or(false),
            adaptive: v
                .opt("adaptive")
                .and_then(|a| a.as_str().ok().map(String::from)),
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.optimizers.is_empty() || self.lrs.is_empty() {
            bail!("job spec needs at least one optimizer and one lr");
        }
        if self.steps == 0 {
            bail!("job spec needs steps >= 1");
        }
        if self.optimizers.len() * self.lrs.len() > 4096 {
            bail!("job spec grid exceeds 4096 points");
        }
        BackendSpec::parse(&self.backend)?;
        if let Some(spec) = &self.adaptive {
            crate::rules::adaptive::AdaptivePolicy::parse(spec)?;
            if self.fused.is_none() {
                bail!("adaptive job specs need a fused engine (set \"fused\")");
            }
        }
        Ok(())
    }

    /// Number of grid points this spec expands to.
    pub fn n_configs(&self) -> usize {
        self.optimizers.len() * self.lrs.len()
    }

    /// Expand to the scheduler's config list: `(optimizer, lr)` row-major,
    /// exactly the grid `slimadam sweep --optimizers … --lrs …` builds
    /// (same base-config defaults, same `--seed-jobs` derivation), so the
    /// two paths share config keys and fingerprints byte for byte.
    pub fn expand(&self) -> Result<Vec<TrainConfig>> {
        self.validate()?;
        let backend = BackendSpec::parse(&self.backend)?;
        let mut base =
            TrainConfig::auto(&self.model, &self.optimizers[0], self.lrs[0], self.steps);
        if !TrainConfig::is_vision(&self.model) {
            // the sweep CLI's default LM stream (main.rs data_spec)
            base.data = DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 1234 };
        }
        base.backend = backend;
        base.seed = self.seed;
        base.accum = self.accum;
        if let Some(ruleset) = &self.fused {
            base.engine = EngineKind::Fused(ruleset.clone());
        }
        if let Some(spec) = &self.adaptive {
            base.adaptive = Some(crate::rules::adaptive::AdaptivePolicy::parse(spec)?);
        }
        let mut configs = Vec::with_capacity(self.n_configs());
        for opt in &self.optimizers {
            for &lr in &self.lrs {
                let mut cfg = base.clone();
                cfg.optimizer = opt.clone();
                if self.fused.is_some() {
                    // mirror LrSweep::build_configs: a fused grid routes
                    // each optimizer token to its own fused artifact
                    cfg.engine = EngineKind::Fused(opt.clone());
                }
                cfg.lr = lr;
                if self.seed_jobs {
                    cfg.seed = job_seed(self.seed, configs.len() as u64);
                }
                configs.push(cfg);
            }
        }
        Ok(configs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runstore::config_key;

    #[test]
    fn tenant_validation() {
        assert!(valid_tenant("team-a_1"));
        assert!(!valid_tenant(""));
        assert!(!valid_tenant("a/b"));
        assert!(!valid_tenant("../etc"));
        assert!(!valid_tenant(&"x".repeat(65)));
    }

    #[test]
    fn jobspec_roundtrip() {
        let mut spec = JobSpec::native("mlp_tiny", &["adam", "slimadam"], &[1e-3, 3e-3], 12);
        spec.seed = 7;
        spec.fused = Some("adam".into());
        spec.seed_jobs = true;
        let back = JobSpec::from_value(&spec.to_value()).unwrap();
        assert_eq!(spec, back);
        // adaptive is written only when present (wire back-compat) and
        // round-trips verbatim; an adaptive spec without a fused engine
        // is rejected at validation
        assert!(spec.to_value().opt("adaptive").is_none());
        spec.adaptive = Some("1.0:0.25:3".into());
        let back = JobSpec::from_value(&spec.to_value()).unwrap();
        assert_eq!(spec, back);
        assert!(back.expand().unwrap().iter().all(|c| c.adaptive.is_some()));
        spec.fused = None;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn expand_matches_cli_grid_construction() {
        // mirror main.rs base_config + LrSweep::build_configs by hand
        let spec = JobSpec::native("gpt_micro", &["adam", "slimadam"], &[1e-3, 3e-3], 10);
        let configs = spec.expand().unwrap();
        assert_eq!(configs.len(), 4);

        let mut base = TrainConfig::auto("gpt_micro", "adam", 1e-3, 10);
        base.data = DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 1234 };
        base.backend = BackendSpec::native();
        base.seed = 0;
        base.accum = 1;
        let mut expected = Vec::new();
        for opt in ["adam", "slimadam"] {
            for lr in [1e-3, 3e-3] {
                let mut cfg = base.clone();
                cfg.optimizer = opt.to_string();
                cfg.lr = lr;
                expected.push(cfg);
            }
        }
        for (got, want) in configs.iter().zip(&expected) {
            assert_eq!(config_key(got), config_key(want), "{}", want.label());
        }
    }

    #[test]
    fn seed_jobs_derives_grid_position_seeds() {
        let mut spec = JobSpec::native("mlp_tiny", &["adam"], &[1e-3, 3e-3], 5);
        spec.seed = 42;
        spec.seed_jobs = true;
        let configs = spec.expand().unwrap();
        assert_eq!(configs[0].seed, job_seed(42, 0));
        assert_eq!(configs[1].seed, job_seed(42, 1));
        assert_ne!(configs[0].seed, configs[1].seed);
    }

    #[test]
    fn oversized_grid_rejected() {
        let lrs: Vec<f64> = (0..5000).map(|i| 1e-4 + i as f64 * 1e-7).collect();
        let spec = JobSpec::native("mlp_tiny", &["adam"], &lrs, 5);
        assert!(spec.validate().is_err());
    }
}
