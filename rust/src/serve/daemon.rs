//! The `slimadam serve` daemon (DESIGN.md §16).
//!
//! One process owns the warm executable cache and a **persistent** worker
//! pool: unlike the one-shot scheduler (which spawns scoped workers per
//! sweep), the daemon's workers live for the daemon's lifetime, so their
//! thread-local `exec_cache` entries stay warm across every request that
//! ever shards onto them. Three thread families:
//!
//! * **Accept loop** (the caller's thread): nonblocking accept, one
//!   handler thread per connection, drain supervision.
//! * **Connection handlers**: frame loop — `submit` journals into the
//!   [`DurableQueue`] (bounded: at capacity the reply is an explicit
//!   `overloaded`, nothing is admitted), `subscribe` registers the
//!   connection as a result sink, `status`/`cancel`/`ping` answer inline,
//!   `drain` arms the drain state machine. A malformed frame is rejected
//!   with an `error` reply and the connection continues (resync at the
//!   next newline); a torn frame ends the connection.
//! * **Dispatcher**: collects every queued job into a *wave*, expands the
//!   specs, restores per-tenant resume state, and plans batched dispatch
//!   groups **across** requests with `coordinator::batch::plan` — two
//!   tenants' same-artifact jobs share a lockstep dispatch. The batch cap
//!   adapts to queue depth ([`adaptive_batch`]): an idle daemon runs
//!   unbatched for latency, a deep queue stacks up to the configured cap
//!   for throughput. Result rows stream to the tenant's run store and to
//!   subscribers the moment their group finishes.
//!
//! ## Drain state machine
//!
//! `running → draining → drained`. `drain` (request or SIGTERM/SIGINT)
//! stops admission (`draining` replies), lets in-flight dispatch groups
//! finish, journals their completions, notifies subscribers (`bye`), and
//! returns from [`run`] — the CLI then flushes traces and exits 0. Jobs
//! still queued but never dispatched stay journal-pending and replay on
//! the next start.
//!
//! ## Determinism
//!
//! Job results are pure functions of their configs; wave composition,
//! batch grouping, worker count and tenant interleaving affect only
//! scheduling. Rows are emitted through `SweepScheduler::summary_row` —
//! the same constructor the CLI sweep path uses — with per-job grid
//! indices, so a daemon-run sweep is row-for-row byte-identical to the
//! one-shot CLI run (`rust/tests/serve_daemon.rs`).

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{batch, SweepScheduler, TrainConfig};
use crate::json::Value;
use crate::metrics::JsonlWriter;
use crate::obs::{self, registry, SpanKind};
use crate::rng::stable_hash64;
use crate::runstore::{config_key, RunStore, StoreMeta, SCHEMA_VERSION};

use super::proto::{self, Addr, Conn, FrameReader, Recv, Request, ServeListener};
use super::queue::{Admission, DurableQueue, QueueEntry};
use super::valid_tenant;

/// Daemon configuration (`slimadam serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Unix socket path or `host:port`.
    pub addr: String,
    /// State directory: `queue.jsonl` + `tenants/<ns>/` run stores.
    pub state_dir: PathBuf,
    /// Worker threads (0 = one per core, capped at 8).
    pub workers: usize,
    /// Upper bound for adaptive batched dispatch (1 = never batch).
    pub max_batch: usize,
    /// Bounded-queue capacity in jobs; beyond it submits get `overloaded`.
    pub queue_cap: usize,
    /// Suppress per-row progress lines.
    pub quiet: bool,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            addr: String::new(),
            state_dir: PathBuf::from("results").join("serve"),
            workers: 0,
            max_batch: 8,
            queue_cap: 64,
            quiet: false,
        }
    }
}

/// Queue-depth–adaptive dispatch batch size: the backpressure knob. A
/// near-empty queue dispatches unbatched (lowest submit→complete latency);
/// deeper queues stack same-artifact jobs for throughput, up to `cap`.
pub fn adaptive_batch(queued_configs: usize, cap: usize) -> usize {
    let by_depth = match queued_configs {
        0..=2 => 1,
        3..=8 => 2,
        9..=32 => 4,
        _ => 8,
    };
    by_depth.min(cap.max(1))
}

/// SIGTERM/SIGINT → drain, latched process-wide. The handler only stores
/// a relaxed atomic flag (async-signal-safe); the accept loop polls it.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_drain_signals() {
    extern "C" fn on_signal(_: i32) {
        SIGNAL_DRAIN.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler = on_signal as extern "C" fn(i32);
    unsafe {
        signal(15, handler as usize); // SIGTERM
        signal(2, handler as usize); // SIGINT
    }
}

#[cfg(not(unix))]
fn install_drain_signals() {}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shard {
    deque: Mutex<VecDeque<Task>>,
    wake: Condvar,
}

/// Long-lived sharded workers. Tasks land on the shard their key selects
/// (same key → same worker → warm thread-local `exec_cache` across waves
/// and daemon uptime); idle workers steal from the fullest other shard,
/// bumping the shared `pool.steals` counter.
struct WorkerPool {
    shards: Vec<Arc<Shard>>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    fn new(n: usize) -> WorkerPool {
        let n = n.max(1);
        let shards: Vec<Arc<Shard>> = (0..n)
            .map(|_| {
                Arc::new(Shard {
                    deque: Mutex::new(VecDeque::new()),
                    wake: Condvar::new(),
                })
            })
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let handles = (0..n)
            .map(|w| {
                let shards = shards.clone();
                let stop = stop.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(w, &shards, &stop))
                    .expect("spawning serve worker")
            })
            .collect();
        WorkerPool { shards, stop, handles }
    }

    fn submit(&self, key: u64, task: Task) {
        let shard = &self.shards[(key % self.shards.len() as u64) as usize];
        shard.deque.lock().unwrap().push_back(task);
        shard.wake.notify_one();
    }

    fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        for s in &self.shards {
            s.wake.notify_all();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(me: usize, shards: &[Arc<Shard>], stop: &AtomicBool) {
    let steals = registry::counter("pool.steals");
    loop {
        // Own shard first — shard affinity is what keeps caches warm.
        // Pop under the lock, run outside it: tasks must never block
        // submits to (or length probes of) their shard.
        let own = shards[me].deque.lock().unwrap().pop_front();
        if let Some(task) = own {
            task();
            continue;
        }
        // steal a whole group from the fullest other shard
        let victim = shards
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != me)
            .max_by_key(|(_, s)| s.deque.lock().unwrap().len());
        if let Some((_, s)) = victim {
            let stolen = s.deque.lock().unwrap().pop_back();
            if let Some(task) = stolen {
                steals.inc();
                task();
                continue;
            }
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let guard = shards[me].deque.lock().unwrap();
        if guard.is_empty() && !stop.load(Ordering::SeqCst) {
            let _ = shards[me]
                .wake
                .wait_timeout(guard, Duration::from_millis(20));
        }
    }
}

// ---------------------------------------------------------------------------
// Shared daemon state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct JobState {
    tenant: String,
    total: usize,
    ran: usize,
    skipped: usize,
    state: &'static str, // queued | running | done | failed
}

struct Subscriber {
    conn: Mutex<Conn>,
    tenant: Option<String>,
    job: Option<String>,
    dead: AtomicBool,
}

impl Subscriber {
    fn wants(&self, tenant: &str, job: &str) -> bool {
        if self.dead.load(Ordering::Relaxed) {
            return false;
        }
        self.tenant.as_deref().map_or(true, |t| t == tenant)
            && self.job.as_deref().map_or(true, |j| j == job)
    }

    fn send(&self, frame: &Value) {
        let mut conn = self.conn.lock().unwrap();
        if proto::write_frame(&mut *conn, frame).is_err() {
            self.dead.store(true, Ordering::Relaxed);
        }
    }
}

struct Shared {
    opts: ServeOpts,
    queue: Mutex<DurableQueue>,
    /// Dispatcher wake (paired with `queue`).
    work: Condvar,
    jobs: Mutex<HashMap<String, JobState>>,
    subs: Mutex<Vec<Arc<Subscriber>>>,
    draining: AtomicBool,
    /// Set once the dispatcher exits; the accept loop then shuts down.
    dispatcher_done: AtomicBool,
}

impl Shared {
    fn publish(&self, tenant: &str, job: &str, frame: &Value) {
        let subs = self.subs.lock().unwrap();
        for s in subs.iter() {
            if s.wants(tenant, job) {
                s.send(frame);
            }
        }
    }

    fn broadcast(&self, frame: &Value) {
        let subs = self.subs.lock().unwrap();
        for s in subs.iter() {
            if !s.dead.load(Ordering::Relaxed) {
                s.send(frame);
            }
        }
    }

    fn prune_subs(&self) {
        self.subs
            .lock()
            .unwrap()
            .retain(|s| !s.dead.load(Ordering::Relaxed));
    }

    fn set_queue_gauges(&self) {
        let q = self.queue.lock().unwrap();
        registry::gauge("serve.queue_depth").set(q.queued() as i64);
        registry::gauge("serve.queue_configs").set(q.queued_configs() as i64);
    }
}

/// Handle on an in-process daemon ([`spawn`]) — tests and benches drive it
/// through a [`super::Client`] and `join` after draining.
pub struct ServerHandle {
    /// The address the daemon is serving on.
    pub addr: String,
    thread: JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Wait for the daemon to drain and return its exit result.
    pub fn join(self) -> Result<()> {
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve daemon panicked"),
        }
    }
}

/// Run the daemon on the caller's thread until drained. Exit `Ok(())`
/// means a graceful drain — the CLI maps it to exit code 0.
pub fn run(opts: ServeOpts) -> Result<()> {
    serve_on(Addr::parse(&opts.addr).bind()?, opts)
}

/// Bind and serve on a background thread (in-process daemon for tests and
/// benches — same code path as [`run`]).
pub fn spawn(opts: ServeOpts) -> Result<ServerHandle> {
    let listener = Addr::parse(&opts.addr).bind()?;
    let addr = opts.addr.clone();
    let thread = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || serve_on(listener, opts))?;
    Ok(ServerHandle { addr, thread })
}

fn serve_on(listener: ServeListener, opts: ServeOpts) -> Result<()> {
    install_drain_signals();
    SIGNAL_DRAIN.store(false, Ordering::Relaxed);
    let queue = DurableQueue::open(&opts.state_dir, opts.queue_cap)?;
    let replayed = queue.queued();
    if !opts.quiet {
        eprintln!(
            "serve: listening on {} — state {}, {} job(s) replayed{}",
            opts.addr,
            opts.state_dir.display(),
            replayed,
            if queue.replay_skipped > 0 {
                format!(" ({} torn/bad journal row(s) skipped)", queue.replay_skipped)
            } else {
                String::new()
            }
        );
    }
    let workers = if opts.workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(2)
    } else {
        opts.workers
    };
    let shared = Arc::new(Shared {
        opts: opts.clone(),
        queue: Mutex::new(queue),
        work: Condvar::new(),
        jobs: Mutex::new(HashMap::new()),
        subs: Mutex::new(Vec::new()),
        draining: AtomicBool::new(false),
        dispatcher_done: AtomicBool::new(false),
    });
    // replayed jobs surface in status as queued
    {
        let q = shared.queue.lock().unwrap();
        let mut jobs = shared.jobs.lock().unwrap();
        for e in q.pending_entries() {
            jobs.insert(
                e.id.clone(),
                JobState {
                    tenant: e.tenant.clone(),
                    total: e.spec.n_configs(),
                    ran: 0,
                    skipped: 0,
                    state: "queued",
                },
            );
        }
    }
    shared.set_queue_gauges();

    let pool = WorkerPool::new(workers);
    let dispatcher = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("serve-dispatch".into())
            .spawn(move || {
                dispatcher_loop(&shared, &pool);
                pool.shutdown();
            })?
    };

    listener.set_nonblocking(true)?;
    let mut handler_seq = 0usize;
    loop {
        if SIGNAL_DRAIN.load(Ordering::Relaxed) {
            shared.draining.store(true, Ordering::SeqCst);
            shared.work.notify_all();
        }
        if shared.dispatcher_done.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok(Some(conn)) => {
                let shared = shared.clone();
                handler_seq += 1;
                let _ = std::thread::Builder::new()
                    .name(format!("serve-conn-{handler_seq}"))
                    .spawn(move || handle_conn(&shared, conn));
            }
            Ok(None) => std::thread::sleep(Duration::from_millis(10)),
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    dispatcher.join().ok();
    shared.broadcast(&proto::reply("bye"));
    // a SIGKILL leaves the socket file behind; a drain cleans it up
    if let Addr::Unix(path) = Addr::parse(&shared.opts.addr) {
        drop(listener);
        let _ = std::fs::remove_file(path);
    }
    if !shared.opts.quiet {
        eprintln!("serve: drained");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Connection handler
// ---------------------------------------------------------------------------

fn handle_conn(shared: &Arc<Shared>, conn: Conn) {
    let Ok(write_half) = conn.try_clone() else { return };
    let write_half = Arc::new(Mutex::new(write_half));
    let mut reader = FrameReader::new(conn);
    loop {
        match reader.read_frame() {
            Recv::Frame(v) => {
                let reply = match Request::from_value(&v) {
                    Ok(req) => handle_request(shared, &write_half, req),
                    Err(e) => {
                        let mut r = proto::reply("error");
                        r.set("error", format!("{e:#}"));
                        r
                    }
                };
                let mut w = write_half.lock().unwrap();
                if proto::write_frame(&mut *w, &reply).is_err() {
                    return;
                }
            }
            // Malformed but complete line: reject the frame, keep the
            // connection — the stream is already resynced past its \n.
            Recv::Bad(reason) => {
                registry::counter("serve.bad_frames").inc();
                let mut r = proto::reply("error");
                r.set("error", format!("bad frame: {reason}"));
                let mut w = write_half.lock().unwrap();
                if proto::write_frame(&mut *w, &r).is_err() {
                    return;
                }
            }
            // Torn mid-frame (peer killed) or clean EOF: done.
            Recv::Torn | Recv::Eof => return,
        }
    }
}

fn handle_request(
    shared: &Arc<Shared>,
    write_half: &Arc<Mutex<Conn>>,
    req: Request,
) -> Value {
    match req {
        Request::Ping => proto::reply("pong"),
        Request::Drain => {
            shared.draining.store(true, Ordering::SeqCst);
            shared.work.notify_all();
            proto::reply("draining")
        }
        Request::Status => status_reply(shared),
        Request::Cancel { job } => {
            let cancelled = {
                let mut q = shared.queue.lock().unwrap();
                q.cancel(&job).unwrap_or(false)
            };
            if cancelled {
                if let Some(j) = shared.jobs.lock().unwrap().get_mut(&job) {
                    j.state = "cancelled";
                }
                shared.set_queue_gauges();
            }
            let mut r = proto::reply("cancelled");
            r.set("job", job.as_str()).set("removed", cancelled);
            r
        }
        Request::Subscribe { tenant, job } => {
            let sub = subscribe(shared, write_half, tenant, job);
            match sub {
                Ok(()) => proto::reply("subscribed"),
                Err(e) => {
                    let mut r = proto::reply("error");
                    r.set("error", format!("{e:#}"));
                    r
                }
            }
        }
        Request::Submit { tenant, spec, watch } => {
            if !valid_tenant(&tenant) {
                let mut r = proto::reply("error");
                r.set(
                    "error",
                    format!(
                        "invalid tenant {tenant:?}: one path-safe segment \
                         ([A-Za-z0-9_-], ≤64 chars)"
                    ),
                );
                return r;
            }
            if let Err(e) = spec.validate() {
                let mut r = proto::reply("error");
                r.set("error", format!("invalid job spec: {e:#}"));
                return r;
            }
            if shared.draining.load(Ordering::SeqCst) {
                return proto::reply("draining");
            }
            let admission = {
                let mut q = shared.queue.lock().unwrap();
                let adm = q.submit(&tenant, spec);
                if let Ok(Admission::Queued(entry)) = &adm {
                    // Register job state and any watch subscription while
                    // still holding the queue lock: the dispatcher cannot
                    // take this job (take_all needs the lock) until both
                    // are visible, so even a microsecond synthetic wave
                    // can never outrun its own watcher. Stream frames may
                    // still reach the wire before the queued reply — the
                    // client buffers them (`Client::request`).
                    shared.jobs.lock().unwrap().insert(
                        entry.id.clone(),
                        JobState {
                            tenant: entry.tenant.clone(),
                            total: entry.spec.n_configs(),
                            ran: 0,
                            skipped: 0,
                            state: "queued",
                        },
                    );
                    if watch {
                        let _ = subscribe(
                            shared,
                            write_half,
                            None,
                            Some(entry.id.clone()),
                        );
                    }
                }
                adm
            };
            match admission {
                Err(e) => {
                    let mut r = proto::reply("error");
                    r.set("error", format!("journal write failed: {e:#}"));
                    r
                }
                Ok(Admission::Overloaded { queue_depth }) => {
                    registry::counter("serve.overloaded").inc();
                    let mut r = proto::reply("overloaded");
                    r.set("queue_depth", queue_depth)
                        .set("queue_cap", shared.opts.queue_cap);
                    r
                }
                Ok(Admission::Queued(entry)) => {
                    registry::counter("serve.submitted").inc();
                    shared.set_queue_gauges();
                    shared.work.notify_all();
                    let mut r = proto::reply("queued");
                    r.set("job", entry.id.as_str())
                        .set("tenant", entry.tenant.as_str())
                        .set("configs", entry.spec.n_configs())
                        .set("seq", entry.seq as usize);
                    r
                }
            }
        }
    }
}

fn subscribe(
    shared: &Arc<Shared>,
    write_half: &Arc<Mutex<Conn>>,
    tenant: Option<String>,
    job: Option<String>,
) -> Result<()> {
    let conn = write_half.lock().unwrap().try_clone()?;
    shared.subs.lock().unwrap().push(Arc::new(Subscriber {
        conn: Mutex::new(conn),
        tenant,
        job,
        dead: AtomicBool::new(false),
    }));
    Ok(())
}

fn status_reply(shared: &Arc<Shared>) -> Value {
    let (queued, queued_configs, live) = {
        let q = shared.queue.lock().unwrap();
        (q.queued(), q.queued_configs(), q.live())
    };
    let jobs = shared.jobs.lock().unwrap();
    let mut running = 0usize;
    let mut done = 0usize;
    let mut job_list = Vec::new();
    for (id, j) in jobs.iter() {
        match j.state {
            "running" => running += 1,
            "done" | "failed" => done += 1,
            _ => {}
        }
        let mut row = Value::obj();
        row.set("job", id.as_str())
            .set("tenant", j.tenant.as_str())
            .set("state", j.state)
            .set("total", j.total)
            .set("ran", j.ran)
            .set("skipped", j.skipped);
        job_list.push(row);
    }
    job_list.sort_by(|a, b| {
        let key = |v: &Value| {
            v.opt("job")
                .and_then(|j| j.as_str().ok().map(String::from))
                .unwrap_or_default()
        };
        key(a).cmp(&key(b))
    });
    let mut r = proto::reply("status");
    r.set("queued", queued)
        .set("queued_configs", queued_configs)
        .set("live", live)
        .set("running", running)
        .set("done", done)
        .set("queue_cap", shared.opts.queue_cap)
        .set("draining", shared.draining.load(Ordering::SeqCst))
        .set("jobs", Value::Arr(job_list));
    r
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn dispatcher_loop(shared: &Arc<Shared>, pool: &WorkerPool) {
    loop {
        // Wait for work or a drain. Guard the queue lock only while
        // deciding; waves execute lock-free so submits keep landing.
        let wave: Vec<QueueEntry> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.draining.load(Ordering::SeqCst) {
                    drop(q);
                    shared.dispatcher_done.store(true, Ordering::SeqCst);
                    return;
                }
                if q.queued() > 0 {
                    break q.take_all();
                }
                let (guard, _) = shared
                    .work
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
        };
        shared.set_queue_gauges();
        {
            let mut jobs = shared.jobs.lock().unwrap();
            for e in &wave {
                if let Some(j) = jobs.get_mut(&e.id) {
                    j.state = "running";
                }
            }
        }
        if let Err(e) = run_wave(shared, pool, &wave) {
            // Wave-level failure (store open, journal write): mark every
            // job failed so clients see a terminal state. Their journal
            // rows stay pending and replay on the next daemon start.
            eprintln!("serve: wave failed: {e:#}");
            let mut jobs = shared.jobs.lock().unwrap();
            for e in &wave {
                if let Some(j) = jobs.get_mut(&e.id) {
                    j.state = "failed";
                }
            }
        }
        shared.prune_subs();
    }
}

struct WaveJob {
    entry: QueueEntry,
    /// Indices into the wave's flat config list.
    flat: std::ops::Range<usize>,
    skipped: usize,
    completed: AtomicUsize,
    failed: AtomicBool,
    /// Finalize-once latch: the last executed config and the resume-only
    /// sweep both race toward [`finalize_job`].
    finalized: AtomicBool,
}

/// Execute one wave: every job taken from the queue, planned together.
fn run_wave(shared: &Arc<Shared>, pool: &WorkerPool, wave: &[QueueEntry]) -> Result<()> {
    let t0 = obs::clock();
    // --- expand specs into one flat config list -------------------------
    let mut flat: Vec<TrainConfig> = Vec::new();
    let mut jobs: Vec<WaveJob> = Vec::new();
    for entry in wave {
        let configs = entry
            .spec
            .expand()
            .with_context(|| format!("expanding job {}", entry.id))?;
        let start = flat.len();
        flat.extend(configs);
        jobs.push(WaveJob {
            entry: entry.clone(),
            flat: start..flat.len(),
            skipped: 0,
            completed: AtomicUsize::new(0),
            failed: AtomicBool::new(false),
            finalized: AtomicBool::new(false),
        });
    }
    let keys: Vec<u64> = flat.iter().map(config_key).collect();
    // flat index → owning wave job
    let mut owner: Vec<usize> = vec![0; flat.len()];
    for (j, job) in jobs.iter().enumerate() {
        for slot in &mut owner[job.flat.clone()] {
            *slot = j;
        }
    }

    // --- per-tenant stores + resume indices -----------------------------
    // Tenant isolation: each namespace gets a private store directory and
    // a private resume index — one tenant's completed rows never satisfy
    // another's lookups, even for identical configs.
    let tenants_dir = shared.opts.state_dir.join("tenants");
    let mut stores: HashMap<String, (RunStore, crate::runstore::RunIndex)> =
        HashMap::new();
    for job in &jobs {
        if stores.contains_key(&job.entry.tenant) {
            continue;
        }
        let base = &flat[job.flat.start];
        let meta = StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: job.entry.spec.seed,
            backend: base.backend.key(),
        };
        let store = RunStore::open_with(tenants_dir.join(&job.entry.tenant), &meta)?;
        store.repair_tails()?;
        let index = store.index()?;
        stores.insert(job.entry.tenant.clone(), (store, index));
    }
    let mut writers: HashMap<String, Arc<Mutex<JsonlWriter>>> = HashMap::new();
    for (tenant, (store, _)) in &stores {
        writers.insert(
            tenant.clone(),
            Arc::new(Mutex::new(JsonlWriter::append(store.primary())?)),
        );
    }

    // --- resume: skip configs the tenant's store already holds ----------
    let jobs_skipped = registry::counter("sweep.jobs_skipped");
    let mut pending: Vec<usize> = Vec::with_capacity(flat.len());
    for (i, key) in keys.iter().enumerate() {
        let job = &jobs[owner[i]];
        let (_, index) = &stores[&job.entry.tenant];
        if index.contains(*key) {
            job.completed.fetch_add(1, Ordering::Relaxed);
            jobs_skipped.inc();
            obs::emit_instant(SpanKind::ResumeSkip, obs::NO_LABEL, [i as u64, 0, 0, 0]);
            continue;
        }
        pending.push(i);
    }
    for job in jobs.iter_mut() {
        job.skipped = job.completed.load(Ordering::Relaxed);
    }
    let jobs = Arc::new(jobs);

    // --- plan dispatch groups across every queued request ---------------
    let batch = adaptive_batch(pending.len(), shared.opts.max_batch);
    let groups: Vec<Vec<usize>> = if batch <= 1 {
        pending.iter().map(|&i| vec![i]).collect()
    } else {
        batch::plan(&flat, &pending, batch)
    };
    let occupancy = registry::histogram("batch.occupancy");
    for g in &groups {
        occupancy.observe(g.len() as u64);
    }
    if !shared.opts.quiet {
        eprintln!(
            "serve: wave — {} job(s), {} config(s) ({} resumed), {} group(s), batch ≤{batch}",
            wave.len(),
            flat.len(),
            flat.len() - pending.len(),
            groups.len(),
        );
    }

    // --- execute on the persistent pool ---------------------------------
    struct WaveSync {
        remaining: Mutex<usize>,
        done: Condvar,
    }
    let sync = Arc::new(WaveSync {
        remaining: Mutex::new(groups.len()),
        done: Condvar::new(),
    });
    let flat = Arc::new(flat);
    let keys = Arc::new(keys);
    let owner = Arc::new(owner);
    let writers = Arc::new(writers);
    let jobs_run = registry::counter("sweep.jobs_run");
    for group in groups {
        let shard = stable_hash64(
            SweepScheduler::shard_key(&flat[group[0]]).as_bytes(),
        );
        let (flat, keys, owner, writers, jobs, sync, shared) = (
            flat.clone(),
            keys.clone(),
            owner.clone(),
            writers.clone(),
            jobs.clone(),
            sync.clone(),
            shared.clone(),
        );
        let jobs_run = jobs_run.clone();
        pool.submit(shard, Box::new(move || {
            match batch::run_group(&flat, &group) {
                Ok(summaries) => {
                    for (&i, summary) in group.iter().zip(&summaries) {
                        let job = &jobs[owner[i]];
                        let cfg = &flat[i];
                        // per-job grid index — identical to the row the
                        // one-shot CLI sweep of this grid would write
                        let local = i - job.flat.start;
                        let row = SweepScheduler::summary_row(cfg, summary, local);
                        debug_assert_eq!(config_key(cfg), keys[i]);
                        {
                            let writer = &writers[&job.entry.tenant];
                            let mut w = writer.lock().unwrap();
                            let append_t0 = obs::clock();
                            if let Err(e) = w.write(&row) {
                                eprintln!(
                                    "serve: row append failed for {}: {e:#}",
                                    job.entry.id
                                );
                                job.failed.store(true, Ordering::Relaxed);
                            }
                            obs::emit_since(
                                SpanKind::StoreAppend,
                                obs::NO_LABEL,
                                append_t0,
                                [local as u64, 0, 0, 0],
                            );
                        }
                        registry::counter("serve.rows_streamed").inc();
                        let mut frame = proto::reply("row");
                        frame
                            .set("tenant", job.entry.tenant.as_str())
                            .set("job", job.entry.id.as_str())
                            .set("row", row);
                        shared.publish(&job.entry.tenant, &job.entry.id, &frame);
                        if !shared.opts.quiet {
                            eprintln!(
                                "  [{}] {:40} loss={:.4}{}",
                                job.entry.id,
                                summary.label,
                                summary.result.final_train_loss,
                                if summary.result.diverged { "  DIVERGED" } else { "" }
                            );
                        }
                        finish_one(&shared, job);
                    }
                    jobs_run.add(group.len() as u64);
                }
                Err(e) => {
                    eprintln!("serve: group failed: {e:#}");
                    for &i in &group {
                        let job = &jobs[owner[i]];
                        job.failed.store(true, Ordering::Relaxed);
                        finish_one(&shared, job);
                    }
                }
            }
            let mut left = sync.remaining.lock().unwrap();
            *left -= 1;
            if *left == 0 {
                sync.done.notify_all();
            }
        }));
    }
    // resume-only jobs (every config skipped) complete without dispatch
    for job in jobs.iter() {
        if job.flat.len() == job.completed.load(Ordering::Relaxed) {
            finalize_job(shared, job);
        }
    }
    let mut left = sync.remaining.lock().unwrap();
    while *left > 0 {
        left = sync.done.wait(left).unwrap();
    }
    drop(left);
    registry::counter("serve.waves").inc();
    obs::emit_since(
        SpanKind::ServeWave,
        obs::NO_LABEL,
        t0,
        [wave.len() as u64, jobs.iter().map(|j| j.flat.len()).sum::<usize>() as u64, batch as u64, 0],
    );
    Ok(())
}

/// Count one finished config toward its job; finalize on the last one.
fn finish_one(shared: &Arc<Shared>, job: &WaveJob) {
    let done = job.completed.fetch_add(1, Ordering::Relaxed) + 1;
    if done == job.flat.len() {
        finalize_job(shared, job);
    }
}

/// Journal a job's completion, update status, notify subscribers.
fn finalize_job(shared: &Arc<Shared>, job: &WaveJob) {
    if job.finalized.swap(true, Ordering::SeqCst) {
        return;
    }
    let failed = job.failed.load(Ordering::Relaxed);
    let total = job.flat.len();
    let ran = total - job.skipped;
    if !failed {
        let mut q = shared.queue.lock().unwrap();
        if let Err(e) = q.done(&job.entry.id, ran, job.skipped) {
            eprintln!("serve: journaling done({}) failed: {e:#}", job.entry.id);
        }
    }
    // a failed job journals nothing: it stays pending (and holds its
    // capacity slot) and replays — resuming past completed rows — on the
    // next daemon start
    registry::counter("serve.jobs_completed").inc();
    {
        let mut jobs = shared.jobs.lock().unwrap();
        if let Some(j) = jobs.get_mut(&job.entry.id) {
            j.state = if failed { "failed" } else { "done" };
            j.ran = ran;
            j.skipped = job.skipped;
        }
    }
    let mut frame = proto::reply("job_done");
    frame
        .set("job", job.entry.id.as_str())
        .set("tenant", job.entry.tenant.as_str())
        .set("ran", ran)
        .set("skipped", job.skipped)
        .set("failed", failed);
    shared.publish(&job.entry.tenant, &job.entry.id, &frame);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_batch_tracks_depth_and_cap() {
        assert_eq!(adaptive_batch(1, 8), 1);
        assert_eq!(adaptive_batch(4, 8), 2);
        assert_eq!(adaptive_batch(16, 8), 4);
        assert_eq!(adaptive_batch(64, 8), 8);
        assert_eq!(adaptive_batch(64, 2), 2, "cap wins");
        assert_eq!(adaptive_batch(64, 0), 1, "cap 0 means unbatched");
    }

    #[test]
    fn worker_pool_runs_tasks_with_shard_affinity_and_stealing() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        for i in 0..32u64 {
            let hits = hits.clone();
            // all tasks on one shard: the other worker must steal
            pool.submit(i % 1, Box::new(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while hits.load(Ordering::SeqCst) < 32 {
            assert!(std::time::Instant::now() < deadline, "pool stalled");
            std::thread::sleep(Duration::from_millis(5));
        }
        pool.shutdown();
        assert_eq!(hits.load(Ordering::SeqCst), 32);
    }
}
