//! Execution engines over compiled executables.
//!
//! * [`GradEngine`] — split engine: the artifact computes
//!   `(loss, grads...) = grad_step(params..., batch...)` and the Rust
//!   [`crate::optim`] family applies the update. This is the analysis /
//!   sweep path: optimizer rules change without re-lowering HLO.
//! * [`TrainEngine`] — fused engine: the artifact is the whole
//!   `train_step` (fwd + bwd + clip + fused update) and optimizer state
//!   lives in literals that are fed straight back into the next dispatch —
//!   the production hot path.
//!
//! Both engines are backend-agnostic (DESIGN.md §11): they consume a
//! [`Compiled`], which wraps whatever [`super::backend::Executable`] the
//! chosen [`super::backend::Backend`] produced — the PJRT path (feature
//! `pjrt`) or the pure-Rust native interpreter.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use crate::tensor::Tensor;

use super::backend::{Backend, Executable};
use super::literal::{
    f32_literal, i32_literal, literal_to_tensor, scalar_f32, tensor_to_literal,
};
use super::manifest::Manifest;

/// One batch input in host form.
#[derive(Debug, Clone)]
pub enum BatchData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

/// Where an artifact's computation comes from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactSource {
    /// AOT-lowered HLO text on disk (`make artifacts`) — compiled by the
    /// PJRT backend.
    HloText(PathBuf),
    /// Builtin model known to the native interpreter
    /// (`runtime::backend::native`) — no files needed.
    Builtin,
}

/// A loaded (not yet compiled) artifact: manifest + computation source.
#[derive(Clone)]
pub struct Artifact {
    /// Artifact name, e.g. `gpt_nano.grad` or `mlp_tiny.train.adam`.
    pub name: String,
    pub manifest: Manifest,
    pub source: ArtifactSource,
    /// Stable digest of the manifest JSON bytes. Together with the
    /// artifact name, backend and device this keys the executable cache
    /// (`coordinator::exec_cache`): re-lowering an artifact changes its
    /// manifest, so stale compiled executables can never be reused.
    pub manifest_hash: u64,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.manifest.json`.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Artifact> {
        let dir = dir.as_ref();
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let man_path = dir.join(format!("{name}.manifest.json"));
        if !hlo_path.exists() {
            bail!(
                "artifact {name:?} not found in {dir:?} — run `make artifacts` \
                 (or use `--backend native` for the builtin models)"
            );
        }
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?}"))?;
        let manifest = Manifest::parse(&text)?;
        manifest.validate()?;
        let manifest_hash = crate::rng::stable_hash64(text.as_bytes());
        Ok(Artifact {
            name: name.to_string(),
            manifest,
            source: ArtifactSource::HloText(hlo_path),
            manifest_hash,
        })
    }

    /// The on-disk HLO path, when this artifact has one.
    pub fn hlo_path(&self) -> Option<&Path> {
        match &self.source {
            ArtifactSource::HloText(p) => Some(p),
            ArtifactSource::Builtin => None,
        }
    }

    /// Compile on the given backend.
    pub fn compile(&self, backend: &dyn Backend) -> Result<Compiled> {
        Ok(Compiled {
            exe: backend.compile(self)?,
            manifest: self.manifest.clone(),
        })
    }
}

/// A compiled executable plus its manifest — the unit `GradEngine` /
/// `TrainEngine` consume, independent of which backend produced it.
pub struct Compiled {
    exe: Box<dyn Executable>,
    pub manifest: Manifest,
}

impl Compiled {
    /// Wrap an already-built executable (backends construct through
    /// [`Artifact::compile`]; this exists for tests and custom backends).
    pub fn new(exe: Box<dyn Executable>, manifest: Manifest) -> Compiled {
        Compiled { exe, manifest }
    }

    /// Execute one step: input literals in manifest order → output
    /// literals in manifest order.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.n_inputs(),
            "expected {} inputs, got {}",
            self.manifest.n_inputs(),
            inputs.len()
        );
        let outs = self.exe.run(inputs)?;
        anyhow::ensure!(
            outs.len() == self.manifest.outputs.len(),
            "executable returned {} outputs, manifest names {}",
            outs.len(),
            self.manifest.outputs.len()
        );
        Ok(outs)
    }

    /// Execute one step for several independent jobs in one backend call
    /// (DESIGN.md §12). Each job's inputs/outputs follow the same
    /// manifest-order contract as [`Compiled::run`]; results are
    /// bit-identical to running the jobs one at a time.
    pub fn run_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        for (b, inputs) in jobs.iter().enumerate() {
            anyhow::ensure!(
                inputs.len() == self.manifest.n_inputs(),
                "job {b}: expected {} inputs, got {}",
                self.manifest.n_inputs(),
                inputs.len()
            );
        }
        let outs = self.exe.run_batch(jobs)?;
        anyhow::ensure!(
            outs.len() == jobs.len(),
            "executable returned {} job results for {} jobs",
            outs.len(),
            jobs.len()
        );
        for (b, out) in outs.iter().enumerate() {
            anyhow::ensure!(
                out.len() == self.manifest.outputs.len(),
                "job {b}: executable returned {} outputs, manifest names {}",
                out.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(outs)
    }
}

fn batch_to_literal(data: &BatchData, shape: &[usize]) -> Result<Literal> {
    match data {
        BatchData::I32(v) => i32_literal(v, shape),
        BatchData::F32(v) => f32_literal(v, shape),
    }
}

/// Split engine: the artifact computes loss+grads, Rust owns the optimizer.
pub struct GradEngine {
    compiled: Compiled,
}

impl GradEngine {
    pub fn new(dir: impl AsRef<Path>, model: &str, backend: &dyn Backend) -> Result<GradEngine> {
        let art = backend.load_artifact(dir.as_ref(), &format!("{model}.grad"))?;
        Self::from_artifact(&art, backend)
    }

    /// Compile an already-loaded grad artifact (the executable cache's
    /// miss path — it loads the artifact itself to learn the cache key).
    pub fn from_artifact(art: &Artifact, backend: &dyn Backend) -> Result<GradEngine> {
        anyhow::ensure!(art.manifest.kind == "grad_step");
        Ok(GradEngine {
            compiled: art.compile(backend)?,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.compiled.manifest
    }

    /// One gradient evaluation: returns `(loss, grads)` in param order.
    pub fn step(&self, params: &[Tensor], batch: &[BatchData]) -> Result<(f32, Vec<Tensor>)> {
        let man = &self.compiled.manifest;
        anyhow::ensure!(params.len() == man.n_params(), "param count");
        anyhow::ensure!(batch.len() == man.batch.len(), "batch count");

        let mut inputs = Vec::with_capacity(man.n_inputs());
        for t in params {
            inputs.push(tensor_to_literal(t)?);
        }
        for (b, info) in batch.iter().zip(&man.batch) {
            inputs.push(batch_to_literal(b, &info.shape)?);
        }
        let outs = self.compiled.run(&inputs)?;
        let loss = super::literal::scalar_value(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()
            .context("converting grads")?;
        Ok((loss, grads))
    }

    /// Gradient evaluations for several independent jobs in one backend
    /// call (DESIGN.md §12): `jobs[b]` is job `b`'s `(params, batch)`
    /// pair, assembled exactly as [`GradEngine::step`] would, and the
    /// per-job `(loss, grads)` results are bit-identical to calling
    /// `step` once per job.
    pub fn step_batch(
        &self,
        jobs: &[(&[Tensor], &[BatchData])],
    ) -> Result<Vec<(f32, Vec<Tensor>)>> {
        let man = &self.compiled.manifest;
        let mut all: Vec<Vec<Literal>> = Vec::with_capacity(jobs.len());
        for (params, batch) in jobs {
            anyhow::ensure!(params.len() == man.n_params(), "param count");
            anyhow::ensure!(batch.len() == man.batch.len(), "batch count");
            let mut inputs = Vec::with_capacity(man.n_inputs());
            for t in *params {
                inputs.push(tensor_to_literal(t)?);
            }
            for (b, info) in batch.iter().zip(&man.batch) {
                inputs.push(batch_to_literal(b, &info.shape)?);
            }
            all.push(inputs);
        }
        let outs = self.compiled.run_batch(&all)?;
        outs.into_iter()
            .map(|out| {
                let loss = super::literal::scalar_value(&out[0])?;
                let grads = out[1..]
                    .iter()
                    .map(literal_to_tensor)
                    .collect::<Result<Vec<_>>>()
                    .context("converting grads")?;
                Ok((loss, grads))
            })
            .collect()
    }
}

/// Fused engine: one dispatch per training step; parameter and optimizer
/// state stay in literals between steps.
///
/// The compiled executable is held behind `Rc` so sweeps can share one
/// compilation across many engine instances on the same worker thread
/// (each run still owns private state literals).
pub struct TrainEngine {
    compiled: Rc<Compiled>,
    /// params..., m..., v... in manifest order
    state: Vec<Literal>,
    pub step_idx: usize,
}

/// Outputs of one fused step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
}

impl TrainEngine {
    /// Load `<model>.train.<ruleset>` and initialize state.
    ///
    /// `init_scheme` is "mitchell" or "default" (paper §4.3); `seed` fixes
    /// the parameter draw.
    pub fn new(
        dir: impl AsRef<Path>,
        model: &str,
        ruleset: &str,
        backend: &dyn Backend,
        init_scheme: &str,
        seed: u64,
    ) -> Result<TrainEngine> {
        let art =
            backend.load_artifact(dir.as_ref(), &format!("{model}.train.{ruleset}"))?;
        anyhow::ensure!(art.manifest.kind == "train_step");
        Self::with_compiled(Rc::new(art.compile(backend)?), init_scheme, seed)
    }

    /// Build an engine over an already-compiled (possibly cached, shared)
    /// train-step executable, initializing fresh parameter/optimizer state.
    pub fn with_compiled(
        compiled: Rc<Compiled>,
        init_scheme: &str,
        seed: u64,
    ) -> Result<TrainEngine> {
        let man = &compiled.manifest;

        let mut rng = crate::rng::Rng::new(seed);
        let mut state = Vec::with_capacity(3 * man.n_params());
        for p in &man.params {
            let init = match init_scheme {
                "mitchell" => &p.init_mitchell,
                "default" => &p.init_default,
                s => bail!("unknown init scheme {s:?}"),
            };
            state.push(tensor_to_literal(&init.materialize(&p.shape, &mut rng))?);
        }
        for i in 0..man.n_params() {
            state.push(tensor_to_literal(&Tensor::zeros(man.m_shape(i)))?);
        }
        let v_shapes = man
            .v_shapes
            .clone()
            .ok_or_else(|| anyhow!("train_step manifest missing v_shapes"))?;
        for vs in &v_shapes {
            state.push(tensor_to_literal(&Tensor::zeros(vs))?);
        }
        Ok(TrainEngine {
            compiled,
            state,
            step_idx: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.compiled.manifest
    }

    /// Restore parameters from host tensors (fine-tuning / checkpoints),
    /// resetting optimizer state.
    pub fn load_params(&mut self, params: &[Tensor]) -> Result<()> {
        let man = &self.compiled.manifest;
        anyhow::ensure!(params.len() == man.n_params());
        for (i, t) in params.iter().enumerate() {
            self.state[i] = tensor_to_literal(t)?;
        }
        Ok(())
    }

    /// One fused training step. `lr` is the already-scheduled rate.
    pub fn step(&mut self, batch: &[BatchData], lr: f32) -> Result<StepStats> {
        let man = &self.compiled.manifest;
        self.step_idx += 1;
        let n = man.n_params();

        let mut inputs: Vec<Literal> = Vec::with_capacity(man.n_inputs());
        // Move state in; it is replaced by the outputs below.
        inputs.append(&mut self.state);
        for (b, info) in batch.iter().zip(&man.batch) {
            inputs.push(batch_to_literal(b, &info.shape)?);
        }
        inputs.push(scalar_f32(self.step_idx as f32));
        inputs.push(scalar_f32(lr));

        let mut outs = self.compiled.run(&inputs)?;
        let loss = super::literal::scalar_value(&outs[0])?;
        let grad_norm = super::literal::scalar_value(&outs[1])?;
        // outs[2..2+3n] are the new params/m/v literals — keep them as the
        // next step's state without any host conversion.
        self.state = outs.drain(2..2 + 3 * n).collect();
        Ok(StepStats { loss, grad_norm })
    }

    /// One fused training step for several engines sharing one compiled
    /// executable, dispatched as a single backend call (DESIGN.md §12).
    ///
    /// Every engine must wrap the *same* `Rc<Compiled>` (the executable
    /// cache hands sweeps exactly that); each engine's inputs are
    /// assembled precisely as [`TrainEngine::step`] would assemble them,
    /// so per-job results and post-step state are bit-identical to
    /// stepping the engines one at a time.
    ///
    /// Error semantics: bad caller inputs (batch shape mismatches) are
    /// rejected before any engine is touched. If the backend call itself
    /// fails, every engine's state has already moved into the dispatch —
    /// as with a failed [`TrainEngine::step`], the engines are unusable
    /// and the whole group must be abandoned (the batched train drivers
    /// do exactly that by propagating the error).
    pub fn step_many(
        engines: &mut [&mut TrainEngine],
        batches: &[Vec<BatchData>],
        lrs: &[f32],
    ) -> Result<Vec<StepStats>> {
        anyhow::ensure!(!engines.is_empty(), "step_many needs at least one engine");
        anyhow::ensure!(
            engines.len() == batches.len() && engines.len() == lrs.len(),
            "step_many: {} engines, {} batches, {} lrs",
            engines.len(),
            batches.len(),
            lrs.len()
        );
        let compiled = engines[0].compiled.clone();
        for e in engines.iter() {
            anyhow::ensure!(
                Rc::ptr_eq(&e.compiled, &compiled),
                "step_many engines must share one compiled executable"
            );
        }
        let man = &compiled.manifest;
        let n = man.n_params();

        // Validate and convert the fallible batch inputs first: an
        // invalid batch must poison no engine. State moves (infallible)
        // happen only after.
        let mut batch_lits: Vec<Vec<Literal>> = Vec::with_capacity(engines.len());
        for (k, batch) in batches.iter().enumerate() {
            anyhow::ensure!(
                batch.len() == man.batch.len(),
                "step_many job {k}: {} batch inputs, manifest wants {}",
                batch.len(),
                man.batch.len()
            );
            let mut lits = Vec::with_capacity(man.batch.len());
            for (b, info) in batch.iter().zip(&man.batch) {
                lits.push(batch_to_literal(b, &info.shape)?);
            }
            batch_lits.push(lits);
        }

        let mut jobs: Vec<Vec<Literal>> = Vec::with_capacity(engines.len());
        for ((engine, lits), &lr) in engines.iter_mut().zip(batch_lits).zip(lrs) {
            engine.step_idx += 1;
            let mut inputs: Vec<Literal> = Vec::with_capacity(man.n_inputs());
            inputs.append(&mut engine.state);
            inputs.extend(lits);
            inputs.push(scalar_f32(engine.step_idx as f32));
            inputs.push(scalar_f32(lr));
            jobs.push(inputs);
        }

        let all_outs = compiled.run_batch(&jobs)?;
        let mut stats = Vec::with_capacity(engines.len());
        for (engine, mut outs) in engines.iter_mut().zip(all_outs) {
            let loss = super::literal::scalar_value(&outs[0])?;
            let grad_norm = super::literal::scalar_value(&outs[1])?;
            engine.state = outs.drain(2..2 + 3 * n).collect();
            stats.push(StepStats { loss, grad_norm });
        }
        Ok(stats)
    }

    /// Snapshot current parameters to host tensors.
    pub fn params(&self) -> Result<Vec<Tensor>> {
        let n = self.compiled.manifest.n_params();
        self.state[..n].iter().map(literal_to_tensor).collect()
    }

    /// Snapshot current second moments (reduced shapes) to host tensors.
    pub fn second_moments(&self) -> Result<Vec<Tensor>> {
        let n = self.compiled.manifest.n_params();
        self.state[2 * n..3 * n]
            .iter()
            .map(literal_to_tensor)
            .collect()
    }

    /// Snapshot current first moments to host tensors. M is always stored
    /// at the full parameter shape, so unlike [`Self::second_moments`] the
    /// result is mode-independent — which is exactly why the adaptive
    /// controller reads its SNR signal from m² (DESIGN.md §18).
    pub fn first_moments(&self) -> Result<Vec<Tensor>> {
        let n = self.compiled.manifest.n_params();
        self.state[n..2 * n].iter().map(literal_to_tensor).collect()
    }

    /// Stored second-moment element count per tensor — reflects adaptive
    /// migrations, unlike the manifest's baked `v_shapes`.
    pub fn v_elem_counts(&self) -> Result<Vec<usize>> {
        let n = self.compiled.manifest.n_params();
        self.state[2 * n..3 * n]
            .iter()
            .map(|lit| Ok(literal_to_tensor(lit)?.numel()))
            .collect()
    }

    /// Migrate tensor `i`'s second moment between storage modes
    /// (DESIGN.md §18): `from_k -> to_k` where one side is `K = ∅` (full)
    /// and the other the tensor's reduced rule. Compression collapses the
    /// full V by the paper's mean rule; decompression expands the reduced
    /// V by broadcast. A no-op when the stored length already matches the
    /// target. Only meaningful on the native AdamW fused engines — the
    /// backend infers the per-tensor effective K from the stored length
    /// on the next dispatch.
    pub fn migrate_v(
        &mut self,
        i: usize,
        from_k: crate::optim::KMode,
        to_k: crate::optim::KMode,
    ) -> Result<()> {
        use crate::optim::adamk::{collapse_v, expand_v, v_len};
        let man = &self.compiled.manifest;
        anyhow::ensure!(i < man.n_params(), "migrate_v: tensor {i} out of range");
        let info = man.params[i].clone();
        let n = man.n_params();
        let cur = literal_to_tensor(&self.state[2 * n + i])?;
        anyhow::ensure!(
            cur.numel() == v_len(&info, from_k),
            "migrate_v {:?}: stored v has {} elements, from-mode wants {}",
            info.name,
            cur.numel(),
            v_len(&info, from_k)
        );
        let to_len = v_len(&info, to_k);
        if cur.numel() == to_len {
            return Ok(()); // degenerate geometry: both modes share a layout
        }
        let (data, shape): (Vec<f32>, Vec<usize>) =
            if to_len == info.numel() {
                // decompress: reduced -> full by broadcast
                (expand_v(&info, from_k, &cur.data), info.shape.clone())
            } else {
                // compress: full -> reduced by the mean rule; keep the
                // manifest's baked V shape so engine state matches what a
                // from-scratch reduced run would carry
                let vs = self
                    .compiled
                    .manifest
                    .v_shapes
                    .as_ref()
                    .ok_or_else(|| anyhow!("train_step manifest missing v_shapes"))?;
                anyhow::ensure!(
                    vs[i].iter().product::<usize>() == to_len,
                    "migrate_v {:?}: target mode stores {} elements but the \
                     artifact bakes {:?} — compress only to the baked rule",
                    info.name,
                    to_len,
                    vs[i]
                );
                (collapse_v(&info, to_k, &cur.data), vs[i].clone())
            };
        self.state[2 * n + i] = tensor_to_literal(&Tensor::from_vec(&shape, data))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{backend_for, BackendSpec};

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("linear2_v64.grad.hlo.txt").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn artifact_missing_is_helpful() {
        let err = match Artifact::load("artifacts", "nope.grad") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn grad_engine_runs_linear2() {
        let Some(dir) = artifacts_dir() else { return };
        let Ok(backend) = backend_for(&BackendSpec::pjrt()) else { return };
        let eng = GradEngine::new(&dir, "linear2_v64", backend.as_ref()).unwrap();
        let man = eng.manifest();
        let mut rng = crate::rng::Rng::new(1);
        let params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let batch: Vec<BatchData> = man
            .batch
            .iter()
            .map(|b| {
                let n: usize = b.shape.iter().product();
                BatchData::I32((0..n).map(|i| (i % 64) as i32).collect())
            })
            .collect();
        let (loss, grads) = eng.step(&params, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), man.n_params());
        for (g, p) in grads.iter().zip(&man.params) {
            assert_eq!(g.shape, p.shape);
        }
    }

    #[test]
    fn train_engine_fused_decreases_loss() {
        let Some(dir) = artifacts_dir() else { return };
        if !dir.join("gpt_nano.train.adam.hlo.txt").exists() {
            return;
        }
        let Ok(backend) = backend_for(&BackendSpec::pjrt()) else { return };
        let mut eng =
            TrainEngine::new(&dir, "gpt_nano", "adam", backend.as_ref(), "mitchell", 3).unwrap();
        let man = eng.manifest().clone();
        let mut rng = crate::rng::Rng::new(4);
        let batch: Vec<BatchData> = man
            .batch
            .iter()
            .map(|b| {
                let n: usize = b.shape.iter().product();
                let bound = man.token_bound() as u64;
                BatchData::I32(
                    (0..n).map(|_| rng.below(bound) as i32).collect(),
                )
            })
            .collect();
        let first = eng.step(&batch, 1e-3).unwrap();
        let mut last = first;
        for _ in 0..10 {
            last = eng.step(&batch, 1e-3).unwrap();
        }
        assert!(first.loss.is_finite());
        assert!(
            last.loss < first.loss,
            "fused step did not reduce loss: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(last.grad_norm.is_finite());
    }
}
