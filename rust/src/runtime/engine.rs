//! Execution engines over compiled PJRT executables.
//!
//! * [`GradEngine`] — split engine: the artifact computes
//!   `(loss, grads...) = grad_step(params..., batch...)` and the Rust
//!   [`crate::optim`] family applies the update. This is the analysis /
//!   sweep path: optimizer rules change without re-lowering HLO.
//! * [`TrainEngine`] — fused engine: the artifact is the whole
//!   `train_step` (fwd + bwd + clip + Pallas fused update) and optimizer
//!   state lives in PJRT literals that are fed straight back into the
//!   next dispatch — the production hot path.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::tensor::Tensor;

use super::literal::{
    f32_literal, i32_literal, literal_to_tensor, scalar_f32, tensor_to_literal,
};
use super::manifest::Manifest;

/// Create the PJRT CPU client. The `xla` wrapper types are not `Send`, so
/// each worker thread creates its own client (cheap for CPU).
pub fn cpu_client() -> Result<PjRtClient> {
    PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))
}

/// One batch input in host form.
#[derive(Debug, Clone)]
pub enum BatchData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

/// A loaded (not yet compiled) artifact: HLO text + manifest.
pub struct Artifact {
    pub manifest: Manifest,
    pub hlo_path: PathBuf,
    /// Stable digest of the manifest JSON bytes. Together with the
    /// artifact name this keys the executable cache
    /// (`coordinator::exec_cache`): re-lowering an artifact changes its
    /// manifest, so stale compiled executables can never be reused.
    pub manifest_hash: u64,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.manifest.json`.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Artifact> {
        let dir = dir.as_ref();
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let man_path = dir.join(format!("{name}.manifest.json"));
        if !hlo_path.exists() {
            bail!(
                "artifact {name:?} not found in {dir:?} — run `make artifacts`"
            );
        }
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?}"))?;
        let manifest = Manifest::parse(&text)?;
        manifest.validate()?;
        let manifest_hash = crate::rng::stable_hash64(text.as_bytes());
        Ok(Artifact {
            manifest,
            hlo_path,
            manifest_hash,
        })
    }

    /// Compile on the given client.
    pub fn compile(&self, client: &PjRtClient) -> Result<Compiled> {
        let proto = xla::HloModuleProto::from_text_file(
            self.hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {:?}: {e}", self.hlo_path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {:?}: {e}", self.hlo_path))?;
        Ok(Compiled {
            exe,
            manifest: self.manifest.clone(),
        })
    }
}

/// A compiled executable plus its manifest.
pub struct Compiled {
    exe: PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl Compiled {
    /// Execute and untuple the (single, tupled) output.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.n_inputs(),
            "expected {} inputs, got {}",
            self.manifest.n_inputs(),
            inputs.len()
        );
        let out = self
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.manifest.model_name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("syncing output: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling output: {e}"))
    }
}

fn batch_to_literal(data: &BatchData, shape: &[usize]) -> Result<Literal> {
    match data {
        BatchData::I32(v) => i32_literal(v, shape),
        BatchData::F32(v) => f32_literal(v, shape),
    }
}

/// Split engine: HLO computes loss+grads, Rust owns the optimizer.
pub struct GradEngine {
    compiled: Compiled,
}

impl GradEngine {
    pub fn new(dir: impl AsRef<Path>, model: &str, client: &PjRtClient) -> Result<GradEngine> {
        let art = Artifact::load(dir, &format!("{model}.grad"))?;
        Self::from_artifact(&art, client)
    }

    /// Compile an already-loaded grad artifact (the executable cache's
    /// miss path — it loads the artifact itself to learn the cache key).
    pub fn from_artifact(art: &Artifact, client: &PjRtClient) -> Result<GradEngine> {
        anyhow::ensure!(art.manifest.kind == "grad_step");
        Ok(GradEngine {
            compiled: art.compile(client)?,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.compiled.manifest
    }

    /// One gradient evaluation: returns `(loss, grads)` in param order.
    pub fn step(&self, params: &[Tensor], batch: &[BatchData]) -> Result<(f32, Vec<Tensor>)> {
        let man = &self.compiled.manifest;
        anyhow::ensure!(params.len() == man.n_params(), "param count");
        anyhow::ensure!(batch.len() == man.batch.len(), "batch count");

        let mut inputs = Vec::with_capacity(man.n_inputs());
        for t in params {
            inputs.push(tensor_to_literal(t)?);
        }
        for (b, info) in batch.iter().zip(&man.batch) {
            inputs.push(batch_to_literal(b, &info.shape)?);
        }
        let outs = self.compiled.run(&inputs)?;
        let loss = super::literal::scalar_value(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()
            .context("converting grads")?;
        Ok((loss, grads))
    }
}

/// Fused engine: one PJRT dispatch per training step; parameter and
/// optimizer state stay in literals between steps.
///
/// The compiled executable is held behind `Rc` so sweeps can share one
/// compilation across many engine instances on the same worker thread
/// (each run still owns private state literals).
pub struct TrainEngine {
    compiled: Rc<Compiled>,
    /// params..., m..., v... in manifest order
    state: Vec<Literal>,
    pub step_idx: usize,
}

/// Outputs of one fused step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
}

impl TrainEngine {
    /// Load `<model>.train.<ruleset>` and initialize state.
    ///
    /// `init_scheme` is "mitchell" or "default" (paper §4.3); `seed` fixes
    /// the parameter draw.
    pub fn new(
        dir: impl AsRef<Path>,
        model: &str,
        ruleset: &str,
        client: &PjRtClient,
        init_scheme: &str,
        seed: u64,
    ) -> Result<TrainEngine> {
        let art = Artifact::load(dir, &format!("{model}.train.{ruleset}"))?;
        anyhow::ensure!(art.manifest.kind == "train_step");
        Self::with_compiled(Rc::new(art.compile(client)?), init_scheme, seed)
    }

    /// Build an engine over an already-compiled (possibly cached, shared)
    /// train-step executable, initializing fresh parameter/optimizer state.
    pub fn with_compiled(
        compiled: Rc<Compiled>,
        init_scheme: &str,
        seed: u64,
    ) -> Result<TrainEngine> {
        let man = &compiled.manifest;

        let mut rng = crate::rng::Rng::new(seed);
        let mut state = Vec::with_capacity(3 * man.n_params());
        for p in &man.params {
            let init = match init_scheme {
                "mitchell" => &p.init_mitchell,
                "default" => &p.init_default,
                s => bail!("unknown init scheme {s:?}"),
            };
            state.push(tensor_to_literal(&init.materialize(&p.shape, &mut rng))?);
        }
        for p in &man.params {
            state.push(tensor_to_literal(&Tensor::zeros(&p.shape))?);
        }
        let v_shapes = man
            .v_shapes
            .clone()
            .ok_or_else(|| anyhow!("train_step manifest missing v_shapes"))?;
        for vs in &v_shapes {
            state.push(tensor_to_literal(&Tensor::zeros(vs))?);
        }
        Ok(TrainEngine {
            compiled,
            state,
            step_idx: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.compiled.manifest
    }

    /// Restore parameters from host tensors (fine-tuning / checkpoints),
    /// resetting optimizer state.
    pub fn load_params(&mut self, params: &[Tensor]) -> Result<()> {
        let man = &self.compiled.manifest;
        anyhow::ensure!(params.len() == man.n_params());
        for (i, t) in params.iter().enumerate() {
            self.state[i] = tensor_to_literal(t)?;
        }
        Ok(())
    }

    /// One fused training step. `lr` is the already-scheduled rate.
    pub fn step(&mut self, batch: &[BatchData], lr: f32) -> Result<StepStats> {
        let man = &self.compiled.manifest;
        self.step_idx += 1;
        let n = man.n_params();

        let mut inputs: Vec<Literal> = Vec::with_capacity(man.n_inputs());
        // Move state in; it is replaced by the outputs below.
        inputs.append(&mut self.state);
        for (b, info) in batch.iter().zip(&man.batch) {
            inputs.push(batch_to_literal(b, &info.shape)?);
        }
        inputs.push(scalar_f32(self.step_idx as f32));
        inputs.push(scalar_f32(lr));

        let mut outs = self.compiled.run(&inputs)?;
        let loss = super::literal::scalar_value(&outs[0])?;
        let grad_norm = super::literal::scalar_value(&outs[1])?;
        // outs[2..2+3n] are the new params/m/v literals — keep them as the
        // next step's state without any host conversion.
        self.state = outs.drain(2..2 + 3 * n).collect();
        Ok(StepStats { loss, grad_norm })
    }

    /// Snapshot current parameters to host tensors.
    pub fn params(&self) -> Result<Vec<Tensor>> {
        let n = self.compiled.manifest.n_params();
        self.state[..n].iter().map(literal_to_tensor).collect()
    }

    /// Snapshot current second moments (reduced shapes) to host tensors.
    pub fn second_moments(&self) -> Result<Vec<Tensor>> {
        let n = self.compiled.manifest.n_params();
        self.state[2 * n..3 * n]
            .iter()
            .map(literal_to_tensor)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("linear2_v64.grad.hlo.txt").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn artifact_missing_is_helpful() {
        let err = match Artifact::load("artifacts", "nope.grad") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn grad_engine_runs_linear2() {
        let Some(dir) = artifacts_dir() else { return };
        let client = cpu_client().unwrap();
        let eng = GradEngine::new(&dir, "linear2_v64", &client).unwrap();
        let man = eng.manifest();
        let mut rng = crate::rng::Rng::new(1);
        let params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let batch: Vec<BatchData> = man
            .batch
            .iter()
            .map(|b| {
                let n: usize = b.shape.iter().product();
                BatchData::I32((0..n).map(|i| (i % 64) as i32).collect())
            })
            .collect();
        let (loss, grads) = eng.step(&params, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), man.n_params());
        for (g, p) in grads.iter().zip(&man.params) {
            assert_eq!(g.shape, p.shape);
        }
    }

    #[test]
    fn train_engine_fused_decreases_loss() {
        let Some(dir) = artifacts_dir() else { return };
        if !dir.join("gpt_nano.train.adam.hlo.txt").exists() {
            return;
        }
        let client = cpu_client().unwrap();
        let mut eng =
            TrainEngine::new(&dir, "gpt_nano", "adam", &client, "mitchell", 3).unwrap();
        let man = eng.manifest().clone();
        let mut rng = crate::rng::Rng::new(4);
        let batch: Vec<BatchData> = man
            .batch
            .iter()
            .map(|b| {
                let n: usize = b.shape.iter().product();
                let bound = man.token_bound() as u64;
                BatchData::I32(
                    (0..n).map(|_| rng.below(bound) as i32).collect(),
                )
            })
            .collect();
        let first = eng.step(&batch, 1e-3).unwrap();
        let mut last = first;
        for _ in 0..10 {
            last = eng.step(&batch, 1e-3).unwrap();
        }
        assert!(first.loss.is_finite());
        assert!(
            last.loss < first.loss,
            "fused step did not reduce loss: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(last.grad_norm.is_finite());
    }
}
