//! Native backend: a pure-Rust interpreter of the manifest's model family
//! (DESIGN.md §11).
//!
//! Where the PJRT backend compiles AOT-lowered HLO text, the native
//! backend *is* the computation: it ships a small catalog of builtin
//! models ([`MODELS`]) — a per-token MLP language model and a one-block
//! causal transformer — with handwritten forward/backward passes, and
//! interprets `grad_step` / `train_step` manifests directly. That makes
//! `slimadam train/sweep --backend native` a real training run (actual
//! losses, actual gradients, actual reduced-V Adam updates) that needs no
//! artifacts, no Python, and no PJRT — the substrate for offline CI
//! end-to-end coverage that the synthetic-run mode (fake losses) could
//! never give.
//!
//! Contracts kept identical to the PJRT path:
//!
//! * manifests are generated, then round-tripped through
//!   [`Manifest::parse`] + `validate`, so both backends agree on the
//!   input/output layout and the manifest hash keys the executable cache;
//! * `train_step` applies global-norm clipping then the Eq. 2 reduced-V
//!   AdamW update with the manifest's baked `k_modes` — split
//!   (grad + `optim::adamk::AdamK`) and fused native runs of the same
//!   config produce matching trajectories
//!   (`rust/tests/engine_agreement.rs`);
//! * forward/backward accumulate in f64 and emit f32, so results are a
//!   deterministic pure function of the inputs on every host.

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use crate::optim::clip_global_norm;
use crate::runtime::engine::{Artifact, ArtifactSource};
use crate::runtime::literal::{literal_to_tensor, scalar_f32, tensor_to_literal};
use crate::runtime::manifest::{Hypers, KMode, Manifest};
use crate::tensor::Tensor;

use super::{Backend, DeviceTag, Executable};

/// Builtin models the native interpreter knows.
pub const MODELS: &[&str] = &["mlp_tiny", "gpt_micro"];

/// Fused rulesets the native interpreter can bake into `train_step`
/// manifests (K modes per tensor).
pub const RULESETS: &[&str] = &["adam", "slimadam", "adalayer"];

const RMS_EPS: f64 = 1e-5;

// ---------------------------------------------------------------------------
// Model catalog + manifest generation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    Mlp,
    Gpt,
}

/// Architecture hyperparameters of one builtin model.
#[derive(Debug, Clone, Copy)]
struct Dims {
    family: Family,
    vocab: usize,
    d: usize,
    hidden: usize,
    heads: usize,
    ctx: usize,
    batch: usize,
}

fn dims_for(model: &str) -> Result<Dims> {
    Ok(match model {
        "mlp_tiny" => Dims {
            family: Family::Mlp,
            vocab: 64,
            d: 16,
            hidden: 32,
            heads: 1,
            ctx: 8,
            batch: 8,
        },
        "gpt_micro" => Dims {
            family: Family::Gpt,
            vocab: 64,
            d: 16,
            hidden: 64,
            heads: 2,
            ctx: 8,
            batch: 4,
        },
        other => bail!(
            "unknown native model {other:?} — builtin models: {}",
            MODELS.join(", ")
        ),
    })
}

/// `(name, shape, layer_type, depth, wd, default_init)` rows, in manifest
/// parameter order.
fn param_rows(dims: &Dims) -> Vec<(&'static str, Vec<usize>, &'static str, i64, bool)> {
    let (v, d, h) = (dims.vocab, dims.d, dims.hidden);
    match dims.family {
        Family::Mlp => vec![
            ("tok_embd", vec![v, d], "tok_embd", -1, true),
            ("mlp_up", vec![h, d], "mlp_up", 0, true),
            ("mlp_down", vec![d, h], "mlp_down", 0, true),
            ("lm_head", vec![v, d], "lm_head", 1, true),
        ],
        Family::Gpt => vec![
            ("tok_embd", vec![v, d], "tok_embd", -1, true),
            ("pos_embd", vec![dims.ctx, d], "pos_embd", -1, false),
            ("h0.ln_attn", vec![d], "ln_attn", 0, false),
            ("h0.attn_q", vec![d, d], "attn_q", 0, true),
            ("h0.attn_k", vec![d, d], "attn_k", 0, true),
            ("h0.attn_v", vec![d, d], "attn_v", 0, true),
            ("h0.attn_proj", vec![d, d], "attn_proj", 0, true),
            ("h0.ln_mlp", vec![d], "ln_mlp", 0, false),
            ("h0.mlp_up", vec![h, d], "mlp_up", 0, true),
            ("h0.mlp_down", vec![d, h], "mlp_down", 0, true),
            ("ln_final", vec![d], "ln_final", 1, false),
            ("lm_head", vec![v, d], "lm_head", 1, true),
        ],
    }
}

fn init_json(shape: &[usize], layer_type: &str, mitchell: bool) -> crate::json::Value {
    let mut v = crate::json::Value::obj();
    if shape.len() <= 1 {
        // norm gains start at one, everything vector-like else at zero
        if layer_type.starts_with("ln") {
            v.set("scheme", "ones");
        } else {
            v.set("scheme", "zeros");
        }
    } else if mitchell {
        v.set("scheme", "normal").set("std", 0.02);
    } else {
        // PyTorch-default-flavored: uniform ±1/sqrt(fan_in)
        let fan_in = shape[1..].iter().product::<usize>().max(1);
        v.set("scheme", "uniform")
            .set("limit", 1.0 / (fan_in as f64).sqrt());
    }
    v
}

fn manifest_json(
    model: &str,
    dims: &Dims,
    kind: &str,
    ruleset: Option<&str>,
) -> crate::json::Value {
    use crate::json::Value;
    let mut root = Value::obj();
    root.set("kind", kind);

    let mut meta = Value::obj();
    meta.set("name", model)
        .set("family", match dims.family {
            Family::Mlp => "mlp",
            Family::Gpt => "gpt",
        })
        .set("vocab", dims.vocab)
        .set("d_model", dims.d)
        .set("hidden", dims.hidden)
        .set("n_heads", dims.heads)
        .set("ctx", dims.ctx)
        .set("batch", dims.batch)
        .set("native", true);
    root.set("model", meta);

    let rows = param_rows(dims);
    let mut params = Vec::new();
    for (name, shape, lt, depth, wd) in &rows {
        let mut p = Value::obj();
        p.set("name", *name)
            .set("shape", shape.clone())
            .set("layer_type", *lt)
            .set("depth", *depth)
            .set("init_mitchell", init_json(shape, lt, true))
            .set("init_default", init_json(shape, lt, false))
            .set("wd", *wd)
            .set("fan_out_axis", 0usize);
        params.push(p);
    }
    root.set("params", params);

    let mut batch = Vec::new();
    for name in ["x", "y"] {
        let mut b = Value::obj();
        b.set("name", name)
            .set("shape", vec![dims.batch, dims.ctx])
            .set("dtype", "s32");
        batch.push(b);
    }
    root.set("batch", batch);

    let mut hypers = Value::obj();
    let h = Hypers::default();
    hypers
        .set("beta1", h.beta1)
        .set("beta2", h.beta2)
        .set("eps", h.eps)
        .set("weight_decay", h.weight_decay)
        .set("clip_norm", h.clip_norm);
    root.set("hypers", hypers);

    let param_names: Vec<&str> = rows.iter().map(|r| r.0).collect();
    match kind {
        "grad_step" => {
            let mut inputs: Vec<String> =
                param_names.iter().map(|n| format!("param:{n}")).collect();
            inputs.push("batch:x".into());
            inputs.push("batch:y".into());
            let mut outputs = vec!["loss".to_string()];
            outputs.extend(param_names.iter().map(|n| format!("grad:{n}")));
            root.set("inputs", inputs).set("outputs", outputs);
        }
        "train_step" => {
            let ruleset = ruleset.expect("train_step needs a ruleset");
            root.set("ruleset", ruleset);
            let mut inputs: Vec<String> = Vec::new();
            for prefix in ["param", "m", "v"] {
                inputs.extend(param_names.iter().map(|n| format!("{prefix}:{n}")));
            }
            inputs.push("batch:x".into());
            inputs.push("batch:y".into());
            inputs.push("step".into());
            inputs.push("lr".into());
            let mut outputs = vec!["loss".to_string(), "grad_norm".to_string()];
            for prefix in ["param", "m", "v"] {
                outputs.extend(param_names.iter().map(|n| format!("{prefix}:{n}")));
            }
            root.set("inputs", inputs).set("outputs", outputs);
        }
        k => unreachable!("manifest kind {k}"),
    }
    root
}

/// Builtin `grad_step` manifest for a native model.
pub fn grad_manifest(model: &str) -> Result<Manifest> {
    Ok(artifact(&format!("{model}.grad"))?.manifest)
}

/// Per-tensor K modes baked into a fused native manifest.
fn ruleset_modes(man: &Manifest, ruleset: &str) -> Result<Vec<KMode>> {
    Ok(match ruleset {
        "adam" => vec![KMode::None; man.n_params()],
        "adalayer" => vec![KMode::Both; man.n_params()],
        "slimadam" => crate::rules::RuleSet::table3_default(man).modes_for(man),
        other => bail!(
            "unknown native ruleset {other:?} — builtin rulesets: {}",
            RULESETS.join(", ")
        ),
    })
}

/// Stored-V shape for a parameter under mode `k` (in matrix-view coords;
/// the fused engine round-trips these literals without inspecting them).
fn v_shape(info: &crate::runtime::manifest::ParamInfo, k: KMode) -> Vec<usize> {
    let (rows, cols) = info.matrix_dims();
    match crate::optim::adamk::effective_k(info, k) {
        KMode::None => info.shape.clone(),
        KMode::FanIn => vec![rows, 1],
        KMode::FanOut => vec![1, cols],
        KMode::Both => vec![1],
        KMode::Blocks(n) => vec![n],
    }
}

thread_local! {
    /// Builtin artifacts are a pure function of their name, so generation
    /// (JSON build + parse + validate) runs once per thread per name —
    /// the dispatch hot path (`exec_cache` recomputes the cache key per
    /// job) then pays only a manifest clone.
    static ARTIFACTS: std::cell::RefCell<std::collections::HashMap<String, Artifact>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Resolve a native artifact by name: `<model>.grad` or
/// `<model>.train.<ruleset>`. The manifest is generated, serialized, and
/// re-parsed through [`Manifest::parse`] so native and PJRT artifacts
/// share one manifest contract (and the hash that keys the executable
/// cache digests the same bytes a file would hold).
pub fn artifact(name: &str) -> Result<Artifact> {
    ARTIFACTS.with(|cache| {
        if let Some(art) = cache.borrow().get(name) {
            return Ok(art.clone());
        }
        let art = generate_artifact(name)?;
        cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    })
}

fn generate_artifact(name: &str) -> Result<Artifact> {
    let (model, kind, ruleset) = match name.split_once('.') {
        Some((model, "grad")) => (model, "grad_step", None),
        Some((model, rest)) => match rest.split_once('.') {
            Some(("train", ruleset)) => (model, "train_step", Some(ruleset)),
            _ => bail!("bad native artifact name {name:?}"),
        },
        None => bail!("bad native artifact name {name:?}"),
    };
    let dims = dims_for(model)?;
    let mut root = manifest_json(model, &dims, kind, ruleset);

    if kind == "train_step" {
        // k_modes/v_shapes need a parsed manifest for ParamInfo geometry;
        // bootstrap from the grad-shaped params.
        let base = Manifest::parse(&root.dump()).map_err(|e| {
            anyhow!("internal: native train manifest bootstrap failed: {e}")
        })?;
        let modes = ruleset_modes(&base, ruleset.unwrap())?;
        // Manifest k_modes strings can carry none/fan_in/fan_out/both only
        // (KMode::parse has no "blocksN" spelling) — refuse early rather
        // than generate a manifest that cannot re-parse.
        anyhow::ensure!(
            !modes.iter().any(|k| matches!(k, KMode::Blocks(_))),
            "native rulesets cannot bake block-partitioned K modes into a \
             manifest"
        );
        let k_modes: Vec<String> = base
            .params
            .iter()
            .zip(&modes)
            .map(|(p, &k)| crate::optim::adamk::effective_k(p, k).as_str())
            .collect();
        let v_shapes: Vec<crate::json::Value> = base
            .params
            .iter()
            .zip(&modes)
            .map(|(p, &k)| crate::json::Value::from(v_shape(p, k)))
            .collect();
        root.set("k_modes", k_modes);
        root.set("v_shapes", crate::json::Value::Arr(v_shapes));
    }

    let text = root.dump();
    let manifest = Manifest::parse(&text)
        .with_context(|| format!("parsing generated native manifest {name:?}"))?;
    manifest
        .validate()
        .with_context(|| format!("validating generated native manifest {name:?}"))?;
    Ok(Artifact {
        name: name.to_string(),
        manifest,
        source: ArtifactSource::Builtin,
        manifest_hash: crate::rng::stable_hash64(text.as_bytes()),
    })
}

// ---------------------------------------------------------------------------
// Backend + executable
// ---------------------------------------------------------------------------

/// The pure-Rust execution path. Stateless; `compile` binds a builtin
/// model's interpreter to the artifact's manifest.
pub struct NativeBackend {
    device: DeviceTag,
}

impl NativeBackend {
    pub fn new(device: DeviceTag) -> NativeBackend {
        NativeBackend { device }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new(DeviceTag::Cpu(0))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn device(&self) -> DeviceTag {
        self.device
    }

    fn load_artifact(&self, _dir: &std::path::Path, name: &str) -> Result<Artifact> {
        artifact(name)
    }

    fn compile(&self, art: &Artifact) -> Result<Box<dyn Executable>> {
        anyhow::ensure!(
            art.source == ArtifactSource::Builtin,
            "native backend interprets builtin models only ({}), got HLO \
             artifact {:?} — use the pjrt backend for `make artifacts` output",
            MODELS.join(", "),
            art.name
        );
        let dims = dims_for(&art.manifest.model_name)?;
        // Guard against manifests that drifted from the interpreter.
        let rows = param_rows(&dims);
        anyhow::ensure!(
            art.manifest.n_params() == rows.len()
                && art
                    .manifest
                    .params
                    .iter()
                    .zip(&rows)
                    .all(|(p, (n, shape, ..))| p.name == *n && &p.shape == shape),
            "native manifest for {:?} does not match the interpreter's \
             parameter layout",
            art.manifest.model_name
        );
        Ok(Box::new(NativeExecutable {
            manifest: art.manifest.clone(),
            dims,
        }))
    }
}

/// One compiled native step function.
struct NativeExecutable {
    manifest: Manifest,
    dims: Dims,
}

impl NativeExecutable {
    fn batch_tokens(&self, lit: &Literal, what: &str) -> Result<Vec<i32>> {
        let toks = lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("reading {what} batch: {e}"))?;
        anyhow::ensure!(
            toks.len() == self.dims.batch * self.dims.ctx,
            "{what} batch has {} tokens, want {}",
            toks.len(),
            self.dims.batch * self.dims.ctx
        );
        let bound = self.dims.vocab as i32;
        anyhow::ensure!(
            toks.iter().all(|&t| (0..bound).contains(&t)),
            "{what} batch token out of range [0, {bound})"
        );
        Ok(toks)
    }

    fn run_grad(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let n = self.manifest.n_params();
        let params: Vec<Tensor> = inputs[..n]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<_>>()?;
        let x = self.batch_tokens(&inputs[n], "x")?;
        let y = self.batch_tokens(&inputs[n + 1], "y")?;
        let (loss, grads) = loss_and_grads(&self.dims, &params, &x, &y);
        let mut out = Vec::with_capacity(1 + n);
        out.push(scalar_f32(loss as f32));
        for g in &grads {
            out.push(tensor_to_literal(g)?);
        }
        Ok(out)
    }

    fn run_train(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let man = &self.manifest;
        let n = man.n_params();
        let mut params: Vec<Tensor> = inputs[..n]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<_>>()?;
        let mut m: Vec<Tensor> = inputs[n..2 * n]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<_>>()?;
        let mut v: Vec<Tensor> = inputs[2 * n..3 * n]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<_>>()?;
        let x = self.batch_tokens(&inputs[3 * n], "x")?;
        let y = self.batch_tokens(&inputs[3 * n + 1], "y")?;
        let step = crate::runtime::literal::scalar_value(&inputs[3 * n + 2])?;
        let lr = crate::runtime::literal::scalar_value(&inputs[3 * n + 3])?;
        let t = step.round().max(1.0) as usize;

        let hypers = man.hypers.unwrap_or_default();
        let k_modes = man
            .k_modes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing k_modes"))?;

        let (loss, mut grads) = loss_and_grads(&self.dims, &params, &x, &y);
        let grad_norm = clip_global_norm(&mut grads, hypers.clip_norm);
        fused_update(man, k_modes, &hypers, &mut params, &mut m, &mut v, &grads, t, lr);

        let mut out = Vec::with_capacity(2 + 3 * n);
        out.push(scalar_f32(loss as f32));
        out.push(scalar_f32(grad_norm as f32));
        for tensor in params.iter().chain(&m).chain(&v) {
            out.push(tensor_to_literal(tensor)?);
        }
        Ok(out)
    }

    /// Read input slot `slot` of every job as f32 and stack lane-major:
    /// element `j` of job `b` lands at `j * lanes + b`.
    fn stack_slot(
        &self,
        jobs: &[Vec<Literal>],
        slot: usize,
        len: usize,
        what: &str,
    ) -> Result<Vec<f32>> {
        let lanes = jobs.len();
        let mut stacked = vec![0.0f32; len * lanes];
        for (b, job) in jobs.iter().enumerate() {
            let vals = job[slot]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("job {b} {what}: {e}"))?;
            anyhow::ensure!(
                vals.len() == len,
                "job {b} {what} has {} elements, want {len}",
                vals.len()
            );
            for (j, &x) in vals.iter().enumerate() {
                stacked[j * lanes + b] = x;
            }
        }
        Ok(stacked)
    }

    /// Batched `grad_step`: one lane-stacked forward/backward pass for
    /// all jobs, per-job `(loss, grads...)` outputs.
    fn run_grad_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        let lanes = jobs.len();
        let man = &self.manifest;
        let n = man.n_params();
        // f32 → f64 exactly as the scalar path (literal_to_tensor + f64s)
        let mut params_l: Vec<Vec<f64>> = Vec::with_capacity(n);
        for i in 0..n {
            let stacked = self.stack_slot(jobs, i, man.params[i].numel(), "param")?;
            params_l.push(stacked.iter().map(|&x| x as f64).collect());
        }
        let mut xs = Vec::with_capacity(lanes);
        let mut ys = Vec::with_capacity(lanes);
        for job in jobs {
            xs.push(self.batch_tokens(&job[n], "x")?);
            ys.push(self.batch_tokens(&job[n + 1], "y")?);
        }
        let (losses, grads_l) = loss_and_grads_l(&self.dims, &params_l, &xs, &ys, lanes);
        let mut out = Vec::with_capacity(lanes);
        for b in 0..lanes {
            let mut job_out = Vec::with_capacity(1 + n);
            job_out.push(scalar_f32(losses[b] as f32));
            for (i, g) in grads_l.iter().enumerate() {
                let data: Vec<f32> =
                    g[b..].iter().step_by(lanes).map(|&x| x as f32).collect();
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &man.params[i].shape,
                    data,
                ))?);
            }
            out.push(job_out);
        }
        Ok(out)
    }

    /// Batched `train_step`: lane-stacked forward/backward, per-lane
    /// global-norm clip and per-lane fused reduced-V AdamW update (each
    /// lane carries its own step index and learning rate).
    fn run_train_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        let lanes = jobs.len();
        let man = &self.manifest;
        let n = man.n_params();
        let hypers = man.hypers.unwrap_or_default();
        let k_modes = man
            .k_modes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing k_modes"))?;
        let v_shapes = man
            .v_shapes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing v_shapes"))?;

        let mut w_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut m_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut v_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            w_l.push(self.stack_slot(jobs, i, man.params[i].numel(), "param")?);
        }
        for i in 0..n {
            m_l.push(self.stack_slot(jobs, n + i, man.params[i].numel(), "m")?);
        }
        for (i, vs) in v_shapes.iter().enumerate() {
            v_l.push(self.stack_slot(jobs, 2 * n + i, vs.iter().product(), "v")?);
        }
        let mut xs = Vec::with_capacity(lanes);
        let mut ys = Vec::with_capacity(lanes);
        let mut ts = Vec::with_capacity(lanes);
        let mut lrs = Vec::with_capacity(lanes);
        for job in jobs {
            xs.push(self.batch_tokens(&job[3 * n], "x")?);
            ys.push(self.batch_tokens(&job[3 * n + 1], "y")?);
            let step = crate::runtime::literal::scalar_value(&job[3 * n + 2])?;
            ts.push(step.round().max(1.0) as usize);
            lrs.push(crate::runtime::literal::scalar_value(&job[3 * n + 3])?);
        }

        let params_f64: Vec<Vec<f64>> = w_l
            .iter()
            .map(|s| s.iter().map(|&x| x as f64).collect())
            .collect();
        let (losses, grads_f64) =
            loss_and_grads_l(&self.dims, &params_f64, &xs, &ys, lanes);
        // f64 → f32 cast before clipping, exactly as the scalar path
        let mut grads_l: Vec<Vec<f32>> = grads_f64
            .iter()
            .map(|g| g.iter().map(|&x| x as f32).collect())
            .collect();
        let norms = clip_global_norm_l(&mut grads_l, hypers.clip_norm, lanes);
        fused_update_l(
            man, k_modes, &hypers, &mut w_l, &mut m_l, &mut v_l, &grads_l, &ts, &lrs,
            lanes,
        );

        let unstack = |stacked: &[f32], b: usize| -> Vec<f32> {
            stacked[b..].iter().step_by(lanes).copied().collect()
        };
        let mut out = Vec::with_capacity(lanes);
        for b in 0..lanes {
            let mut job_out = Vec::with_capacity(2 + 3 * n);
            job_out.push(scalar_f32(losses[b] as f32));
            job_out.push(scalar_f32(norms[b] as f32));
            for (i, s) in w_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &man.params[i].shape,
                    unstack(s, b),
                ))?);
            }
            for (i, s) in m_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &man.params[i].shape,
                    unstack(s, b),
                ))?);
            }
            for (i, s) in v_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &v_shapes[i],
                    unstack(s, b),
                ))?);
            }
            out.push(job_out);
        }
        Ok(out)
    }
}

impl Executable for NativeExecutable {
    fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        match self.manifest.kind.as_str() {
            "grad_step" => self.run_grad(inputs),
            "train_step" => self.run_train(inputs),
            k => bail!("native backend cannot execute manifest kind {k:?}"),
        }
    }

    /// Lane-stacked batched dispatch (DESIGN.md §12): B jobs' tensors are
    /// stacked along a trailing lane axis and one interpreter pass
    /// advances all of them. Bit-for-bit identical to sequential `run`
    /// calls — see the module's lane-kernel section for the argument.
    fn run_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        if jobs.len() <= 1 {
            return jobs.iter().map(|inputs| self.run(inputs)).collect();
        }
        for (b, job) in jobs.iter().enumerate() {
            anyhow::ensure!(
                job.len() == self.manifest.n_inputs(),
                "job {b}: expected {} inputs, got {}",
                self.manifest.n_inputs(),
                job.len()
            );
        }
        match self.manifest.kind.as_str() {
            "grad_step" => self.run_grad_batch(jobs),
            "train_step" => self.run_train_batch(jobs),
            k => bail!("native backend cannot execute manifest kind {k:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Fused reduced-V AdamW update (Eq. 2, mirrors optim::adamk::AdamK)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn fused_update(
    man: &Manifest,
    k_modes: &[KMode],
    h: &Hypers,
    params: &mut [Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
    grads: &[Tensor],
    t: usize,
    lr: f32,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let bc1 = 1.0 / (1.0 - b1.powi(t as i32));
    let bc2 = 1.0 / (1.0 - b2.powi(t as i32));
    for i in 0..params.len() {
        let info = &man.params[i];
        let k = crate::optim::adamk::effective_k(info, k_modes[i]);
        let (rows, cols) = info.matrix_dims();
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        let w = &mut params[i].data;
        let g = &grads[i].data;
        let mi = &mut m[i].data;
        let vi = &mut v[i].data;
        if k == KMode::None {
            // Exact AdamW: V is elementwise, no grouping pass needed.
            for j in 0..w.len() {
                let gj = g[j];
                mi[j] = b1 * mi[j] + (1.0 - b1) * gj;
                vi[j] = b2 * vi[j] + (1.0 - b2) * gj * gj;
                let mh = mi[j] * bc1;
                let vh = vi[j] * bc2;
                w[j] -= lr * (mh / (vh.sqrt() + eps) + wd * w[j]);
            }
            continue;
        }
        // All native params have fan_out_axis 0, so the matrix view is the
        // raw layout: row = j / cols, col = j % cols.
        let group = |j: usize| -> usize {
            match k {
                KMode::None => j,
                KMode::FanIn => j / cols,
                KMode::FanOut => j % cols,
                KMode::Both => 0,
                KMode::Blocks(n) => (j / cols) * n / rows,
            }
        };
        let gsize = match k {
            KMode::None => 1.0,
            KMode::FanIn => cols as f32,
            KMode::FanOut => rows as f32,
            KMode::Both => (rows * cols) as f32,
            KMode::Blocks(n) => ((rows / n) * cols) as f32,
        };
        let mut sums = vec![0.0f32; vi.len()];
        for (j, &gj) in g.iter().enumerate() {
            sums[group(j)] += gj * gj;
        }
        for (vv, s) in vi.iter_mut().zip(&sums) {
            *vv = b2 * *vv + (1.0 - b2) * (s / gsize);
        }
        for j in 0..w.len() {
            let gj = g[j];
            mi[j] = b1 * mi[j] + (1.0 - b1) * gj;
            let mh = mi[j] * bc1;
            let vh = vi[group(j)] * bc2;
            w[j] -= lr * (mh / (vh.sqrt() + eps) + wd * w[j]);
        }
    }
}

// ---------------------------------------------------------------------------
// Forward/backward interpreters (f64 internal, f32 at the boundary)
// ---------------------------------------------------------------------------

/// Loss and gradients for one batch, in manifest parameter order. The f64
/// loss is exposed for finite-difference tests; engines see the f32 cast.
fn loss_and_grads(dims: &Dims, params: &[Tensor], x: &[i32], y: &[i32]) -> (f64, Vec<Tensor>) {
    let mut grads: Vec<Vec<f64>> = params.iter().map(|p| vec![0.0; p.numel()]).collect();
    let loss = match dims.family {
        Family::Mlp => mlp_pass(dims, params, x, y, &mut grads),
        Family::Gpt => gpt_pass(dims, params, x, y, &mut grads),
    };
    let out = params
        .iter()
        .zip(&grads)
        .map(|(p, g)| Tensor::from_vec(&p.shape, g.iter().map(|&x| x as f32).collect()))
        .collect();
    (loss, out)
}

/// Forward-only loss (finite-difference harness for the tests below).
#[cfg(test)]
fn loss_only(dims: &Dims, params: &[Tensor], x: &[i32], y: &[i32]) -> f64 {
    let mut grads: Vec<Vec<f64>> = params.iter().map(|p| vec![0.0; p.numel()]).collect();
    match dims.family {
        Family::Mlp => mlp_pass(dims, params, x, y, &mut grads),
        Family::Gpt => gpt_pass(dims, params, x, y, &mut grads),
    }
}

#[inline]
fn f64s(t: &Tensor) -> Vec<f64> {
    t.data.iter().map(|&x| x as f64).collect()
}

/// `out[r] = W[r,:] · v` for row-major `W (rows × cols)`.
fn matvec(w: &[f64], rows: usize, cols: usize, v: &[f64], out: &mut [f64]) {
    for r in 0..rows {
        let mut s = 0.0;
        let row = &w[r * cols..(r + 1) * cols];
        for (a, b) in row.iter().zip(v) {
            s += a * b;
        }
        out[r] = s;
    }
}

/// `out[c] += W[:,c] · v` (transpose matvec, accumulating).
fn matvec_t_acc(w: &[f64], rows: usize, cols: usize, v: &[f64], out: &mut [f64]) {
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let vr = v[r];
        for (o, a) in out.iter_mut().zip(row) {
            *o += a * vr;
        }
    }
}

/// `dW[r,c] += dv[r] * u[c]` (outer-product accumulation).
fn outer_acc(dw: &mut [f64], rows: usize, cols: usize, dv: &[f64], u: &[f64]) {
    for r in 0..rows {
        let row = &mut dw[r * cols..(r + 1) * cols];
        let d = dv[r];
        for (o, b) in row.iter_mut().zip(u) {
            *o += d * b;
        }
    }
}

/// Softmax cross-entropy at one position: fills `dlogits` with
/// `(p - onehot(y)) * scale` and returns `-ln p[y]`.
fn softmax_ce(logits: &[f64], y: usize, scale: f64, dlogits: &mut [f64]) -> f64 {
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for (d, &l) in dlogits.iter_mut().zip(logits) {
        *d = (l - max).exp();
        z += *d;
    }
    let loss = -(dlogits[y] / z).max(f64::MIN_POSITIVE).ln();
    for d in dlogits.iter_mut() {
        *d = *d / z * scale;
    }
    dlogits[y] -= scale;
    loss
}

/// RMS-norm forward: `y = x / rms(x) * g`; returns the saved rms.
fn rms_fwd(x: &[f64], g: &[f64], out: &mut [f64]) -> f64 {
    let d = x.len() as f64;
    let r = (x.iter().map(|v| v * v).sum::<f64>() / d + RMS_EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] / r * g[i];
    }
    r
}

/// RMS-norm backward: accumulates `dx` and `dg` from `dy`.
fn rms_bwd(x: &[f64], g: &[f64], r: f64, dy: &[f64], dx: &mut [f64], dg: &mut [f64]) {
    let d = x.len() as f64;
    let mut dot = 0.0;
    for i in 0..x.len() {
        dg[i] += dy[i] * x[i] / r;
        dot += dy[i] * g[i] * x[i];
    }
    let coef = dot / (d * r * r * r);
    for i in 0..x.len() {
        dx[i] += dy[i] * g[i] / r - x[i] * coef;
    }
}

/// Per-token MLP language model: `logits = W_head·(W_down·relu(W_up·E[x]))`.
/// Params: `[tok_embd (V×D), mlp_up (H×D), mlp_down (D×H), lm_head (V×D)]`.
fn mlp_pass(dims: &Dims, params: &[Tensor], x: &[i32], y: &[i32], grads: &mut [Vec<f64>]) -> f64 {
    let (v, d, h) = (dims.vocab, dims.d, dims.hidden);
    let e = f64s(&params[0]);
    let wu = f64s(&params[1]);
    let wd = f64s(&params[2]);
    let wh = f64s(&params[3]);
    let n_tok = x.len();
    let scale = 1.0 / n_tok as f64;

    let mut u_pre = vec![0.0; h];
    let mut u = vec![0.0; h];
    let mut z = vec![0.0; d];
    let mut logits = vec![0.0; v];
    let mut dlogits = vec![0.0; v];
    let mut dz = vec![0.0; d];
    let mut du = vec![0.0; h];
    let mut de = vec![0.0; d];
    let mut loss = 0.0;

    for n in 0..n_tok {
        let tok = x[n] as usize;
        let emb = &e[tok * d..(tok + 1) * d];
        matvec(&wu, h, d, emb, &mut u_pre);
        for i in 0..h {
            u[i] = u_pre[i].max(0.0);
        }
        matvec(&wd, d, h, &u, &mut z);
        matvec(&wh, v, d, &z, &mut logits);
        loss += softmax_ce(&logits, y[n] as usize, scale, &mut dlogits);

        // backward
        outer_acc(&mut grads[3], v, d, &dlogits, &z);
        dz.fill(0.0);
        matvec_t_acc(&wh, v, d, &dlogits, &mut dz);
        outer_acc(&mut grads[2], d, h, &dz, &u);
        du.fill(0.0);
        matvec_t_acc(&wd, d, h, &dz, &mut du);
        for i in 0..h {
            if u_pre[i] <= 0.0 {
                du[i] = 0.0;
            }
        }
        outer_acc(&mut grads[1], h, d, &du, emb);
        de.fill(0.0);
        matvec_t_acc(&wu, h, d, &du, &mut de);
        for (gi, di) in grads[0][tok * d..(tok + 1) * d].iter_mut().zip(&de) {
            *gi += di;
        }
    }
    loss * scale
}

/// One-block causal transformer with RMS-norm (scale-only), multi-head
/// attention and a ReLU MLP, residual connections around both sublayers.
/// Params (manifest order): tok_embd, pos_embd, ln_attn, attn_q/k/v/proj,
/// ln_mlp, mlp_up, mlp_down, ln_final, lm_head.
fn gpt_pass(dims: &Dims, params: &[Tensor], x: &[i32], y: &[i32], grads: &mut [Vec<f64>]) -> f64 {
    let (v, d, f, heads, t_ctx, b) =
        (dims.vocab, dims.d, dims.hidden, dims.heads, dims.ctx, dims.batch);
    let dh = d / heads;
    let att_scale = 1.0 / (dh as f64).sqrt();
    let p: Vec<Vec<f64>> = params.iter().map(f64s).collect();
    let (e, pos, g1, wq, wk, wv, wp, g2, wu, wd_, g3, wh) = (
        &p[0], &p[1], &p[2], &p[3], &p[4], &p[5], &p[6], &p[7], &p[8], &p[9], &p[10], &p[11],
    );
    let scale = 1.0 / (b * t_ctx) as f64;
    let mut loss = 0.0;

    // per-row activation buffers (T × dim, row-major by position)
    let td = t_ctx * d;
    let mut h0 = vec![0.0; td];
    let mut a = vec![0.0; td];
    let mut r1 = vec![0.0; t_ctx];
    let mut q = vec![0.0; td];
    let mut k = vec![0.0; td];
    let mut vv = vec![0.0; td];
    let mut att = vec![0.0; heads * t_ctx * t_ctx];
    let mut ctx = vec![0.0; td];
    let mut o = vec![0.0; td];
    let mut h1 = vec![0.0; td];
    let mut m_in = vec![0.0; td];
    let mut r2 = vec![0.0; t_ctx];
    let mut u_pre = vec![0.0; t_ctx * f];
    let mut u = vec![0.0; t_ctx * f];
    let mut h2 = vec![0.0; td];
    let mut fo = vec![0.0; td];
    let mut r3 = vec![0.0; t_ctx];
    let mut logits = vec![0.0; v];
    let mut dlogits = vec![0.0; v];
    // backward buffers, zeroed per row (accumulated within one row)
    let mut dh2 = vec![0.0; td];
    let mut dh1 = vec![0.0; td];
    let mut dh0 = vec![0.0; td];
    let mut dctx = vec![0.0; td];
    let mut dq = vec![0.0; td];
    let mut dk = vec![0.0; td];
    let mut dv = vec![0.0; td];
    let mut da = vec![0.0; td];
    let mut dfo = vec![0.0; d];
    let mut du = vec![0.0; f];
    let mut dm_in = vec![0.0; d];

    for row in 0..b {
        let xs = &x[row * t_ctx..(row + 1) * t_ctx];
        let ys = &y[row * t_ctx..(row + 1) * t_ctx];

        // ---- forward ----
        for t in 0..t_ctx {
            let tok = xs[t] as usize;
            for i in 0..d {
                h0[t * d + i] = e[tok * d + i] + pos[t * d + i];
            }
            r1[t] = rms_fwd(&h0[t * d..(t + 1) * d], g1, &mut a[t * d..(t + 1) * d]);
            matvec(wq, d, d, &a[t * d..(t + 1) * d], &mut q[t * d..(t + 1) * d]);
            matvec(wk, d, d, &a[t * d..(t + 1) * d], &mut k[t * d..(t + 1) * d]);
            matvec(wv, d, d, &a[t * d..(t + 1) * d], &mut vv[t * d..(t + 1) * d]);
        }
        ctx.fill(0.0);
        for hh in 0..heads {
            let off = hh * dh;
            for t in 0..t_ctx {
                let arow = &mut att[(hh * t_ctx + t) * t_ctx..(hh * t_ctx + t + 1) * t_ctx];
                let mut max = f64::NEG_INFINITY;
                for tp in 0..=t {
                    let mut s = 0.0;
                    for i in 0..dh {
                        s += q[t * d + off + i] * k[tp * d + off + i];
                    }
                    arow[tp] = s * att_scale;
                    max = max.max(arow[tp]);
                }
                let mut z = 0.0;
                for tp in 0..=t {
                    arow[tp] = (arow[tp] - max).exp();
                    z += arow[tp];
                }
                for tp in 0..=t {
                    arow[tp] /= z;
                    for i in 0..dh {
                        ctx[t * d + off + i] += arow[tp] * vv[tp * d + off + i];
                    }
                }
                for item in arow.iter_mut().skip(t + 1) {
                    *item = 0.0;
                }
            }
        }
        for t in 0..t_ctx {
            matvec(wp, d, d, &ctx[t * d..(t + 1) * d], &mut o[t * d..(t + 1) * d]);
            for i in 0..d {
                h1[t * d + i] = h0[t * d + i] + o[t * d + i];
            }
            r2[t] = rms_fwd(&h1[t * d..(t + 1) * d], g2, &mut m_in[t * d..(t + 1) * d]);
            matvec(wu, f, d, &m_in[t * d..(t + 1) * d], &mut u_pre[t * f..(t + 1) * f]);
            for i in 0..f {
                u[t * f + i] = u_pre[t * f + i].max(0.0);
            }
            // h2 = h1 + W_down u
            let h2t = &mut h2[t * d..(t + 1) * d];
            matvec(wd_, d, f, &u[t * f..(t + 1) * f], h2t);
            for i in 0..d {
                h2t[i] += h1[t * d + i];
            }
            r3[t] = rms_fwd(&h2[t * d..(t + 1) * d], g3, &mut fo[t * d..(t + 1) * d]);
        }

        // ---- backward ----
        for buf in [
            &mut dh2, &mut dh1, &mut dh0, &mut dctx, &mut dq, &mut dk, &mut dv, &mut da,
        ] {
            buf.fill(0.0);
        }

        for t in 0..t_ctx {
            matvec(wh, v, d, &fo[t * d..(t + 1) * d], &mut logits);
            loss += softmax_ce(&logits, ys[t] as usize, scale, &mut dlogits);
            outer_acc(&mut grads[11], v, d, &dlogits, &fo[t * d..(t + 1) * d]);
            dfo.fill(0.0);
            matvec_t_acc(wh, v, d, &dlogits, &mut dfo);
            rms_bwd(
                &h2[t * d..(t + 1) * d],
                g3,
                r3[t],
                &dfo,
                &mut dh2[t * d..(t + 1) * d],
                &mut grads[10],
            );
        }
        for t in 0..t_ctx {
            // h2 = h1 + W_down relu(W_up m_in)
            let dh2t = &dh2[t * d..(t + 1) * d];
            for i in 0..d {
                dh1[t * d + i] += dh2t[i];
            }
            outer_acc(&mut grads[9], d, f, dh2t, &u[t * f..(t + 1) * f]);
            du.fill(0.0);
            matvec_t_acc(wd_, d, f, dh2t, &mut du);
            for i in 0..f {
                if u_pre[t * f + i] <= 0.0 {
                    du[i] = 0.0;
                }
            }
            outer_acc(&mut grads[8], f, d, &du, &m_in[t * d..(t + 1) * d]);
            dm_in.fill(0.0);
            matvec_t_acc(wu, f, d, &du, &mut dm_in);
            rms_bwd(
                &h1[t * d..(t + 1) * d],
                g2,
                r2[t],
                &dm_in,
                &mut dh1[t * d..(t + 1) * d],
                &mut grads[7],
            );
        }
        for t in 0..t_ctx {
            // h1 = h0 + W_proj ctx
            let dh1t = &dh1[t * d..(t + 1) * d];
            for i in 0..d {
                dh0[t * d + i] += dh1t[i];
            }
            outer_acc(&mut grads[6], d, d, dh1t, &ctx[t * d..(t + 1) * d]);
            matvec_t_acc(wp, d, d, dh1t, &mut dctx[t * d..(t + 1) * d]);
        }
        for hh in 0..heads {
            let off = hh * dh;
            for t in 0..t_ctx {
                let arow = &att[(hh * t_ctx + t) * t_ctx..(hh * t_ctx + t + 1) * t_ctx];
                // d(att row) then softmax jacobian
                let mut datt = vec![0.0; t + 1];
                for (tp, dat) in datt.iter_mut().enumerate() {
                    let mut s = 0.0;
                    for i in 0..dh {
                        s += dctx[t * d + off + i] * vv[tp * d + off + i];
                    }
                    *dat = s;
                    for i in 0..dh {
                        dv[tp * d + off + i] += arow[tp] * dctx[t * d + off + i];
                    }
                }
                let dot: f64 = (0..=t).map(|tp| arow[tp] * datt[tp]).sum();
                for (tp, dat) in datt.iter().enumerate() {
                    let ds = arow[tp] * (dat - dot) * att_scale;
                    for i in 0..dh {
                        dq[t * d + off + i] += ds * k[tp * d + off + i];
                        dk[tp * d + off + i] += ds * q[t * d + off + i];
                    }
                }
            }
        }
        for t in 0..t_ctx {
            let at = &a[t * d..(t + 1) * d];
            outer_acc(&mut grads[3], d, d, &dq[t * d..(t + 1) * d], at);
            outer_acc(&mut grads[4], d, d, &dk[t * d..(t + 1) * d], at);
            outer_acc(&mut grads[5], d, d, &dv[t * d..(t + 1) * d], at);
            let dat = &mut da[t * d..(t + 1) * d];
            matvec_t_acc(wq, d, d, &dq[t * d..(t + 1) * d], dat);
            matvec_t_acc(wk, d, d, &dk[t * d..(t + 1) * d], dat);
            matvec_t_acc(wv, d, d, &dv[t * d..(t + 1) * d], dat);
            rms_bwd(
                &h0[t * d..(t + 1) * d],
                g1,
                r1[t],
                &da[t * d..(t + 1) * d],
                &mut dh0[t * d..(t + 1) * d],
                &mut grads[2],
            );
        }
        for t in 0..t_ctx {
            let tok = xs[t] as usize;
            for i in 0..d {
                grads[0][tok * d + i] += dh0[t * d + i];
                grads[1][t * d + i] += dh0[t * d + i];
            }
        }
    }
    loss * scale
}

// ---------------------------------------------------------------------------
// Lane-stacked batched interpreter (DESIGN.md §12)
//
// `run_batch` stacks B independent jobs along a trailing *lane* axis:
// element `j` of job `b` lives at `j * lanes + b`, so the innermost loops
// below walk unit-stride lane blocks the compiler can vectorize (B f64
// accumulators per step instead of one). Every reduction keeps the scalar
// interpreter's iteration order — sums run over the same non-lane index in
// the same sequence, lanes merely add an independent dimension — so each
// lane's floating-point operation sequence is exactly the scalar pass's,
// and batched results are bit-for-bit identical to sequential `run` calls
// (`run_batch_bit_identical_to_sequential` below and the scheduler-level
// differential suite in `rust/tests/batched_agreement.rs`).
// ---------------------------------------------------------------------------

/// Lane matvec: `out[r] = W[r,:]·v` per lane (accumulation over `cols` in
/// scalar order).
fn matvec_l(w: &[f64], rows: usize, cols: usize, v: &[f64], out: &mut [f64], l: usize) {
    for r in 0..rows {
        let o = &mut out[r * l..(r + 1) * l];
        o.fill(0.0);
        for c in 0..cols {
            let wv = &w[(r * cols + c) * l..(r * cols + c + 1) * l];
            let vc = &v[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += wv[b] * vc[b];
            }
        }
    }
}

/// Lane transpose matvec: `out[c] += W[:,c]·v` per lane (accumulation
/// over `rows` in scalar order).
fn matvec_t_acc_l(w: &[f64], rows: usize, cols: usize, v: &[f64], out: &mut [f64], l: usize) {
    for r in 0..rows {
        let vr = &v[r * l..(r + 1) * l];
        for c in 0..cols {
            let wv = &w[(r * cols + c) * l..(r * cols + c + 1) * l];
            let o = &mut out[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += wv[b] * vr[b];
            }
        }
    }
}

/// Lane outer-product accumulation: `dW[r,c] += dv[r] * u[c]` per lane.
fn outer_acc_l(dw: &mut [f64], rows: usize, cols: usize, dv: &[f64], u: &[f64], l: usize) {
    for r in 0..rows {
        let d = &dv[r * l..(r + 1) * l];
        for c in 0..cols {
            let o = &mut dw[(r * cols + c) * l..(r * cols + c + 1) * l];
            let uc = &u[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += d[b] * uc[b];
            }
        }
    }
}

/// Lane softmax cross-entropy at one position (mirrors `softmax_ce`):
/// per-lane label `ys[b]`, per-lane `-ln p[y]` added into `losses`.
/// `maxs`/`zs` are caller-provided lane scratch.
#[allow(clippy::too_many_arguments)]
fn softmax_ce_l(
    logits: &[f64],
    ys: &[usize],
    scale: f64,
    dlogits: &mut [f64],
    maxs: &mut [f64],
    zs: &mut [f64],
    losses: &mut [f64],
    l: usize,
) {
    let v = logits.len() / l;
    maxs.fill(f64::NEG_INFINITY);
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        for b in 0..l {
            maxs[b] = maxs[b].max(li[b]);
        }
    }
    zs.fill(0.0);
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = (li[b] - maxs[b]).exp();
            zs[b] += di[b];
        }
    }
    for b in 0..l {
        losses[b] += -(dlogits[ys[b] * l + b] / zs[b]).max(f64::MIN_POSITIVE).ln();
    }
    for i in 0..v {
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = di[b] / zs[b] * scale;
        }
    }
    for b in 0..l {
        dlogits[ys[b] * l + b] -= scale;
    }
}

/// Lane RMS-norm forward (mirrors `rms_fwd`); writes per-lane rms into
/// `rs`.
fn rms_fwd_l(x: &[f64], g: &[f64], out: &mut [f64], rs: &mut [f64], l: usize) {
    let dim = x.len() / l;
    let d = dim as f64;
    rs.fill(0.0);
    for i in 0..dim {
        let xi = &x[i * l..(i + 1) * l];
        for b in 0..l {
            rs[b] += xi[b] * xi[b];
        }
    }
    for b in 0..l {
        rs[b] = (rs[b] / d + RMS_EPS).sqrt();
    }
    for i in 0..dim {
        for b in 0..l {
            out[i * l + b] = x[i * l + b] / rs[b] * g[i * l + b];
        }
    }
}

/// Lane RMS-norm backward (mirrors `rms_bwd`). `dots` is lane scratch.
#[allow(clippy::too_many_arguments)]
fn rms_bwd_l(
    x: &[f64],
    g: &[f64],
    rs: &[f64],
    dy: &[f64],
    dx: &mut [f64],
    dg: &mut [f64],
    dots: &mut [f64],
    l: usize,
) {
    let dim = x.len() / l;
    let d = dim as f64;
    dots.fill(0.0);
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dg[s] += dy[s] * x[s] / rs[b];
            dots[b] += dy[s] * g[s] * x[s];
        }
    }
    for b in 0..l {
        dots[b] /= d * rs[b] * rs[b] * rs[b];
    }
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dx[s] += dy[s] * g[s] / rs[b] - x[s] * dots[b];
        }
    }
}

/// Lane-stacked loss + gradients: per-lane losses (scaled like the
/// scalar `loss_and_grads`) and lane-major f64 gradients.
fn loss_and_grads_l(
    dims: &Dims,
    params_l: &[Vec<f64>],
    xs: &[Vec<i32>],
    ys: &[Vec<i32>],
    lanes: usize,
) -> (Vec<f64>, Vec<Vec<f64>>) {
    let mut grads: Vec<Vec<f64>> = params_l.iter().map(|p| vec![0.0; p.len()]).collect();
    let losses = match dims.family {
        Family::Mlp => mlp_pass_l(dims, params_l, xs, ys, &mut grads, lanes),
        Family::Gpt => gpt_pass_l(dims, params_l, xs, ys, &mut grads, lanes),
    };
    (losses, grads)
}

/// Lane translation of `mlp_pass` — identical loop structure, every
/// buffer carries a trailing lane axis, token gathers differ per lane.
fn mlp_pass_l(
    dims: &Dims,
    params_l: &[Vec<f64>],
    xs: &[Vec<i32>],
    ys: &[Vec<i32>],
    grads_l: &mut [Vec<f64>],
    l: usize,
) -> Vec<f64> {
    let (v, d, h) = (dims.vocab, dims.d, dims.hidden);
    let e = &params_l[0];
    let wu = &params_l[1];
    let wd = &params_l[2];
    let wh = &params_l[3];
    let n_tok = xs[0].len();
    let scale = 1.0 / n_tok as f64;

    let mut emb = vec![0.0; d * l];
    let mut u_pre = vec![0.0; h * l];
    let mut u = vec![0.0; h * l];
    let mut z = vec![0.0; d * l];
    let mut logits = vec![0.0; v * l];
    let mut dlogits = vec![0.0; v * l];
    let mut dz = vec![0.0; d * l];
    let mut du = vec![0.0; h * l];
    let mut de = vec![0.0; d * l];
    let mut maxs = vec![0.0; l];
    let mut zs = vec![0.0; l];
    let mut losses = vec![0.0; l];
    let mut ytok = vec![0usize; l];

    for n in 0..n_tok {
        for b in 0..l {
            let tok = xs[b][n] as usize;
            for i in 0..d {
                emb[i * l + b] = e[(tok * d + i) * l + b];
            }
            ytok[b] = ys[b][n] as usize;
        }
        matvec_l(wu, h, d, &emb, &mut u_pre, l);
        for j in 0..h * l {
            u[j] = u_pre[j].max(0.0);
        }
        matvec_l(wd, d, h, &u, &mut z, l);
        matvec_l(wh, v, d, &z, &mut logits, l);
        softmax_ce_l(&logits, &ytok, scale, &mut dlogits, &mut maxs, &mut zs, &mut losses, l);

        // backward
        outer_acc_l(&mut grads_l[3], v, d, &dlogits, &z, l);
        dz.fill(0.0);
        matvec_t_acc_l(wh, v, d, &dlogits, &mut dz, l);
        outer_acc_l(&mut grads_l[2], d, h, &dz, &u, l);
        du.fill(0.0);
        matvec_t_acc_l(wd, d, h, &dz, &mut du, l);
        for j in 0..h * l {
            if u_pre[j] <= 0.0 {
                du[j] = 0.0;
            }
        }
        outer_acc_l(&mut grads_l[1], h, d, &du, &emb, l);
        de.fill(0.0);
        matvec_t_acc_l(wu, h, d, &du, &mut de, l);
        for b in 0..l {
            let tok = xs[b][n] as usize;
            for i in 0..d {
                grads_l[0][(tok * d + i) * l + b] += de[i * l + b];
            }
        }
    }
    losses.iter().map(|&x| x * scale).collect()
}

/// Lane translation of `gpt_pass` — identical loop structure; attention
/// rows, norms and residuals all carry the trailing lane axis.
fn gpt_pass_l(
    dims: &Dims,
    params_l: &[Vec<f64>],
    xs: &[Vec<i32>],
    ys: &[Vec<i32>],
    grads_l: &mut [Vec<f64>],
    l: usize,
) -> Vec<f64> {
    let (v, d, f, heads, t_ctx, rows_b) =
        (dims.vocab, dims.d, dims.hidden, dims.heads, dims.ctx, dims.batch);
    let dh = d / heads;
    let att_scale = 1.0 / (dh as f64).sqrt();
    let (e, pos, g1, wq, wk, wv, wp, g2, wu, wd_, g3, wh) = (
        &params_l[0], &params_l[1], &params_l[2], &params_l[3], &params_l[4],
        &params_l[5], &params_l[6], &params_l[7], &params_l[8], &params_l[9],
        &params_l[10], &params_l[11],
    );
    let scale = 1.0 / (rows_b * t_ctx) as f64;
    let mut losses = vec![0.0; l];

    let td = t_ctx * d;
    let mut h0 = vec![0.0; td * l];
    let mut a = vec![0.0; td * l];
    let mut r1 = vec![0.0; t_ctx * l];
    let mut q = vec![0.0; td * l];
    let mut k = vec![0.0; td * l];
    let mut vv = vec![0.0; td * l];
    let mut att = vec![0.0; heads * t_ctx * t_ctx * l];
    let mut ctx = vec![0.0; td * l];
    let mut o = vec![0.0; td * l];
    let mut h1 = vec![0.0; td * l];
    let mut m_in = vec![0.0; td * l];
    let mut r2 = vec![0.0; t_ctx * l];
    let mut u_pre = vec![0.0; t_ctx * f * l];
    let mut u = vec![0.0; t_ctx * f * l];
    let mut h2 = vec![0.0; td * l];
    let mut fo = vec![0.0; td * l];
    let mut r3 = vec![0.0; t_ctx * l];
    let mut logits = vec![0.0; v * l];
    let mut dlogits = vec![0.0; v * l];
    let mut dh2 = vec![0.0; td * l];
    let mut dh1 = vec![0.0; td * l];
    let mut dh0 = vec![0.0; td * l];
    let mut dctx = vec![0.0; td * l];
    let mut dq = vec![0.0; td * l];
    let mut dk = vec![0.0; td * l];
    let mut dv = vec![0.0; td * l];
    let mut da = vec![0.0; td * l];
    let mut dfo = vec![0.0; d * l];
    let mut du = vec![0.0; f * l];
    let mut dm_in = vec![0.0; d * l];
    let mut datt = vec![0.0; t_ctx * l];
    let mut ds_l = vec![0.0; l];
    let mut maxs = vec![0.0; l];
    let mut zs = vec![0.0; l];
    let mut dots = vec![0.0; l];
    let mut ytok = vec![0usize; l];

    for row in 0..rows_b {
        // ---- forward ----
        for t in 0..t_ctx {
            for b in 0..l {
                let tok = xs[b][row * t_ctx + t] as usize;
                for i in 0..d {
                    h0[(t * d + i) * l + b] =
                        e[(tok * d + i) * l + b] + pos[(t * d + i) * l + b];
                }
            }
            let tr = t * d * l..(t + 1) * d * l;
            rms_fwd_l(&h0[tr.clone()], g1, &mut a[tr.clone()], &mut r1[t * l..(t + 1) * l], l);
            matvec_l(wq, d, d, &a[tr.clone()], &mut q[tr.clone()], l);
            matvec_l(wk, d, d, &a[tr.clone()], &mut k[tr.clone()], l);
            matvec_l(wv, d, d, &a[tr.clone()], &mut vv[tr.clone()], l);
        }
        ctx.fill(0.0);
        for hh in 0..heads {
            let off = hh * dh;
            for t in 0..t_ctx {
                let arow0 = (hh * t_ctx + t) * t_ctx * l;
                maxs.fill(f64::NEG_INFINITY);
                for tp in 0..=t {
                    let sbuf = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    sbuf.fill(0.0);
                    for i in 0..dh {
                        let qi = &q[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                        let ki = &k[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        for b in 0..l {
                            sbuf[b] += qi[b] * ki[b];
                        }
                    }
                    for b in 0..l {
                        sbuf[b] *= att_scale;
                        maxs[b] = maxs[b].max(sbuf[b]);
                    }
                }
                zs.fill(0.0);
                for tp in 0..=t {
                    let ab = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    for b in 0..l {
                        ab[b] = (ab[b] - maxs[b]).exp();
                        zs[b] += ab[b];
                    }
                }
                for tp in 0..=t {
                    // normalize, then accumulate this tp's contribution to
                    // ctx — the scalar pass's interleave, kept verbatim
                    {
                        let ab = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                        for b in 0..l {
                            ab[b] /= zs[b];
                        }
                    }
                    let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    for i in 0..dh {
                        let vvi = &vv[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        let ci = &mut ctx[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                        for b in 0..l {
                            ci[b] += ab[b] * vvi[b];
                        }
                    }
                }
            }
        }
        for t in 0..t_ctx {
            let tr = t * d * l..(t + 1) * d * l;
            matvec_l(wp, d, d, &ctx[tr.clone()], &mut o[tr.clone()], l);
            for j in tr.clone() {
                h1[j] = h0[j] + o[j];
            }
            rms_fwd_l(&h1[tr.clone()], g2, &mut m_in[tr.clone()], &mut r2[t * l..(t + 1) * l], l);
            let fr = t * f * l..(t + 1) * f * l;
            matvec_l(wu, f, d, &m_in[tr.clone()], &mut u_pre[fr.clone()], l);
            for j in fr.clone() {
                u[j] = u_pre[j].max(0.0);
            }
            // h2 = h1 + W_down u
            matvec_l(wd_, d, f, &u[fr], &mut h2[tr.clone()], l);
            for j in tr.clone() {
                h2[j] += h1[j];
            }
            rms_fwd_l(&h2[tr.clone()], g3, &mut fo[tr], &mut r3[t * l..(t + 1) * l], l);
        }

        // ---- backward ----
        for buf in [
            &mut dh2, &mut dh1, &mut dh0, &mut dctx, &mut dq, &mut dk, &mut dv, &mut da,
        ] {
            buf.fill(0.0);
        }

        for t in 0..t_ctx {
            let tr = t * d * l..(t + 1) * d * l;
            matvec_l(wh, v, d, &fo[tr.clone()], &mut logits, l);
            for b in 0..l {
                ytok[b] = ys[b][row * t_ctx + t] as usize;
            }
            softmax_ce_l(&logits, &ytok, scale, &mut dlogits, &mut maxs, &mut zs, &mut losses, l);
            outer_acc_l(&mut grads_l[11], v, d, &dlogits, &fo[tr.clone()], l);
            dfo.fill(0.0);
            matvec_t_acc_l(wh, v, d, &dlogits, &mut dfo, l);
            rms_bwd_l(
                &h2[tr.clone()],
                g3,
                &r3[t * l..(t + 1) * l],
                &dfo,
                &mut dh2[tr],
                &mut grads_l[10],
                &mut dots,
                l,
            );
        }
        for t in 0..t_ctx {
            // h2 = h1 + W_down relu(W_up m_in)
            let tr = t * d * l..(t + 1) * d * l;
            let fr = t * f * l..(t + 1) * f * l;
            for j in tr.clone() {
                dh1[j] += dh2[j];
            }
            outer_acc_l(&mut grads_l[9], d, f, &dh2[tr.clone()], &u[fr.clone()], l);
            du.fill(0.0);
            matvec_t_acc_l(wd_, d, f, &dh2[tr.clone()], &mut du, l);
            for (j, x) in u_pre[fr].iter().enumerate() {
                if *x <= 0.0 {
                    du[j] = 0.0;
                }
            }
            outer_acc_l(&mut grads_l[8], f, d, &du, &m_in[tr.clone()], l);
            dm_in.fill(0.0);
            matvec_t_acc_l(wu, f, d, &du, &mut dm_in, l);
            rms_bwd_l(
                &h1[tr.clone()],
                g2,
                &r2[t * l..(t + 1) * l],
                &dm_in,
                &mut dh1[tr],
                &mut grads_l[7],
                &mut dots,
                l,
            );
        }
        for t in 0..t_ctx {
            // h1 = h0 + W_proj ctx
            let tr = t * d * l..(t + 1) * d * l;
            for j in tr.clone() {
                dh0[j] += dh1[j];
            }
            outer_acc_l(&mut grads_l[6], d, d, &dh1[tr.clone()], &ctx[tr.clone()], l);
            matvec_t_acc_l(wp, d, d, &dh1[tr.clone()], &mut dctx[tr], l);
        }
        for hh in 0..heads {
            let off = hh * dh;
            for t in 0..t_ctx {
                let arow0 = (hh * t_ctx + t) * t_ctx * l;
                for tp in 0..=t {
                    let dat = &mut datt[tp * l..(tp + 1) * l];
                    dat.fill(0.0);
                    for i in 0..dh {
                        let dci = &dctx[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                        let vvi = &vv[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        for b in 0..l {
                            dat[b] += dci[b] * vvi[b];
                        }
                    }
                    let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    for i in 0..dh {
                        let dci = &dctx[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                        let dvi = &mut dv[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        for b in 0..l {
                            dvi[b] += ab[b] * dci[b];
                        }
                    }
                }
                dots.fill(0.0);
                for tp in 0..=t {
                    let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    let dat = &datt[tp * l..(tp + 1) * l];
                    for b in 0..l {
                        dots[b] += ab[b] * dat[b];
                    }
                }
                for tp in 0..=t {
                    let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                    let dat = &datt[tp * l..(tp + 1) * l];
                    for b in 0..l {
                        ds_l[b] = ab[b] * (dat[b] - dots[b]) * att_scale;
                    }
                    for i in 0..dh {
                        let ki = &k[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        let qi = &q[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                        {
                            let dqi = &mut dq[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                            for b in 0..l {
                                dqi[b] += ds_l[b] * ki[b];
                            }
                        }
                        let dki = &mut dk[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                        for b in 0..l {
                            dki[b] += ds_l[b] * qi[b];
                        }
                    }
                }
            }
        }
        for t in 0..t_ctx {
            let tr = t * d * l..(t + 1) * d * l;
            outer_acc_l(&mut grads_l[3], d, d, &dq[tr.clone()], &a[tr.clone()], l);
            outer_acc_l(&mut grads_l[4], d, d, &dk[tr.clone()], &a[tr.clone()], l);
            outer_acc_l(&mut grads_l[5], d, d, &dv[tr.clone()], &a[tr.clone()], l);
            matvec_t_acc_l(wq, d, d, &dq[tr.clone()], &mut da[tr.clone()], l);
            matvec_t_acc_l(wk, d, d, &dk[tr.clone()], &mut da[tr.clone()], l);
            matvec_t_acc_l(wv, d, d, &dv[tr.clone()], &mut da[tr.clone()], l);
            rms_bwd_l(
                &h0[tr.clone()],
                g1,
                &r1[t * l..(t + 1) * l],
                &da[tr.clone()],
                &mut dh0[tr],
                &mut grads_l[2],
                &mut dots,
                l,
            );
        }
        for t in 0..t_ctx {
            for b in 0..l {
                let tok = xs[b][row * t_ctx + t] as usize;
                for i in 0..d {
                    grads_l[0][(tok * d + i) * l + b] += dh0[(t * d + i) * l + b];
                    grads_l[1][(t * d + i) * l + b] += dh0[(t * d + i) * l + b];
                }
            }
        }
    }
    losses.iter().map(|&x| x * scale).collect()
}

/// Per-lane global-norm clip over lane-major f32 gradients (mirrors
/// `optim::clip_global_norm`: squares accumulate in f64 over tensors and
/// elements in scalar order). Returns each lane's pre-clip norm.
fn clip_global_norm_l(grads: &mut [Vec<f32>], max_norm: f64, l: usize) -> Vec<f64> {
    let mut sq = vec![0.0f64; l];
    for g in grads.iter() {
        let numel = g.len() / l;
        for j in 0..numel {
            let row = &g[j * l..(j + 1) * l];
            for b in 0..l {
                sq[b] += (row[b] as f64) * (row[b] as f64);
            }
        }
    }
    let norms: Vec<f64> = sq.iter().map(|s| s.sqrt()).collect();
    for (b, &norm) in norms.iter().enumerate() {
        if norm > max_norm && norm > 0.0 {
            let scale = (max_norm / norm) as f32;
            for g in grads.iter_mut() {
                for x in g[b..].iter_mut().step_by(l) {
                    *x *= scale;
                }
            }
        }
    }
    norms
}

/// Per-lane fused reduced-V AdamW update over lane-major f32 state
/// (mirrors `fused_update`; each lane carries its own step index and
/// learning rate, so bias corrections are per lane).
#[allow(clippy::too_many_arguments)]
fn fused_update_l(
    man: &Manifest,
    k_modes: &[KMode],
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    ts: &[usize],
    lrs: &[f32],
    l: usize,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let bc1: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b1.powi(t as i32))).collect();
    let bc2: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b2.powi(t as i32))).collect();
    for i in 0..w.len() {
        let info = &man.params[i];
        let k = crate::optim::adamk::effective_k(info, k_modes[i]);
        let (rows, cols) = info.matrix_dims();
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        let numel = info.numel();
        let wi = &mut w[i];
        let gi = &g[i];
        let mi = &mut m[i];
        let vi = &mut v[i];
        if k == KMode::None {
            for j in 0..numel {
                for b in 0..l {
                    let s = j * l + b;
                    let gj = gi[s];
                    mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                    vi[s] = b2 * vi[s] + (1.0 - b2) * gj * gj;
                    let mh = mi[s] * bc1[b];
                    let vh = vi[s] * bc2[b];
                    wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
                }
            }
            continue;
        }
        let group = |j: usize| -> usize {
            match k {
                KMode::None => j,
                KMode::FanIn => j / cols,
                KMode::FanOut => j % cols,
                KMode::Both => 0,
                KMode::Blocks(nb) => (j / cols) * nb / rows,
            }
        };
        let gsize = match k {
            KMode::None => 1.0,
            KMode::FanIn => cols as f32,
            KMode::FanOut => rows as f32,
            KMode::Both => (rows * cols) as f32,
            KMode::Blocks(nb) => ((rows / nb) * cols) as f32,
        };
        let vlen = vi.len() / l;
        let mut sums = vec![0.0f32; vlen * l];
        for j in 0..numel {
            let gr = group(j) * l;
            for b in 0..l {
                let gj = gi[j * l + b];
                sums[gr + b] += gj * gj;
            }
        }
        for jv in 0..vlen {
            for b in 0..l {
                let s = jv * l + b;
                vi[s] = b2 * vi[s] + (1.0 - b2) * (sums[s] / gsize);
            }
        }
        for j in 0..numel {
            let gr = group(j) * l;
            for b in 0..l {
                let s = j * l + b;
                let gj = gi[s];
                mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                let mh = mi[s] * bc1[b];
                let vh = vi[gr + b] * bc2[b];
                wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn init_params(man: &Manifest, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        man.params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect()
    }

    fn batch(dims: &Dims, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let n = dims.batch * dims.ctx;
        let mut draw = || (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
        (draw(), draw())
    }

    #[test]
    fn manifests_generate_and_validate() {
        for model in MODELS {
            let grad = artifact(&format!("{model}.grad")).unwrap();
            assert_eq!(grad.manifest.kind, "grad_step");
            assert!(grad.manifest_hash != 0);
            for ruleset in RULESETS {
                let train = artifact(&format!("{model}.train.{ruleset}")).unwrap();
                assert_eq!(train.manifest.kind, "train_step");
                assert_eq!(train.manifest.ruleset.as_deref(), Some(*ruleset));
                // grad and train agree on params/batch, differ in hash
                assert_eq!(train.manifest.n_params(), grad.manifest.n_params());
                assert_ne!(train.manifest_hash, grad.manifest_hash);
            }
        }
        assert!(artifact("mlp_tiny.nonsense").is_err());
        assert!(artifact("no_such_model.grad").is_err());
    }

    #[test]
    fn manifest_hash_is_stable() {
        let a = artifact("gpt_micro.grad").unwrap();
        let b = artifact("gpt_micro.grad").unwrap();
        assert_eq!(a.manifest_hash, b.manifest_hash);
    }

    #[test]
    fn slimadam_ruleset_saves_memory() {
        let adam = artifact("gpt_micro.train.adam").unwrap();
        let slim = artifact("gpt_micro.train.slimadam").unwrap();
        let v_elems = |m: &Manifest| -> usize {
            m.v_shapes
                .as_ref()
                .unwrap()
                .iter()
                .map(|s| s.iter().product::<usize>())
                .sum()
        };
        let full = v_elems(&adam.manifest);
        let reduced = v_elems(&slim.manifest);
        assert_eq!(full, adam.manifest.total_param_elems());
        assert!(
            (reduced as f64) < 0.2 * full as f64,
            "slimadam v_elems {reduced} vs adam {full}"
        );
    }

    /// Central-difference gradient check for both model families: the
    /// handwritten backward passes must match the loss surface.
    #[test]
    fn gradients_match_finite_differences() {
        for model in MODELS {
            let dims = dims_for(model).unwrap();
            let man = grad_manifest(model).unwrap();
            let params = init_params(&man, 11);
            let (x, y) = batch(&dims, 12);
            let (_, grads) = loss_and_grads(&dims, &params, &x, &y);
            let mut rng = Rng::new(13);
            let eps = 1e-3f32;
            for (pi, p) in params.iter().enumerate() {
                // probe a handful of coordinates per tensor
                for _ in 0..4 {
                    let j = rng.usize_below(p.numel());
                    let mut plus = params.clone();
                    plus[pi].data[j] += eps;
                    let mut minus = params.clone();
                    minus[pi].data[j] -= eps;
                    let fd = (loss_only(&dims, &plus, &x, &y)
                        - loss_only(&dims, &minus, &x, &y))
                        / (2.0 * eps as f64);
                    let an = grads[pi].data[j] as f64;
                    assert!(
                        (fd - an).abs() <= 1e-4 + 5e-2 * an.abs().max(fd.abs()),
                        "{model} param {pi} ({}) elem {j}: fd {fd} vs analytic {an}",
                        man.params[pi].name
                    );
                }
            }
        }
    }

    #[test]
    fn grad_step_is_deterministic() {
        let dims = dims_for("gpt_micro").unwrap();
        let man = grad_manifest("gpt_micro").unwrap();
        let params = init_params(&man, 3);
        let (x, y) = batch(&dims, 4);
        let (l1, g1) = loss_and_grads(&dims, &params, &x, &y);
        let (l2, g2) = loss_and_grads(&dims, &params, &x, &y);
        assert_eq!(l1.to_bits(), l2.to_bits());
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn executable_runs_grad_and_train() {
        for model in MODELS {
            let backend = NativeBackend::default();
            let art = artifact(&format!("{model}.grad")).unwrap();
            let exe = backend.compile(&art).unwrap();
            let man = &art.manifest;
            let dims = dims_for(model).unwrap();
            let params = init_params(man, 5);
            let (x, y) = batch(&dims, 6);
            let mut inputs: Vec<Literal> = params
                .iter()
                .map(|t| tensor_to_literal(t).unwrap())
                .collect();
            inputs.push(
                crate::runtime::literal::i32_literal(&x, &[dims.batch, dims.ctx]).unwrap(),
            );
            inputs.push(
                crate::runtime::literal::i32_literal(&y, &[dims.batch, dims.ctx]).unwrap(),
            );
            let outs = exe.run(&inputs).unwrap();
            assert_eq!(outs.len(), 1 + man.n_params());
            let loss = crate::runtime::literal::scalar_value(&outs[0]).unwrap();
            // random tokens: loss should start near ln(vocab)
            assert!((loss as f64 - (dims.vocab as f64).ln()).abs() < 1.0, "{loss}");
        }
    }

    #[test]
    fn fused_train_step_decreases_loss() {
        use crate::runtime::engine::TrainEngine;
        let backend = NativeBackend::default();
        let art = artifact("mlp_tiny.train.adam").unwrap();
        let compiled = std::rc::Rc::new(art.compile(&backend).unwrap());
        let mut eng = TrainEngine::with_compiled(compiled, "mitchell", 7).unwrap();
        let dims = dims_for("mlp_tiny").unwrap();
        let (x, y) = batch(&dims, 8);
        let b = vec![
            crate::runtime::engine::BatchData::I32(x),
            crate::runtime::engine::BatchData::I32(y),
        ];
        let first = eng.step(&b, 3e-3).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = eng.step(&b, 3e-3).unwrap();
        }
        assert!(first.loss.is_finite() && last.grad_norm.is_finite());
        assert!(
            last.loss < first.loss,
            "native fused step did not reduce loss: {} -> {}",
            first.loss,
            last.loss
        );
    }

    /// The lane-stacked batched interpreter must be bit-for-bit identical
    /// to sequential `run` calls — for both model families, both manifest
    /// kinds and every ruleset, with per-lane step/lr scalars differing.
    #[test]
    fn run_batch_bit_identical_to_sequential() {
        fn lit_bits(lit: &Literal) -> (Vec<i64>, Vec<u32>) {
            let dims = lit.array_shape().unwrap().dims().to_vec();
            let bits = lit
                .to_vec::<f32>()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            (dims, bits)
        }
        fn assert_jobs_eq(seq: &[Vec<Literal>], bat: &[Vec<Literal>], what: &str) {
            assert_eq!(seq.len(), bat.len(), "{what}");
            for (b, (s, t)) in seq.iter().zip(bat).enumerate() {
                assert_eq!(s.len(), t.len(), "{what} job {b}");
                for (slot, (a, c)) in s.iter().zip(t).enumerate() {
                    assert_eq!(lit_bits(a), lit_bits(c), "{what} job {b} output {slot}");
                }
            }
        }

        let backend = NativeBackend::default();
        for model in MODELS {
            let dims = dims_for(model).unwrap();

            // grad_step
            let art = artifact(&format!("{model}.grad")).unwrap();
            let exe = backend.compile(&art).unwrap();
            let man = art.manifest.clone();
            let jobs: Vec<Vec<Literal>> = (0..3)
                .map(|jj| {
                    let params = init_params(&man, 100 + jj as u64);
                    let (x, y) = batch(&dims, 200 + jj as u64);
                    let mut inputs: Vec<Literal> = params
                        .iter()
                        .map(|t| tensor_to_literal(t).unwrap())
                        .collect();
                    inputs.push(
                        crate::runtime::literal::i32_literal(&x, &[dims.batch, dims.ctx])
                            .unwrap(),
                    );
                    inputs.push(
                        crate::runtime::literal::i32_literal(&y, &[dims.batch, dims.ctx])
                            .unwrap(),
                    );
                    inputs
                })
                .collect();
            let seq: Vec<Vec<Literal>> = jobs.iter().map(|j| exe.run(j).unwrap()).collect();
            let bat = exe.run_batch(&jobs).unwrap();
            assert_jobs_eq(&seq, &bat, &format!("{model}.grad"));

            // train_step × every ruleset, lanes at different t / lr and
            // non-zero moments so per-lane bias corrections matter
            for ruleset in RULESETS {
                let art = artifact(&format!("{model}.train.{ruleset}")).unwrap();
                let exe = backend.compile(&art).unwrap();
                let man = art.manifest.clone();
                let v_shapes = man.v_shapes.clone().unwrap();
                let jobs: Vec<Vec<Literal>> = (0..3)
                    .map(|jj| {
                        let mut rng = Rng::new(300 + jj as u64);
                        let mut inputs: Vec<Literal> = Vec::new();
                        for p in &man.params {
                            inputs.push(
                                tensor_to_literal(
                                    &p.init_mitchell.materialize(&p.shape, &mut rng),
                                )
                                .unwrap(),
                            );
                        }
                        for p in &man.params {
                            inputs.push(
                                tensor_to_literal(&Tensor::full(
                                    &p.shape,
                                    0.01 * (jj + 1) as f32,
                                ))
                                .unwrap(),
                            );
                        }
                        for vs in &v_shapes {
                            inputs.push(
                                tensor_to_literal(&Tensor::full(vs, 0.002 * (jj + 1) as f32))
                                    .unwrap(),
                            );
                        }
                        let (x, y) = batch(&dims, 400 + jj as u64);
                        inputs.push(
                            crate::runtime::literal::i32_literal(&x, &[dims.batch, dims.ctx])
                                .unwrap(),
                        );
                        inputs.push(
                            crate::runtime::literal::i32_literal(&y, &[dims.batch, dims.ctx])
                                .unwrap(),
                        );
                        inputs.push(scalar_f32((jj + 1) as f32));
                        inputs.push(scalar_f32(1e-3 * (jj + 1) as f32));
                        inputs
                    })
                    .collect();
                let seq: Vec<Vec<Literal>> =
                    jobs.iter().map(|j| exe.run(j).unwrap()).collect();
                let bat = exe.run_batch(&jobs).unwrap();
                assert_jobs_eq(&seq, &bat, &format!("{model}.train.{ruleset}"));
            }
        }
    }

    #[test]
    fn run_batch_single_job_delegates_to_run() {
        let backend = NativeBackend::default();
        let art = artifact("mlp_tiny.grad").unwrap();
        let exe = backend.compile(&art).unwrap();
        let man = art.manifest.clone();
        let dims = dims_for("mlp_tiny").unwrap();
        let params = init_params(&man, 9);
        let (x, y) = batch(&dims, 10);
        let mut inputs: Vec<Literal> = params
            .iter()
            .map(|t| tensor_to_literal(t).unwrap())
            .collect();
        inputs.push(crate::runtime::literal::i32_literal(&x, &[dims.batch, dims.ctx]).unwrap());
        inputs.push(crate::runtime::literal::i32_literal(&y, &[dims.batch, dims.ctx]).unwrap());
        let seq = exe.run(&inputs).unwrap();
        let bat = exe.run_batch(std::slice::from_ref(&inputs)).unwrap();
        assert_eq!(bat.len(), 1);
        let loss_a = crate::runtime::literal::scalar_value(&seq[0]).unwrap();
        let loss_b = crate::runtime::literal::scalar_value(&bat[0][0]).unwrap();
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
    }

    #[test]
    fn hlo_artifacts_rejected() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("linear2_v64.grad.hlo.txt").exists() {
            return;
        }
        let art = Artifact::load(dir, "linear2_v64.grad").unwrap();
        let err = NativeBackend::default().compile(&art).unwrap_err();
        assert!(format!("{err}").contains("builtin"), "{err}");
    }
}
