//! Native backend: a pure-Rust interpreter of the manifest's model family
//! (DESIGN.md §11, §13).
//!
//! Where the PJRT backend compiles AOT-lowered HLO text, the native
//! backend *is* the computation: it ships a model zoo ([`MODELS`]) — a
//! per-token MLP language model, one- and N-block causal transformers,
//! and a small convolutional image classifier — with handwritten
//! forward/backward passes, and interprets `grad_step` / `train_step`
//! manifests directly. That makes `slimadam train/sweep --backend native`
//! a real training run (actual losses, actual gradients, actual reduced-V
//! Adam updates) that needs no artifacts, no Python, and no PJRT — the
//! substrate for offline CI end-to-end coverage, including the paper's
//! architecture-diversity figures (fig3 depth on `gpt_deep`, fig5 conv
//! SNR on `conv_mini`, fig6 attention trends).
//!
//! Contracts kept identical to the PJRT path:
//!
//! * manifests are generated, then round-tripped through
//!   [`Manifest::parse`] + `validate`, so both backends agree on the
//!   input/output layout and the manifest hash keys the executable cache;
//! * `train_step` applies global-norm clipping then the Eq. 2 reduced-V
//!   AdamW update with the manifest's baked `k_modes` — split
//!   (grad + `optim::adamk::AdamK`) and fused native runs of the same
//!   config produce matching trajectories
//!   (`rust/tests/engine_agreement.rs`);
//! * forward/backward accumulate in the compute precision ([`Precision`],
//!   f64 by default, opt-in f32 via `--precision f32`) and emit f32, so
//!   results are a deterministic pure function of the inputs and the
//!   `(lanes, workers, precision)` triple on every host.
//!
//! There is exactly one implementation of every forward/backward pass:
//! the lane-stacked kernels of DESIGN.md §12. A sequential `run` is the
//! lanes = 1 instantiation of the same kernels, so batched-vs-sequential
//! bit-identity is structural rather than a property of two parallel
//! implementations staying in sync (`rust/tests/batched_agreement.rs`
//! still proves it end to end for every model × ruleset).
//!
//! # SIMD lane contract (DESIGN.md §14)
//!
//! The hot kernels run width-4 unrolled tree reductions
//! ([`KernelMode::Simd`]) whose floating-point operation sequence per
//! lane is a function of the *logical shape only* — never the lane
//! count, the intra-op worker count, or the position of a lane in a
//! batch. That keeps `run` ≡ `run_batch` bit-identity structural while
//! allowing reductions to reassociate relative to the scalar reference
//! ([`KernelMode::ScalarRef`], the pre-SIMD bodies, kept as the
//! equivalence oracle for `rust/tests/kernel_equivalence.rs`):
//!
//! * **bit-exact in both modes**: transpose matvec, outer-product
//!   accumulation, every elementwise loop, conv loops, the fused AdamW
//!   update and its reduced-V group sums (scalar `j` order);
//! * **tolerance-bound** (reassociated 4-way trees): matvec rows,
//!   attention score/backward dots, softmax normalizers, RMS-norm
//!   sum-of-squares, and the global-norm-clip squared sum — bounded by
//!   `|Δ| ≤ n·ε·Σ|terms|` and enforced property-style by the harness;
//! * max-reductions are exact under any association and carry no bound.
//!
//! Intra-op parallelism (global-norm clip chunk sums, per-tensor fused
//! updates) uses `pool::parallel_indexed` / `pool::parallel_chunks`:
//! workers fill an index-addressed table that is folded in index order,
//! so results are bitwise invariant in the worker count.

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use crate::runtime::engine::{Artifact, ArtifactSource};
use crate::runtime::literal::{literal_to_tensor, scalar_f32, tensor_to_literal};
use crate::runtime::manifest::{Hypers, KMode, Manifest};
use crate::tensor::Tensor;

use super::{Backend, DeviceTag, Executable, Precision};

/// Builtin models the native interpreter knows.
///
/// ```
/// use slimadam::runtime::backend::native;
///
/// // every zoo member resolves a grad artifact offline
/// for model in native::MODELS {
///     let art = native::artifact(&format!("{model}.grad")).unwrap();
///     assert_eq!(art.manifest.kind, "grad_step");
/// }
/// ```
pub const MODELS: &[&str] = &["mlp_tiny", "gpt_micro", "gpt_deep", "conv_mini"];

/// Fused rulesets the native interpreter can bake into `train_step`
/// manifests (K modes per tensor).
pub const RULESETS: &[&str] = &["adam", "slimadam", "adalayer"];

/// Non-AdamW fused update rules the native interpreter can bake into
/// `train_step` manifests — the optimizer bake-off. Each token selects a
/// dedicated lane kernel with its own stored-state layout (see the
/// `fused_optim_update_l` dispatcher); `lowrank_v<r>` tokens with an
/// explicit rank (e.g. `lowrank_v8`) are accepted too.
pub const OPTIMIZERS: &[&str] = &["lion", "sgdm", "sm3", "adafactor", "lowrank_v"];

const RMS_EPS: f64 = 1e-5;

/// Conv-family kernel side (`valid` convolutions) and pooling window.
const CONV_K: usize = 3;
const POOL: usize = 2;

// ---------------------------------------------------------------------------
// Model catalog + manifest generation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    Mlp,
    Gpt,
    Conv,
}

/// Architecture hyperparameters of one builtin model. Field meaning is
/// per family: `vocab` is the vocabulary (LM families) or class count
/// (vision); `d`/`hidden` are d_model / MLP width for the LM families and
/// the first / second conv channel counts for the conv family; `ctx` is
/// the sequence length (LM only); `blocks` the transformer depth (gpt
/// only); `img`/`channels` the input geometry (conv only).
#[derive(Debug, Clone, Copy)]
struct Dims {
    family: Family,
    vocab: usize,
    d: usize,
    hidden: usize,
    heads: usize,
    ctx: usize,
    batch: usize,
    blocks: usize,
    img: usize,
    channels: usize,
}

fn dims_for(model: &str) -> Result<Dims> {
    let base = Dims {
        family: Family::Mlp,
        vocab: 64,
        d: 16,
        hidden: 32,
        heads: 1,
        ctx: 8,
        batch: 8,
        blocks: 0,
        img: 0,
        channels: 0,
    };
    Ok(match model {
        "mlp_tiny" => base,
        "gpt_micro" => Dims {
            family: Family::Gpt,
            hidden: 64,
            heads: 2,
            batch: 4,
            blocks: 1,
            ..base
        },
        "gpt_deep" => Dims {
            family: Family::Gpt,
            heads: 2,
            batch: 2,
            blocks: 4,
            ..base
        },
        "conv_mini" => Dims {
            family: Family::Conv,
            vocab: 10, // classes
            d: 8,      // conv1 out-channels
            hidden: 16, // conv2 out-channels
            ctx: 0,
            img: 8,
            channels: 2,
            ..base
        },
        other => bail!(
            "unknown native model {other:?} — builtin models: {}",
            MODELS.join(", ")
        ),
    })
}

/// Conv-family activation geometry: `(conv1 out side, pooled side,
/// conv2 out side)` for `valid` 3×3 convolutions around a 2×2 average
/// pool. For `conv_mini` (8×8 input): 6 → 3 → 1.
fn conv_geom(dims: &Dims) -> (usize, usize, usize) {
    let o1 = dims.img - CONV_K + 1;
    let pooled = o1 / POOL;
    let o2 = pooled - CONV_K + 1;
    (o1, pooled, o2)
}

/// `(name, shape, layer_type, depth, wd)` rows, in manifest parameter
/// order. GPT rows carry per-block `h<i>.` prefixes so fig3's depth axis
/// is real; conv weights are stored OIHW (`fan_out_axis` 0), so the
/// matrix view is `(C_out, C_in·kh·kw)` and `fan_in` compression averages
/// over `(C_in, kh, kw)`.
fn param_rows(dims: &Dims) -> Vec<(String, Vec<usize>, &'static str, i64, bool)> {
    let (v, d, h) = (dims.vocab, dims.d, dims.hidden);
    match dims.family {
        Family::Mlp => vec![
            ("tok_embd".into(), vec![v, d], "tok_embd", -1, true),
            ("mlp_up".into(), vec![h, d], "mlp_up", 0, true),
            ("mlp_down".into(), vec![d, h], "mlp_down", 0, true),
            ("lm_head".into(), vec![v, d], "lm_head", 1, true),
        ],
        Family::Gpt => {
            let mut rows: Vec<(String, Vec<usize>, &'static str, i64, bool)> = vec![
                ("tok_embd".into(), vec![v, d], "tok_embd", -1, true),
                ("pos_embd".into(), vec![dims.ctx, d], "pos_embd", -1, false),
            ];
            for b in 0..dims.blocks {
                let i = b as i64;
                rows.push((format!("h{b}.ln_attn"), vec![d], "ln_attn", i, false));
                rows.push((format!("h{b}.attn_q"), vec![d, d], "attn_q", i, true));
                rows.push((format!("h{b}.attn_k"), vec![d, d], "attn_k", i, true));
                rows.push((format!("h{b}.attn_v"), vec![d, d], "attn_v", i, true));
                rows.push((format!("h{b}.attn_proj"), vec![d, d], "attn_proj", i, true));
                rows.push((format!("h{b}.ln_mlp"), vec![d], "ln_mlp", i, false));
                rows.push((format!("h{b}.mlp_up"), vec![h, d], "mlp_up", i, true));
                rows.push((format!("h{b}.mlp_down"), vec![d, h], "mlp_down", i, true));
            }
            let top = dims.blocks as i64;
            rows.push(("ln_final".into(), vec![d], "ln_final", top, false));
            rows.push(("lm_head".into(), vec![v, d], "lm_head", top, true));
            rows
        }
        Family::Conv => {
            let (_, _, o2) = conv_geom(dims);
            vec![
                (
                    "conv1".into(),
                    vec![d, dims.channels, CONV_K, CONV_K],
                    "conv",
                    0,
                    true,
                ),
                ("conv2".into(), vec![h, d, CONV_K, CONV_K], "conv", 1, true),
                ("head".into(), vec![v, o2 * o2 * h], "head", 2, true),
            ]
        }
    }
}

fn init_json(shape: &[usize], layer_type: &str, mitchell: bool) -> crate::json::Value {
    let mut v = crate::json::Value::obj();
    if shape.len() <= 1 {
        // norm gains start at one, everything vector-like else at zero
        if layer_type.starts_with("ln") {
            v.set("scheme", "ones");
        } else {
            v.set("scheme", "zeros");
        }
    } else if mitchell {
        v.set("scheme", "normal").set("std", 0.02);
    } else {
        // PyTorch-default-flavored: uniform ±1/sqrt(fan_in)
        let fan_in = shape[1..].iter().product::<usize>().max(1);
        v.set("scheme", "uniform")
            .set("limit", 1.0 / (fan_in as f64).sqrt());
    }
    v
}

fn manifest_json(
    model: &str,
    dims: &Dims,
    kind: &str,
    ruleset: Option<&str>,
) -> crate::json::Value {
    use crate::json::Value;
    let mut root = Value::obj();
    root.set("kind", kind);

    let mut meta = Value::obj();
    match dims.family {
        Family::Mlp | Family::Gpt => {
            meta.set("name", model)
                .set(
                    "family",
                    if dims.family == Family::Mlp { "mlp" } else { "gpt" },
                )
                .set("vocab", dims.vocab)
                .set("d_model", dims.d)
                .set("hidden", dims.hidden)
                .set("n_heads", dims.heads)
                .set("ctx", dims.ctx)
                .set("batch", dims.batch)
                .set("native", true);
            if dims.family == Family::Gpt {
                meta.set("n_blocks", dims.blocks);
            }
        }
        Family::Conv => {
            meta.set("name", model)
                .set("family", "conv")
                .set("classes", dims.vocab)
                .set("img", dims.img)
                .set("channels", dims.channels)
                .set("c1", dims.d)
                .set("c2", dims.hidden)
                .set("batch", dims.batch)
                .set("native", true);
        }
    }
    root.set("model", meta);

    let rows = param_rows(dims);
    let mut params = Vec::new();
    for (name, shape, lt, depth, wd) in &rows {
        let mut p = Value::obj();
        p.set("name", name.clone())
            .set("shape", shape.clone())
            .set("layer_type", *lt)
            .set("depth", *depth)
            .set("init_mitchell", init_json(shape, lt, true))
            .set("init_default", init_json(shape, lt, false))
            .set("wd", *wd)
            .set("fan_out_axis", 0usize);
        params.push(p);
    }
    root.set("params", params);

    let mut batch = Vec::new();
    match dims.family {
        Family::Conv => {
            let mut x = Value::obj();
            x.set("name", "x")
                .set(
                    "shape",
                    vec![dims.batch, dims.img, dims.img, dims.channels],
                )
                .set("dtype", "f32");
            batch.push(x);
            let mut y = Value::obj();
            y.set("name", "y")
                .set("shape", vec![dims.batch])
                .set("dtype", "s32");
            batch.push(y);
        }
        _ => {
            for name in ["x", "y"] {
                let mut b = Value::obj();
                b.set("name", name)
                    .set("shape", vec![dims.batch, dims.ctx])
                    .set("dtype", "s32");
                batch.push(b);
            }
        }
    }
    root.set("batch", batch);

    let mut hypers = Value::obj();
    let h = Hypers::default();
    hypers
        .set("beta1", h.beta1)
        .set("beta2", h.beta2)
        .set("eps", h.eps)
        .set("weight_decay", h.weight_decay)
        .set("clip_norm", h.clip_norm);
    root.set("hypers", hypers);

    let param_names: Vec<&str> = rows.iter().map(|r| r.0.as_str()).collect();
    match kind {
        "grad_step" => {
            let mut inputs: Vec<String> =
                param_names.iter().map(|n| format!("param:{n}")).collect();
            inputs.push("batch:x".into());
            inputs.push("batch:y".into());
            let mut outputs = vec!["loss".to_string()];
            outputs.extend(param_names.iter().map(|n| format!("grad:{n}")));
            root.set("inputs", inputs).set("outputs", outputs);
        }
        "train_step" => {
            let ruleset = ruleset.expect("train_step needs a ruleset");
            root.set("ruleset", ruleset);
            let mut inputs: Vec<String> = Vec::new();
            for prefix in ["param", "m", "v"] {
                inputs.extend(param_names.iter().map(|n| format!("{prefix}:{n}")));
            }
            inputs.push("batch:x".into());
            inputs.push("batch:y".into());
            inputs.push("step".into());
            inputs.push("lr".into());
            let mut outputs = vec!["loss".to_string(), "grad_norm".to_string()];
            for prefix in ["param", "m", "v"] {
                outputs.extend(param_names.iter().map(|n| format!("{prefix}:{n}")));
            }
            root.set("inputs", inputs).set("outputs", outputs);
        }
        k => unreachable!("manifest kind {k}"),
    }
    root
}

/// Builtin `grad_step` manifest for a native model.
///
/// ```
/// use slimadam::runtime::backend::native;
///
/// let man = native::grad_manifest("gpt_deep").unwrap();
/// // 4 blocks × 8 tensors + embeddings + final norm/head
/// assert_eq!(man.n_params(), 2 + 4 * 8 + 2);
/// let max_depth = man.params.iter().map(|p| p.depth).max().unwrap();
/// assert_eq!(max_depth, 4); // fig3's depth axis is real
///
/// let conv = native::grad_manifest("conv_mini").unwrap();
/// assert_eq!(conv.params[0].shape, vec![8, 2, 3, 3]); // OIHW conv weight
/// assert_eq!(conv.token_bound(), 10); // classes
/// ```
pub fn grad_manifest(model: &str) -> Result<Manifest> {
    Ok(artifact(&format!("{model}.grad"))?.manifest)
}

/// Builtin `train_step` manifest for a native model and fused-update
/// token — a ruleset from [`RULESETS`] or an optimizer from
/// [`OPTIMIZERS`].
///
/// ```
/// use slimadam::runtime::backend::native;
///
/// let man = native::train_manifest("mlp_tiny", "lion").unwrap();
/// assert_eq!(man.optimizer_name(), "lion");
/// // Lion stores no second moment: every baked V shape is empty
/// let v: usize = man
///     .v_shapes
///     .as_ref()
///     .unwrap()
///     .iter()
///     .map(|s| s.iter().product::<usize>())
///     .sum();
/// assert_eq!(v, 0);
/// ```
pub fn train_manifest(model: &str, token: &str) -> Result<Manifest> {
    Ok(artifact(&format!("{model}.train.{token}"))?.manifest)
}

/// Does this train token select a bake-off optimizer kernel (as opposed
/// to a K-moded AdamW ruleset)?
fn is_optimizer_token(token: &str) -> bool {
    crate::optim::lowrank_v::parse_token(token).is_some() || OPTIMIZERS.contains(&token)
}

/// Per-tensor K modes baked into a fused native manifest.
fn ruleset_modes(man: &Manifest, ruleset: &str) -> Result<Vec<KMode>> {
    Ok(match ruleset {
        "adam" => vec![KMode::None; man.n_params()],
        "adalayer" => vec![KMode::Both; man.n_params()],
        "slimadam" => crate::rules::RuleSet::table3_default(man).modes_for(man),
        other => bail!(
            "unknown native ruleset {other:?} — builtin rulesets: {}; \
             optimizer tokens: {}",
            RULESETS.join(", "),
            OPTIMIZERS.join(", ")
        ),
    })
}

/// Stored-V shape for a parameter under mode `k` (in matrix-view coords;
/// the fused engine round-trips these literals without inspecting them).
fn v_shape(info: &crate::runtime::manifest::ParamInfo, k: KMode) -> Vec<usize> {
    let (rows, cols) = info.matrix_dims();
    match crate::optim::adamk::effective_k(info, k) {
        KMode::None => info.shape.clone(),
        KMode::FanIn => vec![rows, 1],
        KMode::FanOut => vec![1, cols],
        KMode::Both => vec![1],
        KMode::Blocks(n) => vec![n],
    }
}

/// Bake a bake-off optimizer's state layout into a train manifest: the
/// `optimizer` field, all-`none` K modes (these rules don't use Eq. 2
/// sharing), each rule's own stored-V layout in `v_shapes`, and
/// `m_shapes` when the first moment is not one full tensor per
/// parameter. Stored layouts, matching the lane kernels:
///
/// * `lion` / `sgdm` — no V at all (`[0]` per tensor), full momentum;
/// * `sm3` — matrices store row+col cover accumulators stacked
///   `[rows..][cols..]`, vectors stay exact; full momentum;
/// * `adafactor` — matrices store factored row+col EMAs stacked
///   `[rows..][cols..]`, vectors stay exact; no momentum (v1);
/// * `lowrank_v<r>` — matrices store the rank-r sketch `Y (rows×r)`
///   row-major followed by `C (cols)`, vectors stay exact; full
///   momentum.
fn bake_optimizer_shapes(
    root: &mut crate::json::Value,
    base: &Manifest,
    token: &str,
) -> Result<()> {
    // The kernels address matrix-view element (ri, ci) as raw index
    // ri*cols+ci; that identity needs fan_out_axis 0, which every native
    // builtin parameter has.
    anyhow::ensure!(
        base.params.iter().all(|p| p.fan_out_axis == 0),
        "native optimizer kernels require fan_out_axis 0"
    );
    let rank = crate::optim::lowrank_v::parse_token(token);
    let v_shapes: Vec<crate::json::Value> = base
        .params
        .iter()
        .map(|p| {
            let (rows, cols) = p.matrix_dims();
            let shape: Vec<usize> = match (token, rank) {
                ("lion" | "sgdm", _) => vec![0],
                ("sm3" | "adafactor", _) if p.is_vector() => p.shape.clone(),
                ("sm3" | "adafactor", _) => vec![rows + cols],
                (_, Some(_)) if p.is_vector() => p.shape.clone(),
                (_, Some(r)) => vec![rows * r + cols],
                other => unreachable!("unvetted optimizer token {other:?}"),
            };
            crate::json::Value::from(shape)
        })
        .collect();
    root.set("optimizer", token);
    root.set("k_modes", vec!["none".to_string(); base.n_params()]);
    root.set("v_shapes", crate::json::Value::Arr(v_shapes));
    if token == "adafactor" {
        let m_shapes: Vec<crate::json::Value> = (0..base.n_params())
            .map(|_| crate::json::Value::from(vec![0usize]))
            .collect();
        root.set("m_shapes", crate::json::Value::Arr(m_shapes));
    }
    Ok(())
}

thread_local! {
    /// Builtin artifacts are a pure function of their name, so generation
    /// (JSON build + parse + validate) runs once per thread per name —
    /// the dispatch hot path (`exec_cache` recomputes the cache key per
    /// job) then pays only a manifest clone.
    static ARTIFACTS: std::cell::RefCell<std::collections::HashMap<String, Artifact>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Resolve a native artifact by name: `<model>.grad` or
/// `<model>.train.<ruleset>`. The manifest is generated, serialized, and
/// re-parsed through [`Manifest::parse`] so native and PJRT artifacts
/// share one manifest contract (and the hash that keys the executable
/// cache digests the same bytes a file would hold).
///
/// ```
/// use slimadam::runtime::backend::native;
///
/// let art = native::artifact("conv_mini.train.slimadam").unwrap();
/// assert_eq!(art.manifest.kind, "train_step");
/// // conv weights compress fan_in over (C_in, kh, kw): one V per filter
/// let v: usize = art.manifest.v_shapes.as_ref().unwrap()[0].iter().product();
/// assert_eq!(v, 8);
/// assert!(native::artifact("conv_mini.nonsense").is_err());
/// ```
pub fn artifact(name: &str) -> Result<Artifact> {
    ARTIFACTS.with(|cache| {
        if let Some(art) = cache.borrow().get(name) {
            return Ok(art.clone());
        }
        let art = generate_artifact(name)?;
        cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    })
}

fn generate_artifact(name: &str) -> Result<Artifact> {
    let (model, kind, ruleset) = match name.split_once('.') {
        Some((model, "grad")) => (model, "grad_step", None),
        Some((model, rest)) => match rest.split_once('.') {
            Some(("train", ruleset)) => (model, "train_step", Some(ruleset)),
            _ => bail!("bad native artifact name {name:?}"),
        },
        None => bail!("bad native artifact name {name:?}"),
    };
    let dims = dims_for(model)?;
    let mut root = manifest_json(model, &dims, kind, ruleset);

    if kind == "train_step" {
        // k_modes/v_shapes need a parsed manifest for ParamInfo geometry;
        // bootstrap from the grad-shaped params.
        let base = Manifest::parse(&root.dump()).map_err(|e| {
            anyhow!("internal: native train manifest bootstrap failed: {e}")
        })?;
        if is_optimizer_token(ruleset.unwrap()) {
            bake_optimizer_shapes(&mut root, &base, ruleset.unwrap())?;
            return finish_artifact(name, root);
        }
        let modes = ruleset_modes(&base, ruleset.unwrap())?;
        // Manifest k_modes strings can carry none/fan_in/fan_out/both only
        // (KMode::parse has no "blocksN" spelling) — refuse early rather
        // than generate a manifest that cannot re-parse.
        anyhow::ensure!(
            !modes.iter().any(|k| matches!(k, KMode::Blocks(_))),
            "native rulesets cannot bake block-partitioned K modes into a \
             manifest"
        );
        let k_modes: Vec<String> = base
            .params
            .iter()
            .zip(&modes)
            .map(|(p, &k)| crate::optim::adamk::effective_k(p, k).as_str())
            .collect();
        let v_shapes: Vec<crate::json::Value> = base
            .params
            .iter()
            .zip(&modes)
            .map(|(p, &k)| crate::json::Value::from(v_shape(p, k)))
            .collect();
        root.set("k_modes", k_modes);
        root.set("v_shapes", crate::json::Value::Arr(v_shapes));
    }

    finish_artifact(name, root)
}

/// Serialize, re-parse and validate a generated manifest, producing the
/// builtin [`Artifact`] whose hash digests the same bytes a file would
/// hold.
fn finish_artifact(name: &str, root: crate::json::Value) -> Result<Artifact> {
    let text = root.dump();
    let manifest = Manifest::parse(&text)
        .with_context(|| format!("parsing generated native manifest {name:?}"))?;
    manifest
        .validate()
        .with_context(|| format!("validating generated native manifest {name:?}"))?;
    Ok(Artifact {
        name: name.to_string(),
        manifest,
        source: ArtifactSource::Builtin,
        manifest_hash: crate::rng::stable_hash64(text.as_bytes()),
    })
}

// ---------------------------------------------------------------------------
// Compute element type + kernel mode
// ---------------------------------------------------------------------------

/// Scalar element type the interpreter computes in: `f64` (the verify
/// reference) or `f32` (the opt-in fast mode, `--precision f32`).
///
/// The trait surface is exactly what the lane kernels need; method names
/// mirror the `f64` inherent methods so generic bodies read like the
/// scalar originals. `maxr` is `f64::max` (NaN-ignoring), renamed so the
/// trait method cannot shadow-collide with the inherent one.
pub trait Real:
    Copy
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + 'static
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::ops::DivAssign
{
    /// Additive identity.
    const ZERO: Self;
    /// `-∞`, the max-reduction seed.
    const NEG_INF: Self;
    /// Smallest positive normal value (log-loss clamp).
    const MIN_POS: Self;
    /// Lossy conversion from f64.
    fn from_f64(x: f64) -> Self;
    /// Widening (f64) or identity conversion.
    fn to_f64(self) -> f64;
    /// Conversion from the f32 storage boundary.
    fn from_f32(x: f32) -> Self;
    /// Conversion to the f32 storage boundary.
    fn to_f32(self) -> f32;
    /// Natural exponential.
    fn exp(self) -> Self;
    /// Natural logarithm.
    fn ln(self) -> Self;
    /// Square root.
    fn sqrt(self) -> Self;
    /// NaN-ignoring maximum (`f64::max` semantics).
    fn maxr(self, other: Self) -> Self;
}

impl Real for f64 {
    const ZERO: Self = 0.0;
    const NEG_INF: Self = f64::NEG_INFINITY;
    const MIN_POS: Self = f64::MIN_POSITIVE;
    fn from_f64(x: f64) -> Self {
        x
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f32(x: f32) -> Self {
        x as f64
    }
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn exp(self) -> Self {
        f64::exp(self)
    }
    fn ln(self) -> Self {
        f64::ln(self)
    }
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    fn maxr(self, other: Self) -> Self {
        f64::max(self, other)
    }
}

impl Real for f32 {
    const ZERO: Self = 0.0;
    const NEG_INF: Self = f32::NEG_INFINITY;
    const MIN_POS: Self = f32::MIN_POSITIVE;
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f32(x: f32) -> Self {
        x
    }
    fn to_f32(self) -> f32 {
        self
    }
    fn exp(self) -> Self {
        f32::exp(self)
    }
    fn ln(self) -> Self {
        f32::ln(self)
    }
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    fn maxr(self, other: Self) -> Self {
        f32::max(self, other)
    }
}

/// Which kernel bodies the reassociating reductions run (DESIGN.md §14).
///
/// `Simd` (the default) runs the width-4 unrolled tree reductions;
/// `ScalarRef` runs the strict scalar-iteration-order reference bodies.
/// The flag is thread-local so the `kernel_equivalence` harness and the
/// bench's before/after measurement can flip modes without racing
/// concurrently running tests. Order-preserving kernels ignore the mode
/// (one body, bit-identical by construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Width-4 tree reductions; intra-op workers enabled.
    Simd,
    /// Pre-SIMD scalar-order reference; single-threaded.
    ScalarRef,
}

thread_local! {
    static KERNEL_MODE: std::cell::Cell<KernelMode> =
        const { std::cell::Cell::new(KernelMode::Simd) };
}

/// This thread's kernel mode (default [`KernelMode::Simd`]).
pub fn kernel_mode() -> KernelMode {
    KERNEL_MODE.with(|m| m.get())
}

/// Set this thread's kernel mode. Worker threads spawned by the pool
/// always start in `Simd`; the reference mode is a test/bench
/// instrument, not a run-time configuration.
pub fn set_kernel_mode(mode: KernelMode) {
    KERNEL_MODE.with(|m| m.set(mode));
}

// ---------------------------------------------------------------------------
// Backend + executable
// ---------------------------------------------------------------------------

/// The pure-Rust execution path. Stateless; `compile` binds a builtin
/// model's interpreter to the artifact's manifest (and this backend's
/// compute precision).
pub struct NativeBackend {
    device: DeviceTag,
    precision: Precision,
}

impl NativeBackend {
    pub fn new(device: DeviceTag) -> NativeBackend {
        NativeBackend {
            device,
            precision: Precision::F64,
        }
    }

    /// A backend computing in `precision` (`--precision f32` plumbs
    /// through here; f64 stays the verify reference).
    pub fn with_precision(device: DeviceTag, precision: Precision) -> NativeBackend {
        NativeBackend { device, precision }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new(DeviceTag::Cpu(0))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn device(&self) -> DeviceTag {
        self.device
    }

    fn load_artifact(&self, _dir: &std::path::Path, name: &str) -> Result<Artifact> {
        artifact(name)
    }

    fn compile(&self, art: &Artifact) -> Result<Box<dyn Executable>> {
        anyhow::ensure!(
            art.source == ArtifactSource::Builtin,
            "native backend interprets builtin models only ({}), got HLO \
             artifact {:?} — use the pjrt backend for `make artifacts` output",
            MODELS.join(", "),
            art.name
        );
        let dims = dims_for(&art.manifest.model_name)?;
        // Guard against manifests that drifted from the interpreter.
        let rows = param_rows(&dims);
        anyhow::ensure!(
            art.manifest.n_params() == rows.len()
                && art
                    .manifest
                    .params
                    .iter()
                    .zip(&rows)
                    .all(|(p, (n, shape, ..))| p.name == *n && &p.shape == shape),
            "native manifest for {:?} does not match the interpreter's \
             parameter layout",
            art.manifest.model_name
        );
        Ok(Box::new(NativeExecutable {
            manifest: art.manifest.clone(),
            dims,
            precision: self.precision,
        }))
    }
}

/// One compiled native step function.
struct NativeExecutable {
    manifest: Manifest,
    dims: Dims,
    precision: Precision,
}

/// One job's decoded batch inputs, per model family.
enum BatchIn {
    /// LM families: `batch × ctx` next-token pairs.
    Tokens { x: Vec<i32>, y: Vec<i32> },
    /// Conv family: NHWC f32 images plus one class label per sample.
    Images { x: Vec<f32>, y: Vec<i32> },
}

impl NativeExecutable {
    fn batch_tokens(&self, lit: &Literal, what: &str) -> Result<Vec<i32>> {
        let toks = lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("reading {what} batch: {e}"))?;
        anyhow::ensure!(
            toks.len() == self.dims.batch * self.dims.ctx,
            "{what} batch has {} tokens, want {}",
            toks.len(),
            self.dims.batch * self.dims.ctx
        );
        let bound = self.dims.vocab as i32;
        anyhow::ensure!(
            toks.iter().all(|&t| (0..bound).contains(&t)),
            "{what} batch token out of range [0, {bound})"
        );
        Ok(toks)
    }

    /// Decode one job's `(x, y)` batch literals for this model's family.
    fn read_batch(&self, x: &Literal, y: &Literal) -> Result<BatchIn> {
        match self.dims.family {
            Family::Conv => {
                let d = &self.dims;
                let imgs = x
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading image batch: {e}"))?;
                let want = d.batch * d.img * d.img * d.channels;
                anyhow::ensure!(
                    imgs.len() == want,
                    "image batch has {} elements, want {want}",
                    imgs.len()
                );
                let labels = y
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("reading label batch: {e}"))?;
                anyhow::ensure!(
                    labels.len() == d.batch,
                    "label batch has {} entries, want {}",
                    labels.len(),
                    d.batch
                );
                let bound = d.vocab as i32;
                anyhow::ensure!(
                    labels.iter().all(|&c| (0..bound).contains(&c)),
                    "label out of range [0, {bound})"
                );
                Ok(BatchIn::Images { x: imgs, y: labels })
            }
            _ => Ok(BatchIn::Tokens {
                x: self.batch_tokens(x, "x")?,
                y: self.batch_tokens(y, "y")?,
            }),
        }
    }

    fn run_grad<E: Real>(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let n = self.manifest.n_params();
        // f32 at the boundary, E internally (lanes = 1)
        let mut params_l: Vec<Vec<E>> = Vec::with_capacity(n);
        for lit in &inputs[..n] {
            let t = literal_to_tensor(lit)?;
            params_l.push(t.data.iter().map(|&x| E::from_f32(x)).collect());
        }
        let batch = self.read_batch(&inputs[n], &inputs[n + 1])?;
        let (losses, grads_l) =
            loss_and_grads_l::<E>(&self.dims, &params_l, std::slice::from_ref(&batch), 1);
        let mut out = Vec::with_capacity(1 + n);
        out.push(scalar_f32(losses[0] as f32));
        for (i, g) in grads_l.iter().enumerate() {
            let data: Vec<f32> = g.iter().map(|&x| x.to_f32()).collect();
            out.push(tensor_to_literal(&Tensor::from_vec(
                &self.manifest.params[i].shape,
                data,
            ))?);
        }
        Ok(out)
    }

    fn run_train<E: Real>(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let man = &self.manifest;
        let n = man.n_params();
        let hypers = man.hypers.unwrap_or_default();
        let k_modes = man
            .k_modes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing k_modes"))?;
        let v_shapes = man
            .v_shapes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing v_shapes"))?;

        let read = |lit: &Literal, len: usize, what: &str| -> Result<Vec<f32>> {
            let vals = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("reading {what}: {e}"))?;
            anyhow::ensure!(
                vals.len() == len,
                "{what} has {} elements, want {len}",
                vals.len()
            );
            Ok(vals)
        };
        let mut w_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut m_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut v_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            w_l.push(read(&inputs[i], man.params[i].numel(), "param")?);
        }
        for i in 0..n {
            m_l.push(read(&inputs[n + i], man.m_shape(i).iter().product(), "m")?);
        }
        // Second moments accept either the baked reduced length or the
        // full parameter length (an adaptive decompression — DESIGN.md
        // §18); the effective K per tensor follows from the stored length.
        let mut eff_modes: Vec<KMode> = Vec::with_capacity(n);
        let mut v_out_shapes: Vec<&[usize]> = Vec::with_capacity(n);
        for (i, vs) in v_shapes.iter().enumerate() {
            let vals = inputs[2 * n + i]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("reading v: {e}"))?;
            let (k, shape) = effective_v_mode(man, k_modes, vs, i, vals.len())?;
            eff_modes.push(k);
            v_out_shapes.push(shape);
            v_l.push(vals);
        }
        let batch = self.read_batch(&inputs[3 * n], &inputs[3 * n + 1])?;
        let step = crate::runtime::literal::scalar_value(&inputs[3 * n + 2])?;
        let lr = crate::runtime::literal::scalar_value(&inputs[3 * n + 3])?;
        let t = step.round().max(1.0) as usize;

        // The sequential step IS the lanes = 1 batched step: the same
        // kernels, the same iteration order, one lane.
        let params_e: Vec<Vec<E>> = w_l
            .iter()
            .map(|s| s.iter().map(|&x| E::from_f32(x)).collect())
            .collect();
        let (losses, grads_e) = loss_and_grads_l::<E>(
            &self.dims,
            &params_e,
            std::slice::from_ref(&batch),
            1,
        );
        let mut grads_l: Vec<Vec<f32>> = grads_e
            .iter()
            .map(|g| g.iter().map(|&x| x.to_f32()).collect())
            .collect();
        let norms = clip_global_norm_l(&mut grads_l, hypers.clip_norm, 1);
        fused_optim_update_l(
            man, &eff_modes, &hypers, &mut w_l, &mut m_l, &mut v_l, &grads_l, &[t],
            &[lr], 1,
        )?;

        let mut out = Vec::with_capacity(2 + 3 * n);
        out.push(scalar_f32(losses[0] as f32));
        out.push(scalar_f32(norms[0] as f32));
        for (i, s) in w_l.into_iter().enumerate() {
            out.push(tensor_to_literal(&Tensor::from_vec(&man.params[i].shape, s))?);
        }
        for (i, s) in m_l.into_iter().enumerate() {
            out.push(tensor_to_literal(&Tensor::from_vec(man.m_shape(i), s))?);
        }
        for (i, s) in v_l.into_iter().enumerate() {
            out.push(tensor_to_literal(&Tensor::from_vec(v_out_shapes[i], s))?);
        }
        Ok(out)
    }

    /// Read input slot `slot` of every job as f32 and stack lane-major:
    /// element `j` of job `b` lands at `j * lanes + b`.
    fn stack_slot(
        &self,
        jobs: &[Vec<Literal>],
        slot: usize,
        len: usize,
        what: &str,
    ) -> Result<Vec<f32>> {
        let lanes = jobs.len();
        let mut stacked = vec![0.0f32; len * lanes];
        for (b, job) in jobs.iter().enumerate() {
            let vals = job[slot]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("job {b} {what}: {e}"))?;
            anyhow::ensure!(
                vals.len() == len,
                "job {b} {what} has {} elements, want {len}",
                vals.len()
            );
            for (j, &x) in vals.iter().enumerate() {
                stacked[j * lanes + b] = x;
            }
        }
        Ok(stacked)
    }

    /// Batched `grad_step`: one lane-stacked forward/backward pass for
    /// all jobs, per-job `(loss, grads...)` outputs.
    fn run_grad_batch<E: Real>(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        let lanes = jobs.len();
        let man = &self.manifest;
        let n = man.n_params();
        // f32 → E exactly as the scalar path (f32 boundary, E internal)
        let mut params_l: Vec<Vec<E>> = Vec::with_capacity(n);
        for i in 0..n {
            let stacked = self.stack_slot(jobs, i, man.params[i].numel(), "param")?;
            params_l.push(stacked.iter().map(|&x| E::from_f32(x)).collect());
        }
        let mut batches = Vec::with_capacity(lanes);
        for job in jobs {
            batches.push(self.read_batch(&job[n], &job[n + 1])?);
        }
        let (losses, grads_l) =
            loss_and_grads_l::<E>(&self.dims, &params_l, &batches, lanes);
        let mut out = Vec::with_capacity(lanes);
        for b in 0..lanes {
            let mut job_out = Vec::with_capacity(1 + n);
            job_out.push(scalar_f32(losses[b] as f32));
            for (i, g) in grads_l.iter().enumerate() {
                let data: Vec<f32> =
                    g[b..].iter().step_by(lanes).map(|&x| x.to_f32()).collect();
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &man.params[i].shape,
                    data,
                ))?);
            }
            out.push(job_out);
        }
        Ok(out)
    }

    /// Batched `train_step`: lane-stacked forward/backward, per-lane
    /// global-norm clip and per-lane fused reduced-V AdamW update (each
    /// lane carries its own step index and learning rate).
    fn run_train_batch<E: Real>(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        let lanes = jobs.len();
        let man = &self.manifest;
        let n = man.n_params();
        let hypers = man.hypers.unwrap_or_default();
        let k_modes = man
            .k_modes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing k_modes"))?;
        let v_shapes = man
            .v_shapes
            .as_ref()
            .ok_or_else(|| anyhow!("native train_step manifest missing v_shapes"))?;

        let mut w_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut m_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut v_l: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            w_l.push(self.stack_slot(jobs, i, man.params[i].numel(), "param")?);
        }
        for i in 0..n {
            let m_len = man.m_shape(i).iter().product();
            m_l.push(self.stack_slot(jobs, n + i, m_len, "m")?);
        }
        // As in the sequential path, the V slot accepts the baked reduced
        // length or the full parameter length; all lanes must agree (the
        // batch planner keeps adaptive configs out of mixed groups, and
        // `stack_slot` rejects any straggler lane).
        let mut eff_modes: Vec<KMode> = Vec::with_capacity(n);
        let mut v_out_shapes: Vec<&[usize]> = Vec::with_capacity(n);
        for (i, vs) in v_shapes.iter().enumerate() {
            let lane0 = jobs[0][2 * n + i]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("job 0 v: {e}"))?;
            let (k, shape) = effective_v_mode(man, k_modes, vs, i, lane0.len())?;
            eff_modes.push(k);
            v_out_shapes.push(shape);
            v_l.push(self.stack_slot(jobs, 2 * n + i, lane0.len(), "v")?);
        }
        let mut batches = Vec::with_capacity(lanes);
        let mut ts = Vec::with_capacity(lanes);
        let mut lrs = Vec::with_capacity(lanes);
        for job in jobs {
            batches.push(self.read_batch(&job[3 * n], &job[3 * n + 1])?);
            let step = crate::runtime::literal::scalar_value(&job[3 * n + 2])?;
            ts.push(step.round().max(1.0) as usize);
            lrs.push(crate::runtime::literal::scalar_value(&job[3 * n + 3])?);
        }

        let params_e: Vec<Vec<E>> = w_l
            .iter()
            .map(|s| s.iter().map(|&x| E::from_f32(x)).collect())
            .collect();
        let (losses, grads_e) =
            loss_and_grads_l::<E>(&self.dims, &params_e, &batches, lanes);
        // E → f32 cast before clipping, exactly as the scalar path
        let mut grads_l: Vec<Vec<f32>> = grads_e
            .iter()
            .map(|g| g.iter().map(|&x| x.to_f32()).collect())
            .collect();
        let norms = clip_global_norm_l(&mut grads_l, hypers.clip_norm, lanes);
        fused_optim_update_l(
            man, &eff_modes, &hypers, &mut w_l, &mut m_l, &mut v_l, &grads_l, &ts, &lrs,
            lanes,
        )?;

        let unstack = |stacked: &[f32], b: usize| -> Vec<f32> {
            stacked[b..].iter().step_by(lanes).copied().collect()
        };
        let mut out = Vec::with_capacity(lanes);
        for b in 0..lanes {
            let mut job_out = Vec::with_capacity(2 + 3 * n);
            job_out.push(scalar_f32(losses[b] as f32));
            job_out.push(scalar_f32(norms[b] as f32));
            for (i, s) in w_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    &man.params[i].shape,
                    unstack(s, b),
                ))?);
            }
            for (i, s) in m_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    man.m_shape(i),
                    unstack(s, b),
                ))?);
            }
            for (i, s) in v_l.iter().enumerate() {
                job_out.push(tensor_to_literal(&Tensor::from_vec(
                    v_out_shapes[i],
                    unstack(s, b),
                ))?);
            }
            out.push(job_out);
        }
        Ok(out)
    }
}

impl Executable for NativeExecutable {
    fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        match (self.manifest.kind.as_str(), self.precision) {
            ("grad_step", Precision::F64) => self.run_grad::<f64>(inputs),
            ("grad_step", Precision::F32) => self.run_grad::<f32>(inputs),
            ("train_step", Precision::F64) => self.run_train::<f64>(inputs),
            ("train_step", Precision::F32) => self.run_train::<f32>(inputs),
            (k, _) => bail!("native backend cannot execute manifest kind {k:?}"),
        }
    }

    /// Lane-stacked batched dispatch (DESIGN.md §12): B jobs' tensors are
    /// stacked along a trailing lane axis and one interpreter pass
    /// advances all of them. Bit-for-bit identical to sequential `run`
    /// calls — see the module's lane-kernel section for the argument.
    fn run_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        if jobs.len() <= 1 {
            return jobs.iter().map(|inputs| self.run(inputs)).collect();
        }
        for (b, job) in jobs.iter().enumerate() {
            anyhow::ensure!(
                job.len() == self.manifest.n_inputs(),
                "job {b}: expected {} inputs, got {}",
                self.manifest.n_inputs(),
                job.len()
            );
        }
        match (self.manifest.kind.as_str(), self.precision) {
            ("grad_step", Precision::F64) => self.run_grad_batch::<f64>(jobs),
            ("grad_step", Precision::F32) => self.run_grad_batch::<f32>(jobs),
            ("train_step", Precision::F64) => self.run_train_batch::<f64>(jobs),
            ("train_step", Precision::F32) => self.run_train_batch::<f32>(jobs),
            (k, _) => bail!("native backend cannot execute manifest kind {k:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Forward/backward interpreters (f64 internal, f32 at the boundary)
//
// Single implementation: the lane-stacked kernels below. The scalar entry
// point is the lanes = 1 instantiation.
// ---------------------------------------------------------------------------

/// Loss and gradients for one job, in manifest parameter order. The f64
/// loss is exposed for finite-difference tests; engines see the f32 cast.
/// Runs the lane kernels at lanes = 1 (with one lane the lane-major
/// layout is the flat layout, so this is free of any reshuffling).
fn loss_and_grads(dims: &Dims, params: &[Tensor], batch: &BatchIn) -> (f64, Vec<Tensor>) {
    let params_l: Vec<Vec<f64>> = params.iter().map(f64s).collect();
    let (losses, grads_l) =
        loss_and_grads_l::<f64>(dims, &params_l, std::slice::from_ref(batch), 1);
    let out = params
        .iter()
        .zip(&grads_l)
        .map(|(p, g)| Tensor::from_vec(&p.shape, g.iter().map(|&x| x as f32).collect()))
        .collect();
    (losses[0], out)
}

#[inline]
fn f64s(t: &Tensor) -> Vec<f64> {
    t.data.iter().map(|&x| x as f64).collect()
}

/// Per-lane token views of a token-family batch set.
fn token_lanes(batches: &[BatchIn]) -> (Vec<&[i32]>, Vec<&[i32]>) {
    let mut xs = Vec::with_capacity(batches.len());
    let mut ys = Vec::with_capacity(batches.len());
    for b in batches {
        match b {
            BatchIn::Tokens { x, y } => {
                xs.push(x.as_slice());
                ys.push(y.as_slice());
            }
            BatchIn::Images { .. } => {
                unreachable!("token-family model fed an image batch")
            }
        }
    }
    (xs, ys)
}

/// Per-lane image/label views of a conv-family batch set.
fn image_lanes(batches: &[BatchIn]) -> (Vec<&[f32]>, Vec<&[i32]>) {
    let mut xs = Vec::with_capacity(batches.len());
    let mut ys = Vec::with_capacity(batches.len());
    for b in batches {
        match b {
            BatchIn::Images { x, y } => {
                xs.push(x.as_slice());
                ys.push(y.as_slice());
            }
            BatchIn::Tokens { .. } => {
                unreachable!("conv-family model fed a token batch")
            }
        }
    }
    (xs, ys)
}

// ---------------------------------------------------------------------------
// Lane-stacked interpreter kernels (DESIGN.md §12)
//
// `run_batch` stacks B independent jobs along a trailing *lane* axis:
// element `j` of job `b` lives at `j * lanes + b`, so the innermost loops
// below walk unit-stride lane blocks the compiler can vectorize (B f64
// accumulators per step instead of one). Reductions run over the same
// non-lane index in the same sequence regardless of the lane count —
// lanes only add an independent dimension — so a job's floating-point
// operation sequence is identical whether it runs alone (`run`, lanes=1)
// or stacked with others, and batched results are bit-for-bit identical
// to sequential `run` calls (`run_batch_bit_identical_to_sequential`
// below and the scheduler-level differential suite in
// `rust/tests/batched_agreement.rs`).
// ---------------------------------------------------------------------------

/// Strided lane dot product, width-4 unrolled tree order: reduces
/// `Σ_i a[i·l + lane] · b[i·l + lane]` over `n` terms with four
/// independent accumulators folded `(a0+a1)+(a2+a3)` plus a scalar tail.
/// The FP operation sequence depends only on `n` — never on `l` or
/// `lane` — which is what keeps `run` ≡ `run_batch` bit-identity intact
/// under reassociation (DESIGN.md §14).
#[inline]
fn dot_tree<E: Real>(a: &[E], b: &[E], n: usize, lane: usize, l: usize) -> E {
    let n4 = n & !3;
    let mut a0 = E::ZERO;
    let mut a1 = E::ZERO;
    let mut a2 = E::ZERO;
    let mut a3 = E::ZERO;
    let mut i = 0;
    while i < n4 {
        a0 += a[i * l + lane] * b[i * l + lane];
        a1 += a[(i + 1) * l + lane] * b[(i + 1) * l + lane];
        a2 += a[(i + 2) * l + lane] * b[(i + 2) * l + lane];
        a3 += a[(i + 3) * l + lane] * b[(i + 3) * l + lane];
        i += 4;
    }
    let mut s = (a0 + a1) + (a2 + a3);
    while i < n {
        s += a[i * l + lane] * b[i * l + lane];
        i += 1;
    }
    s
}

/// Scalar-order strided dot: the [`KernelMode::ScalarRef`] reduction.
#[inline]
fn dot_seq<E: Real>(a: &[E], b: &[E], n: usize, lane: usize, l: usize) -> E {
    let mut s = E::ZERO;
    for i in 0..n {
        s += a[i * l + lane] * b[i * l + lane];
    }
    s
}

/// Mode-dispatched strided lane dot (attention scores and backward dots
/// route through this; `matvec_l` rows do too).
#[inline]
pub fn dot_l<E: Real>(a: &[E], b: &[E], n: usize, lane: usize, l: usize) -> E {
    match kernel_mode() {
        KernelMode::Simd => dot_tree(a, b, n, lane, l),
        KernelMode::ScalarRef => dot_seq(a, b, n, lane, l),
    }
}

/// Strided lane sum in tree order (softmax normalizer); same sequence
/// contract as [`dot_l`].
#[inline]
fn sum_tree<E: Real>(a: &[E], n: usize, lane: usize, l: usize) -> E {
    let n4 = n & !3;
    let mut a0 = E::ZERO;
    let mut a1 = E::ZERO;
    let mut a2 = E::ZERO;
    let mut a3 = E::ZERO;
    let mut i = 0;
    while i < n4 {
        a0 += a[i * l + lane];
        a1 += a[(i + 1) * l + lane];
        a2 += a[(i + 2) * l + lane];
        a3 += a[(i + 3) * l + lane];
        i += 4;
    }
    let mut s = (a0 + a1) + (a2 + a3);
    while i < n {
        s += a[i * l + lane];
        i += 1;
    }
    s
}

/// Strided three-way lane dot in tree order (`Σ dy·g·x`, RMS backward).
#[inline]
fn dot3_tree<E: Real>(a: &[E], b: &[E], c: &[E], n: usize, lane: usize, l: usize) -> E {
    let n4 = n & !3;
    let mut a0 = E::ZERO;
    let mut a1 = E::ZERO;
    let mut a2 = E::ZERO;
    let mut a3 = E::ZERO;
    let mut i = 0;
    while i < n4 {
        a0 += a[i * l + lane] * b[i * l + lane] * c[i * l + lane];
        a1 += a[(i + 1) * l + lane] * b[(i + 1) * l + lane] * c[(i + 1) * l + lane];
        a2 += a[(i + 2) * l + lane] * b[(i + 2) * l + lane] * c[(i + 2) * l + lane];
        a3 += a[(i + 3) * l + lane] * b[(i + 3) * l + lane] * c[(i + 3) * l + lane];
        i += 4;
    }
    let mut s = (a0 + a1) + (a2 + a3);
    while i < n {
        s += a[i * l + lane] * b[i * l + lane] * c[i * l + lane];
        i += 1;
    }
    s
}

/// Lane matvec: `out[r] = W[r,:]·v` per lane. Simd mode reduces each row
/// with the width-4 tree ([`dot_tree`]); ScalarRef accumulates over
/// `cols` in scalar order ([`matvec_ref_l`]). Tolerance-bound kernel.
pub fn matvec_l<E: Real>(
    w: &[E],
    rows: usize,
    cols: usize,
    v: &[E],
    out: &mut [E],
    l: usize,
) {
    if kernel_mode() == KernelMode::ScalarRef {
        return matvec_ref_l(w, rows, cols, v, out, l);
    }
    for r in 0..rows {
        let wrow = &w[r * cols * l..(r + 1) * cols * l];
        let o = &mut out[r * l..(r + 1) * l];
        for (b, ob) in o.iter_mut().enumerate() {
            *ob = dot_tree(wrow, v, cols, b, l);
        }
    }
}

/// Scalar-iteration-order lane matvec: the pre-SIMD body, kept as the
/// `kernel_equivalence` oracle.
pub fn matvec_ref_l<E: Real>(
    w: &[E],
    rows: usize,
    cols: usize,
    v: &[E],
    out: &mut [E],
    l: usize,
) {
    for r in 0..rows {
        let o = &mut out[r * l..(r + 1) * l];
        o.fill(E::ZERO);
        for c in 0..cols {
            let wv = &w[(r * cols + c) * l..(r * cols + c + 1) * l];
            let vc = &v[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += wv[b] * vc[b];
            }
        }
    }
}

/// Lane transpose matvec: `out[c] += W[:,c]·v` per lane (accumulation
/// over `rows` in scalar order). Order-preserving: the inner `c`/`b`
/// loops are elementwise axpy sweeps the compiler vectorizes without
/// reassociating, so the one body is bit-exact in both kernel modes.
pub fn matvec_t_acc_l<E: Real>(
    w: &[E],
    rows: usize,
    cols: usize,
    v: &[E],
    out: &mut [E],
    l: usize,
) {
    for r in 0..rows {
        let vr = &v[r * l..(r + 1) * l];
        let wrow = &w[r * cols * l..(r + 1) * cols * l];
        for c in 0..cols {
            let wv = &wrow[c * l..(c + 1) * l];
            let o = &mut out[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += wv[b] * vr[b];
            }
        }
    }
}

/// Lane outer-product accumulation: `dW[r,c] += dv[r] * u[c]` per lane.
/// Order-preserving (no reduction): bit-exact in both kernel modes.
pub fn outer_acc_l<E: Real>(
    dw: &mut [E],
    rows: usize,
    cols: usize,
    dv: &[E],
    u: &[E],
    l: usize,
) {
    for r in 0..rows {
        let d = &dv[r * l..(r + 1) * l];
        let drow = &mut dw[r * cols * l..(r + 1) * cols * l];
        for c in 0..cols {
            let o = &mut drow[c * l..(c + 1) * l];
            let uc = &u[c * l..(c + 1) * l];
            for b in 0..l {
                o[b] += d[b] * uc[b];
            }
        }
    }
}

/// Lane softmax cross-entropy at one position (mirrors `softmax_ce`):
/// per-lane label `ys[b]`, per-lane `-ln p[y]` added into `losses`.
/// `maxs`/`zs` are caller-provided lane scratch.
///
/// The max pass is exact under any association; the normalizer `Z` is
/// the tolerance-bound part — Simd mode sums the exponentials with the
/// width-4 tree ([`sum_tree`]), ScalarRef interleaves exp and sum in
/// scalar index order exactly as the pre-SIMD body did.
#[allow(clippy::too_many_arguments)]
pub fn softmax_ce_l<E: Real>(
    logits: &[E],
    ys: &[usize],
    scale: E,
    dlogits: &mut [E],
    maxs: &mut [E],
    zs: &mut [E],
    losses: &mut [E],
    l: usize,
) {
    if kernel_mode() == KernelMode::ScalarRef {
        return softmax_ce_ref_l(logits, ys, scale, dlogits, maxs, zs, losses, l);
    }
    let v = logits.len() / l;
    maxs.fill(E::NEG_INF);
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        for b in 0..l {
            maxs[b] = maxs[b].maxr(li[b]);
        }
    }
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = (li[b] - maxs[b]).exp();
        }
    }
    for (b, zb) in zs.iter_mut().enumerate() {
        *zb = sum_tree(dlogits, v, b, l);
    }
    for b in 0..l {
        losses[b] += -(dlogits[ys[b] * l + b] / zs[b]).maxr(E::MIN_POS).ln();
    }
    for i in 0..v {
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = di[b] / zs[b] * scale;
        }
    }
    for b in 0..l {
        dlogits[ys[b] * l + b] -= scale;
    }
}

/// Scalar-order softmax cross-entropy: the pre-SIMD body, kept as the
/// `kernel_equivalence` oracle.
#[allow(clippy::too_many_arguments)]
pub fn softmax_ce_ref_l<E: Real>(
    logits: &[E],
    ys: &[usize],
    scale: E,
    dlogits: &mut [E],
    maxs: &mut [E],
    zs: &mut [E],
    losses: &mut [E],
    l: usize,
) {
    let v = logits.len() / l;
    maxs.fill(E::NEG_INF);
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        for b in 0..l {
            maxs[b] = maxs[b].maxr(li[b]);
        }
    }
    zs.fill(E::ZERO);
    for i in 0..v {
        let li = &logits[i * l..(i + 1) * l];
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = (li[b] - maxs[b]).exp();
            zs[b] += di[b];
        }
    }
    for b in 0..l {
        losses[b] += -(dlogits[ys[b] * l + b] / zs[b]).maxr(E::MIN_POS).ln();
    }
    for i in 0..v {
        let di = &mut dlogits[i * l..(i + 1) * l];
        for b in 0..l {
            di[b] = di[b] / zs[b] * scale;
        }
    }
    for b in 0..l {
        dlogits[ys[b] * l + b] -= scale;
    }
}

/// Lane RMS-norm forward (mirrors `rms_fwd`); writes per-lane rms into
/// `rs`. The sum-of-squares is tolerance-bound: Simd mode reduces it
/// with the width-4 tree (`dot_tree(x, x, …)`), ScalarRef in scalar
/// index order. The normalization sweep is elementwise in both.
pub fn rms_fwd_l<E: Real>(x: &[E], g: &[E], out: &mut [E], rs: &mut [E], l: usize) {
    let dim = x.len() / l;
    let d = E::from_f64(dim as f64);
    let eps = E::from_f64(RMS_EPS);
    if kernel_mode() == KernelMode::ScalarRef {
        rs.fill(E::ZERO);
        for i in 0..dim {
            let xi = &x[i * l..(i + 1) * l];
            for b in 0..l {
                rs[b] += xi[b] * xi[b];
            }
        }
    } else {
        for (b, rb) in rs.iter_mut().enumerate() {
            *rb = dot_tree(x, x, dim, b, l);
        }
    }
    for b in 0..l {
        rs[b] = (rs[b] / d + eps).sqrt();
    }
    for i in 0..dim {
        for b in 0..l {
            out[i * l + b] = x[i * l + b] / rs[b] * g[i * l + b];
        }
    }
}

/// Scalar-order RMS-norm forward: the pre-SIMD body, kept as the
/// `kernel_equivalence` oracle.
pub fn rms_fwd_ref_l<E: Real>(x: &[E], g: &[E], out: &mut [E], rs: &mut [E], l: usize) {
    let dim = x.len() / l;
    let d = E::from_f64(dim as f64);
    let eps = E::from_f64(RMS_EPS);
    rs.fill(E::ZERO);
    for i in 0..dim {
        let xi = &x[i * l..(i + 1) * l];
        for b in 0..l {
            rs[b] += xi[b] * xi[b];
        }
    }
    for b in 0..l {
        rs[b] = (rs[b] / d + eps).sqrt();
    }
    for i in 0..dim {
        for b in 0..l {
            out[i * l + b] = x[i * l + b] / rs[b] * g[i * l + b];
        }
    }
}

/// Lane RMS-norm backward (mirrors `rms_bwd`). `dots` is lane scratch.
/// The `Σ dy·g·x` reduction is tolerance-bound ([`dot3_tree`] in Simd
/// mode, scalar order in ScalarRef); the `dg` and `dx` sweeps are
/// elementwise and bit-exact in both modes.
#[allow(clippy::too_many_arguments)]
pub fn rms_bwd_l<E: Real>(
    x: &[E],
    g: &[E],
    rs: &[E],
    dy: &[E],
    dx: &mut [E],
    dg: &mut [E],
    dots: &mut [E],
    l: usize,
) {
    if kernel_mode() == KernelMode::ScalarRef {
        return rms_bwd_ref_l(x, g, rs, dy, dx, dg, dots, l);
    }
    let dim = x.len() / l;
    let d = E::from_f64(dim as f64);
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dg[s] += dy[s] * x[s] / rs[b];
        }
    }
    for (b, db) in dots.iter_mut().enumerate() {
        *db = dot3_tree(dy, g, x, dim, b, l);
    }
    for b in 0..l {
        dots[b] /= d * rs[b] * rs[b] * rs[b];
    }
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dx[s] += dy[s] * g[s] / rs[b] - x[s] * dots[b];
        }
    }
}

/// Scalar-order RMS-norm backward: the pre-SIMD body, kept as the
/// `kernel_equivalence` oracle.
#[allow(clippy::too_many_arguments)]
pub fn rms_bwd_ref_l<E: Real>(
    x: &[E],
    g: &[E],
    rs: &[E],
    dy: &[E],
    dx: &mut [E],
    dg: &mut [E],
    dots: &mut [E],
    l: usize,
) {
    let dim = x.len() / l;
    let d = E::from_f64(dim as f64);
    dots.fill(E::ZERO);
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dg[s] += dy[s] * x[s] / rs[b];
            dots[b] += dy[s] * g[s] * x[s];
        }
    }
    for b in 0..l {
        dots[b] /= d * rs[b] * rs[b] * rs[b];
    }
    for i in 0..dim {
        for b in 0..l {
            let s = i * l + b;
            dx[s] += dy[s] * g[s] / rs[b] - x[s] * dots[b];
        }
    }
}

/// Lane-stacked loss + gradients: per-lane losses (widened to f64 at
/// the boundary) and lane-major gradients in the compute precision,
/// dispatched on the model family. Every family has exactly one pass
/// implementation; lanes = 1 is the sequential case.
fn loss_and_grads_l<E: Real>(
    dims: &Dims,
    params_l: &[Vec<E>],
    batches: &[BatchIn],
    lanes: usize,
) -> (Vec<f64>, Vec<Vec<E>>) {
    let mut grads: Vec<Vec<E>> =
        params_l.iter().map(|p| vec![E::ZERO; p.len()]).collect();
    let losses = match dims.family {
        Family::Mlp => mlp_pass_l(dims, params_l, batches, &mut grads, lanes),
        Family::Gpt => gpt_pass_l(dims, params_l, batches, &mut grads, lanes),
        Family::Conv => conv_pass_l(dims, params_l, batches, &mut grads, lanes),
    };
    (losses, grads)
}

/// Per-token MLP language model: `logits = W_head·(W_down·relu(W_up·E[x]))`.
/// Params: `[tok_embd (V×D), mlp_up (H×D), mlp_down (D×H), lm_head (V×D)]`.
/// Every buffer carries a trailing lane axis; token gathers differ per lane.
fn mlp_pass_l<E: Real>(
    dims: &Dims,
    params_l: &[Vec<E>],
    batches: &[BatchIn],
    grads_l: &mut [Vec<E>],
    l: usize,
) -> Vec<f64> {
    let (v, d, h) = (dims.vocab, dims.d, dims.hidden);
    let (xs, ys) = token_lanes(batches);
    let e = &params_l[0];
    let wu = &params_l[1];
    let wd = &params_l[2];
    let wh = &params_l[3];
    let n_tok = xs[0].len();
    let scale = E::from_f64(1.0 / n_tok as f64);

    let mut emb = vec![E::ZERO; d * l];
    let mut u_pre = vec![E::ZERO; h * l];
    let mut u = vec![E::ZERO; h * l];
    let mut z = vec![E::ZERO; d * l];
    let mut logits = vec![E::ZERO; v * l];
    let mut dlogits = vec![E::ZERO; v * l];
    let mut dz = vec![E::ZERO; d * l];
    let mut du = vec![E::ZERO; h * l];
    let mut de = vec![E::ZERO; d * l];
    let mut maxs = vec![E::ZERO; l];
    let mut zs = vec![E::ZERO; l];
    let mut losses = vec![E::ZERO; l];
    let mut ytok = vec![0usize; l];

    for n in 0..n_tok {
        for b in 0..l {
            let tok = xs[b][n] as usize;
            for i in 0..d {
                emb[i * l + b] = e[(tok * d + i) * l + b];
            }
            ytok[b] = ys[b][n] as usize;
        }
        matvec_l(wu, h, d, &emb, &mut u_pre, l);
        for j in 0..h * l {
            u[j] = u_pre[j].maxr(E::ZERO);
        }
        matvec_l(wd, d, h, &u, &mut z, l);
        matvec_l(wh, v, d, &z, &mut logits, l);
        softmax_ce_l(&logits, &ytok, scale, &mut dlogits, &mut maxs, &mut zs, &mut losses, l);

        // backward
        outer_acc_l(&mut grads_l[3], v, d, &dlogits, &z, l);
        dz.fill(E::ZERO);
        matvec_t_acc_l(wh, v, d, &dlogits, &mut dz, l);
        outer_acc_l(&mut grads_l[2], d, h, &dz, &u, l);
        du.fill(E::ZERO);
        matvec_t_acc_l(wd, d, h, &dz, &mut du, l);
        for j in 0..h * l {
            if u_pre[j] <= E::ZERO {
                du[j] = 0.0;
            }
        }
        outer_acc_l(&mut grads_l[1], h, d, &du, &emb, l);
        de.fill(E::ZERO);
        matvec_t_acc_l(wu, h, d, &du, &mut de, l);
        for b in 0..l {
            let tok = xs[b][n] as usize;
            for i in 0..d {
                grads_l[0][(tok * d + i) * l + b] += de[i * l + b];
            }
        }
    }
    losses.iter().map(|&x| (x * scale).to_f64()).collect()
}

/// N-block causal transformer with RMS-norm (scale-only), multi-head
/// attention and a ReLU MLP, residual connections around both sublayers.
/// Params (manifest order): tok_embd, pos_embd, then per block
/// `h<i>.{ln_attn, attn_q, attn_k, attn_v, attn_proj, ln_mlp, mlp_up,
/// mlp_down}`, then ln_final, lm_head. `gpt_micro` is the 1-block
/// instantiation, `gpt_deep` the 4-block one; attention rows, norms and
/// residuals all carry the trailing lane axis.
fn gpt_pass_l<E: Real>(
    dims: &Dims,
    params_l: &[Vec<E>],
    batches: &[BatchIn],
    grads_l: &mut [Vec<E>],
    l: usize,
) -> Vec<f64> {
    let (v, d, f, heads, t_ctx, rows_b, nb) = (
        dims.vocab,
        dims.d,
        dims.hidden,
        dims.heads,
        dims.ctx,
        dims.batch,
        dims.blocks,
    );
    let dh = d / heads;
    let att_scale = E::from_f64(1.0 / (dh as f64).sqrt());
    let (xs, ys) = token_lanes(batches);
    let e = &params_l[0];
    let pos = &params_l[1];
    // block b's parameter index for offset o: 0 ln_attn, 1 q, 2 k, 3 v,
    // 4 proj, 5 ln_mlp, 6 up, 7 down
    let blk = |b: usize, o: usize| 2 + 8 * b + o;
    let i_lnf = 2 + 8 * nb;
    let i_head = i_lnf + 1;
    let scale = E::from_f64(1.0 / (rows_b * t_ctx) as f64);
    let mut losses = vec![E::ZERO; l];

    let td = t_ctx * d;
    // residual stream levels: hs[b] enters block b; hs[nb] feeds ln_final
    let mut hs: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb + 1];
    let mut dhs: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb + 1];
    // per-block saved activations (needed by the backward pass)
    let mut a_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut q_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut k_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut vv_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut att_s: Vec<Vec<E>> = vec![vec![E::ZERO; heads * t_ctx * t_ctx * l]; nb];
    let mut ctx_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut hmid_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut min_s: Vec<Vec<E>> = vec![vec![E::ZERO; td * l]; nb];
    let mut upre_s: Vec<Vec<E>> = vec![vec![E::ZERO; t_ctx * f * l]; nb];
    let mut u_s: Vec<Vec<E>> = vec![vec![E::ZERO; t_ctx * f * l]; nb];
    let mut r_attn: Vec<Vec<E>> = vec![vec![E::ZERO; t_ctx * l]; nb];
    let mut r_mlp: Vec<Vec<E>> = vec![vec![E::ZERO; t_ctx * l]; nb];
    let mut fo = vec![E::ZERO; td * l];
    let mut r_fin = vec![E::ZERO; t_ctx * l];
    // transient buffers shared across blocks
    let mut o = vec![E::ZERO; td * l];
    let mut logits = vec![E::ZERO; v * l];
    let mut dlogits = vec![E::ZERO; v * l];
    let mut dhmid = vec![E::ZERO; td * l];
    let mut dctx = vec![E::ZERO; td * l];
    let mut dq = vec![E::ZERO; td * l];
    let mut dk = vec![E::ZERO; td * l];
    let mut dv = vec![E::ZERO; td * l];
    let mut da = vec![E::ZERO; td * l];
    let mut dfo = vec![E::ZERO; d * l];
    let mut du = vec![E::ZERO; f * l];
    let mut dm_in = vec![E::ZERO; d * l];
    let mut datt = vec![E::ZERO; t_ctx * l];
    let mut ds_l = vec![E::ZERO; l];
    let mut maxs = vec![E::ZERO; l];
    let mut zs = vec![E::ZERO; l];
    let mut dots = vec![E::ZERO; l];
    let mut ytok = vec![0usize; l];

    for row in 0..rows_b {
        // ---- forward ----
        for t in 0..t_ctx {
            for b in 0..l {
                let tok = xs[b][row * t_ctx + t] as usize;
                for i in 0..d {
                    hs[0][(t * d + i) * l + b] =
                        e[(tok * d + i) * l + b] + pos[(t * d + i) * l + b];
                }
            }
        }
        for bi in 0..nb {
            let (g1, wq, wk, wv, wp, g2, wu, wd_) = (
                &params_l[blk(bi, 0)],
                &params_l[blk(bi, 1)],
                &params_l[blk(bi, 2)],
                &params_l[blk(bi, 3)],
                &params_l[blk(bi, 4)],
                &params_l[blk(bi, 5)],
                &params_l[blk(bi, 6)],
                &params_l[blk(bi, 7)],
            );
            for t in 0..t_ctx {
                let tr = t * d * l..(t + 1) * d * l;
                rms_fwd_l(
                    &hs[bi][tr.clone()],
                    g1,
                    &mut a_s[bi][tr.clone()],
                    &mut r_attn[bi][t * l..(t + 1) * l],
                    l,
                );
                matvec_l(wq, d, d, &a_s[bi][tr.clone()], &mut q_s[bi][tr.clone()], l);
                matvec_l(wk, d, d, &a_s[bi][tr.clone()], &mut k_s[bi][tr.clone()], l);
                matvec_l(wv, d, d, &a_s[bi][tr.clone()], &mut vv_s[bi][tr], l);
            }
            {
                let att = &mut att_s[bi];
                let ctx = &mut ctx_s[bi];
                let (q, k, vv) = (&q_s[bi], &k_s[bi], &vv_s[bi]);
                ctx.fill(E::ZERO);
                for hh in 0..heads {
                    let off = hh * dh;
                    for t in 0..t_ctx {
                        let arow0 = (hh * t_ctx + t) * t_ctx * l;
                        maxs.fill(E::NEG_INF);
                        for tp in 0..=t {
                            // score = (q_t · k_tp) / sqrt(dh), per lane;
                            // the dot reassociates under Simd (dot_l)
                            let sbuf = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            let qrow = &q[(t * d + off) * l..(t * d + off + dh) * l];
                            let krow = &k[(tp * d + off) * l..(tp * d + off + dh) * l];
                            for (b, sb) in sbuf.iter_mut().enumerate() {
                                *sb = dot_l(qrow, krow, dh, b, l) * att_scale;
                                maxs[b] = maxs[b].maxr(*sb);
                            }
                        }
                        zs.fill(E::ZERO);
                        for tp in 0..=t {
                            let ab = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            for b in 0..l {
                                ab[b] = (ab[b] - maxs[b]).exp();
                                zs[b] += ab[b];
                            }
                        }
                        for tp in 0..=t {
                            // normalize, then accumulate this tp's
                            // contribution to ctx
                            {
                                let ab = &mut att[arow0 + tp * l..arow0 + (tp + 1) * l];
                                for b in 0..l {
                                    ab[b] /= zs[b];
                                }
                            }
                            let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            for i in 0..dh {
                                let vvi =
                                    &vv[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                                let ci = &mut ctx
                                    [(t * d + off + i) * l..(t * d + off + i + 1) * l];
                                for b in 0..l {
                                    ci[b] += ab[b] * vvi[b];
                                }
                            }
                        }
                    }
                }
            }
            for t in 0..t_ctx {
                let tr = t * d * l..(t + 1) * d * l;
                matvec_l(wp, d, d, &ctx_s[bi][tr.clone()], &mut o[tr.clone()], l);
                for j in tr.clone() {
                    hmid_s[bi][j] = hs[bi][j] + o[j];
                }
                rms_fwd_l(
                    &hmid_s[bi][tr.clone()],
                    g2,
                    &mut min_s[bi][tr.clone()],
                    &mut r_mlp[bi][t * l..(t + 1) * l],
                    l,
                );
                let fr = t * f * l..(t + 1) * f * l;
                matvec_l(wu, f, d, &min_s[bi][tr.clone()], &mut upre_s[bi][fr.clone()], l);
                for j in fr.clone() {
                    u_s[bi][j] = upre_s[bi][j].maxr(E::ZERO);
                }
                // hs[bi+1] = hmid + W_down u
                matvec_l(wd_, d, f, &u_s[bi][fr], &mut hs[bi + 1][tr.clone()], l);
                for j in tr {
                    hs[bi + 1][j] += hmid_s[bi][j];
                }
            }
        }
        {
            let g3 = &params_l[i_lnf];
            for t in 0..t_ctx {
                let tr = t * d * l..(t + 1) * d * l;
                rms_fwd_l(
                    &hs[nb][tr.clone()],
                    g3,
                    &mut fo[tr],
                    &mut r_fin[t * l..(t + 1) * l],
                    l,
                );
            }
        }

        // ---- backward ----
        for buf in dhs.iter_mut() {
            buf.fill(E::ZERO);
        }
        {
            let g3 = &params_l[i_lnf];
            let wh = &params_l[i_head];
            for t in 0..t_ctx {
                let tr = t * d * l..(t + 1) * d * l;
                matvec_l(wh, v, d, &fo[tr.clone()], &mut logits, l);
                for b in 0..l {
                    ytok[b] = ys[b][row * t_ctx + t] as usize;
                }
                softmax_ce_l(
                    &logits, &ytok, scale, &mut dlogits, &mut maxs, &mut zs,
                    &mut losses, l,
                );
                outer_acc_l(&mut grads_l[i_head], v, d, &dlogits, &fo[tr.clone()], l);
                dfo.fill(E::ZERO);
                matvec_t_acc_l(wh, v, d, &dlogits, &mut dfo, l);
                rms_bwd_l(
                    &hs[nb][tr.clone()],
                    g3,
                    &r_fin[t * l..(t + 1) * l],
                    &dfo,
                    &mut dhs[nb][tr],
                    &mut grads_l[i_lnf],
                    &mut dots,
                    l,
                );
            }
        }
        for bi in (0..nb).rev() {
            let (g1, wq, wk, wv, wp, g2, wu, wd_) = (
                &params_l[blk(bi, 0)],
                &params_l[blk(bi, 1)],
                &params_l[blk(bi, 2)],
                &params_l[blk(bi, 3)],
                &params_l[blk(bi, 4)],
                &params_l[blk(bi, 5)],
                &params_l[blk(bi, 6)],
                &params_l[blk(bi, 7)],
            );
            for buf in [&mut dhmid, &mut dctx, &mut dq, &mut dk, &mut dv, &mut da] {
                buf.fill(E::ZERO);
            }
            for t in 0..t_ctx {
                // hs[bi+1] = hmid + W_down relu(W_up m_in)
                let tr = t * d * l..(t + 1) * d * l;
                let fr = t * f * l..(t + 1) * f * l;
                for j in tr.clone() {
                    dhmid[j] += dhs[bi + 1][j];
                }
                outer_acc_l(
                    &mut grads_l[blk(bi, 7)],
                    d,
                    f,
                    &dhs[bi + 1][tr.clone()],
                    &u_s[bi][fr.clone()],
                    l,
                );
                du.fill(E::ZERO);
                matvec_t_acc_l(wd_, d, f, &dhs[bi + 1][tr.clone()], &mut du, l);
                for (j, x) in upre_s[bi][fr].iter().enumerate() {
                    if *x <= E::ZERO {
                        du[j] = 0.0;
                    }
                }
                outer_acc_l(&mut grads_l[blk(bi, 6)], f, d, &du, &min_s[bi][tr.clone()], l);
                dm_in.fill(E::ZERO);
                matvec_t_acc_l(wu, f, d, &du, &mut dm_in, l);
                rms_bwd_l(
                    &hmid_s[bi][tr.clone()],
                    g2,
                    &r_mlp[bi][t * l..(t + 1) * l],
                    &dm_in,
                    &mut dhmid[tr],
                    &mut grads_l[blk(bi, 5)],
                    &mut dots,
                    l,
                );
            }
            for t in 0..t_ctx {
                // hmid = hs[bi] + W_proj ctx
                let tr = t * d * l..(t + 1) * d * l;
                for j in tr.clone() {
                    dhs[bi][j] += dhmid[j];
                }
                outer_acc_l(
                    &mut grads_l[blk(bi, 4)],
                    d,
                    d,
                    &dhmid[tr.clone()],
                    &ctx_s[bi][tr.clone()],
                    l,
                );
                matvec_t_acc_l(wp, d, d, &dhmid[tr.clone()], &mut dctx[tr], l);
            }
            {
                let att = &att_s[bi];
                let (q, k, vv) = (&q_s[bi], &k_s[bi], &vv_s[bi]);
                for hh in 0..heads {
                    let off = hh * dh;
                    for t in 0..t_ctx {
                        let arow0 = (hh * t_ctx + t) * t_ctx * l;
                        for tp in 0..=t {
                            // dα = dctx_t · v_tp per lane (reassociates
                            // under Simd via dot_l)
                            let dat = &mut datt[tp * l..(tp + 1) * l];
                            let drow =
                                &dctx[(t * d + off) * l..(t * d + off + dh) * l];
                            let vrow =
                                &vv[(tp * d + off) * l..(tp * d + off + dh) * l];
                            for (b, db) in dat.iter_mut().enumerate() {
                                *db = dot_l(drow, vrow, dh, b, l);
                            }
                            let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            for i in 0..dh {
                                let dci = &dctx
                                    [(t * d + off + i) * l..(t * d + off + i + 1) * l];
                                let dvi = &mut dv
                                    [(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                                for b in 0..l {
                                    dvi[b] += ab[b] * dci[b];
                                }
                            }
                        }
                        dots.fill(E::ZERO);
                        for tp in 0..=t {
                            let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            let dat = &datt[tp * l..(tp + 1) * l];
                            for b in 0..l {
                                dots[b] += ab[b] * dat[b];
                            }
                        }
                        for tp in 0..=t {
                            let ab = &att[arow0 + tp * l..arow0 + (tp + 1) * l];
                            let dat = &datt[tp * l..(tp + 1) * l];
                            for b in 0..l {
                                ds_l[b] = ab[b] * (dat[b] - dots[b]) * att_scale;
                            }
                            for i in 0..dh {
                                let ki =
                                    &k[(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                                let qi =
                                    &q[(t * d + off + i) * l..(t * d + off + i + 1) * l];
                                {
                                    let dqi = &mut dq
                                        [(t * d + off + i) * l..(t * d + off + i + 1) * l];
                                    for b in 0..l {
                                        dqi[b] += ds_l[b] * ki[b];
                                    }
                                }
                                let dki = &mut dk
                                    [(tp * d + off + i) * l..(tp * d + off + i + 1) * l];
                                for b in 0..l {
                                    dki[b] += ds_l[b] * qi[b];
                                }
                            }
                        }
                    }
                }
            }
            for t in 0..t_ctx {
                let tr = t * d * l..(t + 1) * d * l;
                outer_acc_l(
                    &mut grads_l[blk(bi, 1)],
                    d,
                    d,
                    &dq[tr.clone()],
                    &a_s[bi][tr.clone()],
                    l,
                );
                outer_acc_l(
                    &mut grads_l[blk(bi, 2)],
                    d,
                    d,
                    &dk[tr.clone()],
                    &a_s[bi][tr.clone()],
                    l,
                );
                outer_acc_l(
                    &mut grads_l[blk(bi, 3)],
                    d,
                    d,
                    &dv[tr.clone()],
                    &a_s[bi][tr.clone()],
                    l,
                );
                matvec_t_acc_l(wq, d, d, &dq[tr.clone()], &mut da[tr.clone()], l);
                matvec_t_acc_l(wk, d, d, &dk[tr.clone()], &mut da[tr.clone()], l);
                matvec_t_acc_l(wv, d, d, &dv[tr.clone()], &mut da[tr.clone()], l);
                rms_bwd_l(
                    &hs[bi][tr.clone()],
                    g1,
                    &r_attn[bi][t * l..(t + 1) * l],
                    &da[tr.clone()],
                    &mut dhs[bi][tr],
                    &mut grads_l[blk(bi, 0)],
                    &mut dots,
                    l,
                );
            }
        }
        for t in 0..t_ctx {
            for b in 0..l {
                let tok = xs[b][row * t_ctx + t] as usize;
                for i in 0..d {
                    grads_l[0][(tok * d + i) * l + b] += dhs[0][(t * d + i) * l + b];
                    grads_l[1][(t * d + i) * l + b] += dhs[0][(t * d + i) * l + b];
                }
            }
        }
    }
    losses.iter().map(|&x| (x * scale).to_f64()).collect()
}

/// Small convolutional image classifier: two `valid` 3×3 convolutions
/// (ReLU) around a 2×2 average pool, then a linear head over the
/// flattened features. Params (manifest order): conv1 `(C1, C_in, 3, 3)`,
/// conv2 `(C2, C1, 3, 3)`, head `(classes, o2·o2·C2)` — all OIHW /
/// fan_out_axis 0, so `fan_in` compression averages one second moment per
/// output filter. Input is NHWC f32, one class label per sample.
fn conv_pass_l<E: Real>(
    dims: &Dims,
    params_l: &[Vec<E>],
    batches: &[BatchIn],
    grads_l: &mut [Vec<E>],
    l: usize,
) -> Vec<f64> {
    let (classes, c1, c2, img, ch, bsz) = (
        dims.vocab,
        dims.d,
        dims.hidden,
        dims.img,
        dims.channels,
        dims.batch,
    );
    let kk = CONV_K;
    let (o1, pw, o2) = conv_geom(dims);
    let feats = o2 * o2 * c2;
    let inv_pool = E::from_f64(1.0 / (POOL * POOL) as f64);
    let (xs, ys) = image_lanes(batches);
    let w1 = &params_l[0];
    let w2 = &params_l[1];
    let wh = &params_l[2];
    let scale = E::from_f64(1.0 / bsz as f64);
    let mut losses = vec![E::ZERO; l];

    let px = img * img * ch;
    let mut x_l = vec![E::ZERO; px * l]; // one sample per lane, gathered
    let mut a1 = vec![E::ZERO; o1 * o1 * c1 * l]; // conv1 pre-activation
    let mut pool = vec![E::ZERO; pw * pw * c1 * l]; // avg-pooled relu(a1)
    let mut z = vec![E::ZERO; feats * l]; // conv2 pre-activation
    let mut fvec = vec![E::ZERO; feats * l]; // relu(z)
    let mut logits = vec![E::ZERO; classes * l];
    let mut dlogits = vec![E::ZERO; classes * l];
    let mut df = vec![E::ZERO; feats * l];
    let mut dz = vec![E::ZERO; feats * l];
    let mut dpool = vec![E::ZERO; pw * pw * c1 * l];
    let mut da1 = vec![E::ZERO; o1 * o1 * c1 * l];
    let mut maxs = vec![E::ZERO; l];
    let mut zs = vec![E::ZERO; l];
    let mut ytok = vec![0usize; l];

    for s in 0..bsz {
        // ---- forward ----
        for b in 0..l {
            let src = &xs[b][s * px..(s + 1) * px];
            for (j, &val) in src.iter().enumerate() {
                x_l[j * l + b] = E::from_f32(val);
            }
            ytok[b] = ys[b][s] as usize;
        }
        // conv1 (valid): a1[oy,ox,co] = Σ_{ci,ky,kx} w1[co,ci,ky,kx] ·
        // x[oy+ky, ox+kx, ci]
        for oy in 0..o1 {
            for ox in 0..o1 {
                for co in 0..c1 {
                    let oi = ((oy * o1 + ox) * c1 + co) * l;
                    let out = &mut a1[oi..oi + l];
                    out.fill(E::ZERO);
                    for ci in 0..ch {
                        for ky in 0..kk {
                            for kx in 0..kk {
                                let wi = (((co * ch + ci) * kk + ky) * kk + kx) * l;
                                let xi = (((oy + ky) * img + (ox + kx)) * ch + ci) * l;
                                let wv = &w1[wi..wi + l];
                                let xv = &x_l[xi..xi + l];
                                for b in 0..l {
                                    out[b] += wv[b] * xv[b];
                                }
                            }
                        }
                    }
                }
            }
        }
        // ReLU then 2×2 average pool
        for py in 0..pw {
            for pxi in 0..pw {
                for co in 0..c1 {
                    let oi = ((py * pw + pxi) * c1 + co) * l;
                    {
                        let out = &mut pool[oi..oi + l];
                        out.fill(E::ZERO);
                    }
                    for dy in 0..POOL {
                        for dx in 0..POOL {
                            let si =
                                (((py * POOL + dy) * o1 + (pxi * POOL + dx)) * c1 + co) * l;
                            for b in 0..l {
                                pool[oi + b] += a1[si + b].maxr(E::ZERO);
                            }
                        }
                    }
                    for b in 0..l {
                        pool[oi + b] *= inv_pool;
                    }
                }
            }
        }
        // conv2 (valid) over the pooled map, flattened feature order
        // ((qy·o2 + qx)·C2 + co)
        for qy in 0..o2 {
            for qx in 0..o2 {
                for co in 0..c2 {
                    let oi = ((qy * o2 + qx) * c2 + co) * l;
                    {
                        let out = &mut z[oi..oi + l];
                        out.fill(E::ZERO);
                    }
                    for ci in 0..c1 {
                        for ky in 0..kk {
                            for kx in 0..kk {
                                let wi = (((co * c1 + ci) * kk + ky) * kk + kx) * l;
                                let pi = (((qy + ky) * pw + (qx + kx)) * c1 + ci) * l;
                                for b in 0..l {
                                    z[oi + b] += w2[wi + b] * pool[pi + b];
                                }
                            }
                        }
                    }
                }
            }
        }
        for j in 0..feats * l {
            fvec[j] = z[j].maxr(E::ZERO);
        }
        matvec_l(wh, classes, feats, &fvec, &mut logits, l);
        softmax_ce_l(
            &logits, &ytok, scale, &mut dlogits, &mut maxs, &mut zs, &mut losses, l,
        );

        // ---- backward ----
        outer_acc_l(&mut grads_l[2], classes, feats, &dlogits, &fvec, l);
        df.fill(E::ZERO);
        matvec_t_acc_l(wh, classes, feats, &dlogits, &mut df, l);
        for j in 0..feats * l {
            dz[j] = if z[j] > E::ZERO { df[j] } else { 0.0 };
        }
        dpool.fill(E::ZERO);
        for qy in 0..o2 {
            for qx in 0..o2 {
                for co in 0..c2 {
                    let oi = ((qy * o2 + qx) * c2 + co) * l;
                    for ci in 0..c1 {
                        for ky in 0..kk {
                            for kx in 0..kk {
                                let wi = (((co * c1 + ci) * kk + ky) * kk + kx) * l;
                                let pi = (((qy + ky) * pw + (qx + kx)) * c1 + ci) * l;
                                {
                                    let gw = &mut grads_l[1][wi..wi + l];
                                    for b in 0..l {
                                        gw[b] += dz[oi + b] * pool[pi + b];
                                    }
                                }
                                for b in 0..l {
                                    dpool[pi + b] += dz[oi + b] * w2[wi + b];
                                }
                            }
                        }
                    }
                }
            }
        }
        // pool backward (uniform 1/4 share) + conv1 ReLU mask
        for py in 0..pw {
            for pxi in 0..pw {
                for co in 0..c1 {
                    let pi = ((py * pw + pxi) * c1 + co) * l;
                    for dy in 0..POOL {
                        for dx in 0..POOL {
                            let si =
                                (((py * POOL + dy) * o1 + (pxi * POOL + dx)) * c1 + co) * l;
                            for b in 0..l {
                                da1[si + b] = if a1[si + b] > E::ZERO {
                                    dpool[pi + b] * inv_pool
                                } else {
                                    E::ZERO
                                };
                            }
                        }
                    }
                }
            }
        }
        // conv1 weight gradients
        for oy in 0..o1 {
            for ox in 0..o1 {
                for co in 0..c1 {
                    let oi = ((oy * o1 + ox) * c1 + co) * l;
                    for ci in 0..ch {
                        for ky in 0..kk {
                            for kx in 0..kk {
                                let wi = (((co * ch + ci) * kk + ky) * kk + kx) * l;
                                let xi = (((oy + ky) * img + (ox + kx)) * ch + ci) * l;
                                let gw = &mut grads_l[0][wi..wi + l];
                                for b in 0..l {
                                    gw[b] += da1[oi + b] * x_l[xi + b];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    losses.iter().map(|&x| (x * scale).to_f64()).collect()
}

/// Per-chunk element count for the parallel global-norm squared sum.
/// Chunk boundaries are a function of each tensor's element count only
/// (never of the lane or worker count), so the reduction tree — per-chunk
/// width-4 tree sums folded in `(tensor, chunk)` order — is deterministic
/// for any `(lanes, workers)` pair.
const CLIP_CHUNK: usize = 8192;

/// Per-lane squared sum of one `[j0, j1)` element range of a lane-major
/// f32 gradient, accumulated in f64 with the width-4 tree.
fn clip_sq_chunk(g: &[f32], j0: usize, j1: usize, l: usize) -> Vec<f64> {
    let n = j1 - j0;
    let n4 = n & !3;
    let mut out = vec![0.0f64; l];
    for (b, ob) in out.iter_mut().enumerate() {
        let at = |i: usize| -> f64 { g[(j0 + i) * l + b] as f64 };
        let mut a0 = 0.0f64;
        let mut a1 = 0.0f64;
        let mut a2 = 0.0f64;
        let mut a3 = 0.0f64;
        let mut i = 0;
        while i < n4 {
            a0 += at(i) * at(i);
            a1 += at(i + 1) * at(i + 1);
            a2 += at(i + 2) * at(i + 2);
            a3 += at(i + 3) * at(i + 3);
            i += 4;
        }
        let mut s = (a0 + a1) + (a2 + a3);
        while i < n {
            s += at(i) * at(i);
            i += 1;
        }
        *ob = s;
    }
    out
}

/// Per-lane global-norm clip over lane-major f32 gradients (mirrors
/// `optim::clip_global_norm`: squares accumulate in f64). Returns each
/// lane's pre-clip norm.
///
/// Simd mode splits every tensor into [`CLIP_CHUNK`]-element ranges,
/// computes per-chunk width-4 tree sums — optionally on
/// `pool::intraop_workers()` threads — and folds them in `(tensor,
/// chunk)` index order, so the result is bitwise invariant in the worker
/// count and the reduction is tolerance-bound vs. the scalar-order
/// reference ([`clip_global_norm_ref_l`]). The rescale sweep is
/// elementwise and bit-exact in both modes.
pub fn clip_global_norm_l(grads: &mut [Vec<f32>], max_norm: f64, l: usize) -> Vec<f64> {
    if kernel_mode() == KernelMode::ScalarRef {
        return clip_global_norm_ref_l(grads, max_norm, l);
    }
    // chunk table: (tensor index, j range) — layout from shapes only
    let mut chunks: Vec<(usize, usize, usize)> = Vec::new();
    for (gi, g) in grads.iter().enumerate() {
        let numel = g.len() / l;
        let mut j = 0;
        while j < numel {
            chunks.push((gi, j, (j + CLIP_CHUNK).min(numel)));
            j += CLIP_CHUNK;
        }
    }
    let workers = crate::pool::intraop_workers();
    let t0 = crate::obs::clock();
    let partials = crate::pool::parallel_indexed(chunks.len(), workers, |i| {
        let (gi, j0, j1) = chunks[i];
        clip_sq_chunk(&grads[gi], j0, j1, l)
    });
    let mut sq = vec![0.0f64; l];
    for part in &partials {
        for b in 0..l {
            sq[b] += part[b];
        }
    }
    if crate::obs::enabled() {
        let elems: usize = grads.iter().map(|g| g.len()).sum();
        crate::obs::emit_since(
            crate::obs::SpanKind::IntraopChunk,
            crate::obs::intern("clip_global_norm"),
            t0,
            [chunks.len() as u64, elems as u64, 0, 0],
        );
    }
    let norms: Vec<f64> = sq.iter().map(|s| s.sqrt()).collect();
    rescale_lanes(grads, &norms, max_norm, l);
    norms
}

/// Post-norm rescale sweep shared by the SIMD and scalar-order clip
/// paths, elementwise and bit-exact in both. A non-finite lane norm
/// (some gradient element overflowed to NaN/Inf) clips that lane to
/// zero: rescaling cannot repair it — `g * (max_norm / inf)` leaves
/// NaNs in place — and without the guard one degenerate lane poisons
/// its optimizer state for the rest of the run (mirrors
/// `optim::clip_global_norm`).
fn rescale_lanes(grads: &mut [Vec<f32>], norms: &[f64], max_norm: f64, l: usize) {
    for (b, &norm) in norms.iter().enumerate() {
        if !norm.is_finite() {
            for g in grads.iter_mut() {
                for x in g[b..].iter_mut().step_by(l) {
                    *x = 0.0;
                }
            }
        } else if norm > max_norm && norm > 0.0 {
            let scale = (max_norm / norm) as f32;
            for g in grads.iter_mut() {
                for x in g[b..].iter_mut().step_by(l) {
                    *x *= scale;
                }
            }
        }
    }
}

/// Scalar-order global-norm clip: the pre-SIMD body (squares accumulate
/// over tensors and elements in scalar order, single-threaded), kept as
/// the `kernel_equivalence` oracle.
pub fn clip_global_norm_ref_l(
    grads: &mut [Vec<f32>],
    max_norm: f64,
    l: usize,
) -> Vec<f64> {
    let mut sq = vec![0.0f64; l];
    for g in grads.iter() {
        let numel = g.len() / l;
        for j in 0..numel {
            let row = &g[j * l..(j + 1) * l];
            for b in 0..l {
                sq[b] += (row[b] as f64) * (row[b] as f64);
            }
        }
    }
    let norms: Vec<f64> = sq.iter().map(|s| s.sqrt()).collect();
    rescale_lanes(grads, &norms, max_norm, l);
    norms
}

/// Resolve tensor `i`'s effective K and output V shape from the stored
/// second-moment length (DESIGN.md §18). The baked reduced length runs
/// the baked mode; the full parameter length — produced by an adaptive
/// decompression — runs exact AdamW (`K = ∅`). Only the AdamW family
/// migrates: the bake-off kernels own their V layouts and accept exactly
/// the baked length. When the two lengths coincide (e.g. fan_out on a
/// 1×N view) the baked branch wins, which is exact anyway — every
/// sharing group has one element, so the grouped update *is* AdamW.
fn effective_v_mode<'a>(
    man: &'a Manifest,
    k_modes: &[KMode],
    baked: &'a [usize],
    i: usize,
    got_len: usize,
) -> Result<(KMode, &'a [usize])> {
    let baked_len: usize = baked.iter().product();
    if got_len == baked_len {
        return Ok((k_modes[i], baked));
    }
    let full_len = man.params[i].numel();
    if got_len == full_len && k_modes[i] != KMode::None && man.optimizer_name() == "adamw" {
        return Ok((KMode::None, man.params[i].shape.as_slice()));
    }
    bail!(
        "v for {:?} has {got_len} elements, want {baked_len} (baked K) or \
         {full_len} (decompressed full V)",
        man.params[i].name
    )
}

/// One tensor's fused reduced-V AdamW update: the body of the pre-PR
/// per-tensor loop, scalar `j` order throughout (the reduced-V group
/// sums accumulate in element order). Bit-exact in both kernel modes —
/// parallelism only distributes whole tensors across workers.
#[allow(clippy::too_many_arguments)]
fn update_tensor(
    info: &crate::runtime::manifest::ParamInfo,
    k: KMode,
    h: &Hypers,
    bc1: &[f32],
    bc2: &[f32],
    lrs: &[f32],
    wi: &mut [f32],
    mi: &mut [f32],
    vi: &mut [f32],
    gi: &[f32],
    l: usize,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let (rows, cols) = info.matrix_dims();
    let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
    let numel = info.numel();
    {
        if k == KMode::None {
            for j in 0..numel {
                for b in 0..l {
                    let s = j * l + b;
                    let gj = gi[s];
                    mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                    vi[s] = b2 * vi[s] + (1.0 - b2) * gj * gj;
                    let mh = mi[s] * bc1[b];
                    let vh = vi[s] * bc2[b];
                    wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
                }
            }
            return;
        }
        let group = |j: usize| -> usize {
            match k {
                KMode::None => j,
                KMode::FanIn => j / cols,
                KMode::FanOut => j % cols,
                KMode::Both => 0,
                KMode::Blocks(nb) => (j / cols) * nb / rows,
            }
        };
        let gsize = match k {
            KMode::None => 1.0,
            KMode::FanIn => cols as f32,
            KMode::FanOut => rows as f32,
            KMode::Both => (rows * cols) as f32,
            KMode::Blocks(nb) => ((rows / nb) * cols) as f32,
        };
        let vlen = vi.len() / l;
        let mut sums = vec![0.0f32; vlen * l];
        for j in 0..numel {
            let gr = group(j) * l;
            for b in 0..l {
                let gj = gi[j * l + b];
                sums[gr + b] += gj * gj;
            }
        }
        for jv in 0..vlen {
            for b in 0..l {
                let s = jv * l + b;
                vi[s] = b2 * vi[s] + (1.0 - b2) * (sums[s] / gsize);
            }
        }
        for j in 0..numel {
            let gr = group(j) * l;
            for b in 0..l {
                let s = j * l + b;
                let gj = gi[s];
                mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                let mh = mi[s] * bc1[b];
                let vh = vi[gr + b] * bc2[b];
                wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
            }
        }
    }
}

/// Per-lane fused reduced-V AdamW update over lane-major f32 state
/// (mirrors `fused_update`; each lane carries its own step index and
/// learning rate, so bias corrections are per lane).
///
/// Tensors are independent, so Simd mode distributes them across
/// `pool::intraop_workers()` via `pool::parallel_chunks`; each tensor's
/// update runs the identical scalar-order body ([`update_tensor`])
/// whichever worker executes it, so results are bitwise invariant in the
/// worker count. ScalarRef mode forces a single worker.
#[allow(clippy::too_many_arguments)]
pub fn fused_update_l(
    man: &Manifest,
    k_modes: &[KMode],
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    ts: &[usize],
    lrs: &[f32],
    l: usize,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let bc1: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b1.powi(t as i32))).collect();
    let bc2: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b2.powi(t as i32))).collect();
    let workers = match kernel_mode() {
        KernelMode::Simd => crate::pool::intraop_workers(),
        KernelMode::ScalarRef => 1,
    };
    let mut items: Vec<(usize, &mut [f32], &mut [f32], &mut [f32], &[f32])> = w
        .iter_mut()
        .zip(m.iter_mut())
        .zip(v.iter_mut())
        .zip(g.iter())
        .enumerate()
        .map(|(i, (((wi, mi), vi), gi))| {
            (
                i,
                wi.as_mut_slice(),
                mi.as_mut_slice(),
                vi.as_mut_slice(),
                gi.as_slice(),
            )
        })
        .collect();
    let t0 = crate::obs::clock();
    let n_tensors = items.len();
    crate::pool::parallel_chunks(&mut items, workers, |_, item| {
        let info = &man.params[item.0];
        let k = crate::optim::adamk::effective_k(info, k_modes[item.0]);
        update_tensor(
            info,
            k,
            h,
            &bc1,
            &bc2,
            lrs,
            &mut *item.1,
            &mut *item.2,
            &mut *item.3,
            item.4,
            l,
        );
    });
    if crate::obs::enabled() {
        let elems: usize = w.iter().map(|wi| wi.len()).sum();
        crate::obs::emit_since(
            crate::obs::SpanKind::IntraopChunk,
            crate::obs::intern("fused_update"),
            t0,
            [n_tensors as u64, elems as u64, 0, 0],
        );
    }
}

// ---------------------------------------------------------------------------
// Optimizer bake-off lane kernels
//
// One fused kernel per non-AdamW update rule, mirroring the split
// optimizers in `crate::optim` op for op (same FP op sequence in the
// same order, so split-vs-fused trajectories agree exactly on vector
// parameters and on 2-D matrices where view index == raw index — native
// builtins always, since every parameter has fan_out_axis 0, which
// manifest generation enforces). Each kernel follows the lane contract:
// element j of lane b lives at j*l + b, the per-lane op sequence depends
// only on the logical shape, and no operation mixes lanes — so `run` is
// the lanes = 1 instantiation and `run_batch` is bit-identical to
// sequential runs by construction.
// ---------------------------------------------------------------------------

/// Adafactor's epsilon_1 (inside g²) and RMS clip threshold d — shared
/// by the `adafactor` and `lowrank_v` lane kernels, matching the split
/// optimizers' constants.
const AF_EPS1: f32 = 1e-30;
const AF_CLIP_D: f32 = 1.0;

/// SM3's denominator epsilon, matching `optim::sm3::Sm3`.
const SM3_EPS: f32 = 1e-8;

/// Dispatch the fused per-lane update for this manifest's baked update
/// rule: the K-moded AdamW family when no `optimizer` field is present,
/// else the matching bake-off kernel.
#[allow(clippy::too_many_arguments)]
pub fn fused_optim_update_l(
    man: &Manifest,
    k_modes: &[KMode],
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    ts: &[usize],
    lrs: &[f32],
    l: usize,
) -> Result<()> {
    match man.optimizer_name() {
        "adamw" => fused_update_l(man, k_modes, h, w, m, v, g, ts, lrs, l),
        "lion" => lion_update_l(man, h, w, m, v, g, lrs, l),
        "sgdm" => sgdm_update_l(man, h, w, m, v, g, lrs, l),
        "sm3" => sm3_update_l(man, h, w, m, v, g, lrs, l),
        "adafactor" => adafactor_update_l(man, h, w, m, v, g, ts, lrs, l),
        other => match crate::optim::lowrank_v::parse_token(other) {
            Some(rank) => lowrank_update_l(man, h, rank, w, m, v, g, ts, lrs, l),
            None => bail!("native backend cannot execute fused optimizer {other:?}"),
        },
    }
    Ok(())
}

/// Distribute independent per-tensor updates across intra-op workers.
/// Every kernel body passed here runs strict scalar order inside a
/// tensor, so results are bitwise invariant in the worker count;
/// [`KernelMode::ScalarRef`] simply forces one worker.
fn per_tensor_update<F>(
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    span: &'static str,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32], &mut [f32], &[f32]) + Sync,
{
    let workers = match kernel_mode() {
        KernelMode::Simd => crate::pool::intraop_workers(),
        KernelMode::ScalarRef => 1,
    };
    let elems: usize = w.iter().map(|wi| wi.len()).sum();
    let mut items: Vec<(usize, &mut [f32], &mut [f32], &mut [f32], &[f32])> = w
        .iter_mut()
        .zip(m.iter_mut())
        .zip(v.iter_mut())
        .zip(g.iter())
        .enumerate()
        .map(|(i, (((wi, mi), vi), gi))| {
            (
                i,
                wi.as_mut_slice(),
                mi.as_mut_slice(),
                vi.as_mut_slice(),
                gi.as_slice(),
            )
        })
        .collect();
    let t0 = crate::obs::clock();
    let n_tensors = items.len();
    crate::pool::parallel_chunks(&mut items, workers, |_, item| {
        f(item.0, &mut *item.1, &mut *item.2, &mut *item.3, item.4)
    });
    if crate::obs::enabled() {
        crate::obs::emit_since(
            crate::obs::SpanKind::IntraopChunk,
            crate::obs::intern(span),
            t0,
            [n_tensors as u64, elems as u64, 0, 0],
        );
    }
}

/// Per-lane fused Lion update (mirrors `optim::lion::Lion`): sign of the
/// beta1 interpolation, decoupled weight decay, beta2 momentum EMA. No
/// second moment — `v` slices are zero-length.
#[allow(clippy::too_many_arguments)]
pub fn lion_update_l(
    man: &Manifest,
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    lrs: &[f32],
    l: usize,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    per_tensor_update(w, m, v, g, "lion_update", |i, wi, mi, _vi, gi| {
        let info = &man.params[i];
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        for j in 0..info.numel() {
            for b in 0..l {
                let s = j * l + b;
                let gj = gi[s];
                let interp = b1 * mi[s] + (1.0 - b1) * gj;
                let u = if interp > 0.0 {
                    1.0
                } else if interp < 0.0 {
                    -1.0
                } else {
                    0.0
                };
                wi[s] -= lrs[b] * (u + wd * wi[s]);
                mi[s] = b2 * mi[s] + (1.0 - b2) * gj;
            }
        }
    });
}

/// Per-lane fused SGD-momentum update (mirrors `optim::sgdm::SgdM`,
/// momentum = `hypers.beta1`). No second moment — `v` slices are
/// zero-length.
#[allow(clippy::too_many_arguments)]
pub fn sgdm_update_l(
    man: &Manifest,
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    lrs: &[f32],
    l: usize,
) {
    let mom = h.beta1 as f32;
    per_tensor_update(w, m, v, g, "sgdm_update", |i, wi, mi, _vi, gi| {
        let info = &man.params[i];
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        for j in 0..info.numel() {
            for b in 0..l {
                let s = j * l + b;
                mi[s] = mom * mi[s] + gi[s];
                wi[s] -= lrs[b] * (mi[s] + wd * wi[s]);
            }
        }
    });
}

/// Per-lane fused SM3 update (mirrors `optim::sm3::Sm3`, beta =
/// `hypers.beta2`, momentum = `hypers.beta1`): matrices store row+col
/// cover accumulators stacked `[rows..][cols..]` in `v`, vectors keep
/// exact accumulators; `m` is the momentum buffer.
#[allow(clippy::too_many_arguments)]
pub fn sm3_update_l(
    man: &Manifest,
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    lrs: &[f32],
    l: usize,
) {
    let beta = h.beta2 as f32;
    let mom = h.beta1 as f32;
    per_tensor_update(w, m, v, g, "sm3_update", |i, wi, mi, vi, gi| {
        let info = &man.params[i];
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        if info.is_vector() {
            for j in 0..info.numel() {
                for b in 0..l {
                    let s = j * l + b;
                    let gj = gi[s];
                    vi[s] = beta * vi[s] + (1.0 - beta) * gj * gj;
                    let pg = gj / (vi[s].sqrt() + SM3_EPS);
                    mi[s] = mom * mi[s] + (1.0 - mom) * pg;
                    wi[s] -= lrs[b] * (mi[s] + wd * wi[s]);
                }
            }
            return;
        }
        let (rows, cols) = info.matrix_dims();
        let (racc, cacc) = vi.split_at_mut(rows * l);
        let mut new_rows = vec![0.0f32; rows * l];
        let mut new_cols = vec![0.0f32; cols * l];
        for ri in 0..rows {
            for ci in 0..cols {
                for b in 0..l {
                    let s = (ri * cols + ci) * l + b;
                    let gj = gi[s];
                    let nu = beta * racc[ri * l + b].min(cacc[ci * l + b])
                        + (1.0 - beta) * gj * gj;
                    new_rows[ri * l + b] = new_rows[ri * l + b].max(nu);
                    new_cols[ci * l + b] = new_cols[ci * l + b].max(nu);
                    let pg = gj / (nu.sqrt() + SM3_EPS);
                    mi[s] = mom * mi[s] + (1.0 - mom) * pg;
                    wi[s] -= lrs[b] * (mi[s] + wd * wi[s]);
                }
            }
        }
        racc.copy_from_slice(&new_rows);
        cacc.copy_from_slice(&new_cols);
    });
}

/// Per-lane fused Adafactor-v1 update (mirrors `optim::adafactor` with
/// `use_momentum = false`): factored row+col EMAs stacked
/// `[rows..][cols..]` in `v`, time-dependent decay `1 - t^-0.8`, RMS
/// update clipping with f64 square accumulation. No momentum — `m`
/// slices are zero-length.
#[allow(clippy::too_many_arguments)]
pub fn adafactor_update_l(
    man: &Manifest,
    h: &Hypers,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    ts: &[usize],
    lrs: &[f32],
    l: usize,
) {
    let beta2t: Vec<f32> = ts.iter().map(|&t| 1.0 - (t as f32).powf(-0.8)).collect();
    per_tensor_update(w, m, v, g, "adafactor_update", |i, wi, _mi, vi, gi| {
        let info = &man.params[i];
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        let numel = info.numel();
        let mut u = vec![0.0f32; numel * l];
        if info.is_vector() {
            for j in 0..numel {
                for b in 0..l {
                    let s = j * l + b;
                    let g2 = gi[s] * gi[s] + AF_EPS1;
                    vi[s] = beta2t[b] * vi[s] + (1.0 - beta2t[b]) * g2;
                    u[s] = gi[s] / vi[s].sqrt();
                }
            }
        } else {
            let (rows, cols) = info.matrix_dims();
            let (racc, cacc) = vi.split_at_mut(rows * l);
            let mut rsum = vec![0.0f32; rows * l];
            let mut csum = vec![0.0f32; cols * l];
            for ri in 0..rows {
                for ci in 0..cols {
                    for b in 0..l {
                        let gj = gi[(ri * cols + ci) * l + b];
                        let g2 = gj * gj + AF_EPS1;
                        rsum[ri * l + b] += g2;
                        csum[ci * l + b] += g2;
                    }
                }
            }
            for k in 0..rows {
                for b in 0..l {
                    let s = k * l + b;
                    racc[s] = beta2t[b] * racc[s] + (1.0 - beta2t[b]) * rsum[s];
                }
            }
            for k in 0..cols {
                for b in 0..l {
                    let s = k * l + b;
                    cacc[s] = beta2t[b] * cacc[s] + (1.0 - beta2t[b]) * csum[s];
                }
            }
            let mut rtot = vec![0.0f32; l];
            for k in 0..rows {
                for b in 0..l {
                    rtot[b] += racc[k * l + b];
                }
            }
            for ri in 0..rows {
                for ci in 0..cols {
                    for b in 0..l {
                        let s = (ri * cols + ci) * l + b;
                        let vv = (racc[ri * l + b] * cacc[ci * l + b]
                            / rtot[b].max(AF_EPS1))
                        .max(AF_EPS1);
                        u[s] = gi[s] / vv.sqrt();
                    }
                }
            }
        }
        // RMS clipping per lane: u /= max(1, RMS(u)/d). Squares stay in
        // f32 before the f64 accumulation, matching the split optimizer.
        let mut sums = vec![0.0f64; l];
        for j in 0..numel {
            for b in 0..l {
                let x = u[j * l + b];
                sums[b] += (x * x) as f64;
            }
        }
        let scale: Vec<f32> = sums
            .iter()
            .map(|&s| {
                let rms = (s / numel as f64).sqrt() as f32;
                1.0 / (rms / AF_CLIP_D).max(1.0)
            })
            .collect();
        for j in 0..numel {
            for b in 0..l {
                let s = j * l + b;
                wi[s] -= lrs[b] * (u[s] * scale[b] + wd * wi[s]);
            }
        }
    });
}

/// Per-lane fused low-rank-V update (mirrors `optim::lowrank_v::LowRankV`):
/// matrices store the rank-r sketch `Y (rows×r)` row-major then `C (cols)`
/// stacked in `v`, with the deterministic column buckets shared with the
/// split optimizer via [`crate::optim::lowrank_v::bucket_of`]; vectors run
/// exact AdamW. Full bias-corrected momentum in `m`.
#[allow(clippy::too_many_arguments)]
pub fn lowrank_update_l(
    man: &Manifest,
    h: &Hypers,
    rank: usize,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    g: &[Vec<f32>],
    ts: &[usize],
    lrs: &[f32],
    l: usize,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let bc1: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b1.powi(t as i32))).collect();
    let bc2: Vec<f32> = ts.iter().map(|&t| 1.0 / (1.0 - b2.powi(t as i32))).collect();
    per_tensor_update(w, m, v, g, "lowrank_update", |i, wi, mi, vi, gi| {
        let info = &man.params[i];
        let wd = if info.wd { h.weight_decay as f32 } else { 0.0 };
        if info.is_vector() {
            for j in 0..info.numel() {
                for b in 0..l {
                    let s = j * l + b;
                    let gj = gi[s];
                    mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                    vi[s] = b2 * vi[s] + (1.0 - b2) * gj * gj;
                    let mh = mi[s] * bc1[b];
                    let vh = vi[s] * bc2[b];
                    wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
                }
            }
            return;
        }
        let (rows, cols) = info.matrix_dims();
        let buckets: Vec<usize> = (0..cols)
            .map(|j| crate::optim::lowrank_v::bucket_of(&info.name, rank, j))
            .collect();
        let (yacc, cacc) = vi.split_at_mut(rows * rank * l);
        let mut ysum = vec![0.0f32; rows * rank * l];
        let mut csum = vec![0.0f32; cols * l];
        for ri in 0..rows {
            for ci in 0..cols {
                for b in 0..l {
                    let gj = gi[(ri * cols + ci) * l + b];
                    let g2 = gj * gj + AF_EPS1;
                    ysum[(ri * rank + buckets[ci]) * l + b] += g2;
                    csum[ci * l + b] += g2;
                }
            }
        }
        for k in 0..rows * rank {
            for b in 0..l {
                let s = k * l + b;
                yacc[s] = b2 * yacc[s] + (1.0 - b2) * ysum[s];
            }
        }
        for k in 0..cols {
            for b in 0..l {
                let s = k * l + b;
                cacc[s] = b2 * cacc[s] + (1.0 - b2) * csum[s];
            }
        }
        let mut bsum = vec![0.0f32; rank * l];
        for ci in 0..cols {
            for b in 0..l {
                bsum[buckets[ci] * l + b] += cacc[ci * l + b];
            }
        }
        for ri in 0..rows {
            for ci in 0..cols {
                for b in 0..l {
                    let s = (ri * cols + ci) * l + b;
                    let bk = buckets[ci];
                    let vv = (yacc[(ri * rank + bk) * l + b] * cacc[ci * l + b]
                        / bsum[bk * l + b].max(AF_EPS1))
                    .max(AF_EPS1);
                    let gj = gi[s];
                    mi[s] = b1 * mi[s] + (1.0 - b1) * gj;
                    let mh = mi[s] * bc1[b];
                    let vh = vv * bc2[b];
                    wi[s] -= lrs[b] * (mh / (vh.sqrt() + eps) + wd * wi[s]);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn init_params(man: &Manifest, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        man.params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect()
    }

    /// Family-appropriate random batch for one job.
    fn sample_batch(dims: &Dims, seed: u64) -> BatchIn {
        let mut rng = Rng::new(seed);
        match dims.family {
            Family::Conv => {
                let px = dims.img * dims.img * dims.channels;
                let x = (0..dims.batch * px)
                    .map(|_| rng.uniform(-1.0, 1.0) as f32)
                    .collect();
                let y = (0..dims.batch)
                    .map(|_| rng.below(dims.vocab as u64) as i32)
                    .collect();
                BatchIn::Images { x, y }
            }
            _ => {
                let n = dims.batch * dims.ctx;
                let mut draw =
                    || (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
                BatchIn::Tokens { x: draw(), y: draw() }
            }
        }
    }

    /// Batch literals in manifest order for one job.
    fn batch_literals(dims: &Dims, b: &BatchIn) -> Vec<Literal> {
        match b {
            BatchIn::Tokens { x, y } => vec![
                crate::runtime::literal::i32_literal(x, &[dims.batch, dims.ctx]).unwrap(),
                crate::runtime::literal::i32_literal(y, &[dims.batch, dims.ctx]).unwrap(),
            ],
            BatchIn::Images { x, y } => vec![
                crate::runtime::literal::f32_literal(
                    x,
                    &[dims.batch, dims.img, dims.img, dims.channels],
                )
                .unwrap(),
                crate::runtime::literal::i32_literal(y, &[dims.batch]).unwrap(),
            ],
        }
    }

    #[test]
    fn manifests_generate_and_validate() {
        for model in MODELS {
            let grad = artifact(&format!("{model}.grad")).unwrap();
            assert_eq!(grad.manifest.kind, "grad_step");
            assert!(grad.manifest_hash != 0);
            for ruleset in RULESETS {
                let train = artifact(&format!("{model}.train.{ruleset}")).unwrap();
                assert_eq!(train.manifest.kind, "train_step");
                assert_eq!(train.manifest.ruleset.as_deref(), Some(*ruleset));
                // AdamW family: no optimizer field, full-shape moments
                assert_eq!(train.manifest.optimizer_name(), "adamw");
                assert!(train.manifest.m_shapes.is_none());
                // grad and train agree on params/batch, differ in hash
                assert_eq!(train.manifest.n_params(), grad.manifest.n_params());
                assert_ne!(train.manifest_hash, grad.manifest_hash);
            }
            for opt in OPTIMIZERS {
                let train = artifact(&format!("{model}.train.{opt}")).unwrap();
                assert_eq!(train.manifest.kind, "train_step");
                assert_eq!(train.manifest.optimizer_name(), *opt);
                assert_eq!(train.manifest.n_params(), grad.manifest.n_params());
            }
        }
        assert!(artifact("mlp_tiny.nonsense").is_err());
        assert!(artifact("no_such_model.grad").is_err());
        // explicit-rank lowrank tokens parse too
        let man = train_manifest("mlp_tiny", "lowrank_v2").unwrap();
        assert_eq!(man.optimizer_name(), "lowrank_v2");
    }

    /// Baked optimizer state layouts match the split optimizers' exact
    /// element counts — `optim::memory::report` over the live optimizer
    /// and `report_manifest` over the fused artifact must agree, for
    /// every model and bake-off token.
    #[test]
    fn optimizer_manifest_state_matches_split_accounting() {
        for model in MODELS {
            let grad = grad_manifest(model).unwrap();
            let total = grad.total_param_elems();
            for opt in OPTIMIZERS {
                let man = train_manifest(model, opt).unwrap();
                let fused = crate::optim::memory::report_manifest(&man).unwrap();
                let split =
                    crate::optim::presets::build(opt, &grad, man.hypers.unwrap_or_default())
                        .unwrap();
                let split = crate::optim::memory::report(split.as_ref(), total);
                assert_eq!(
                    (fused.m_elems, fused.v_elems),
                    (split.m_elems, split.v_elems),
                    "{model}.{opt}: fused state layout disagrees with split"
                );
                assert_eq!(fused.param_elems, total, "{model}.{opt}");
            }
        }
    }

    #[test]
    fn manifest_hash_is_stable() {
        let a = artifact("gpt_micro.grad").unwrap();
        let b = artifact("gpt_micro.grad").unwrap();
        assert_eq!(a.manifest_hash, b.manifest_hash);
    }

    #[test]
    fn slimadam_ruleset_saves_memory() {
        let v_elems = |m: &Manifest| -> usize {
            m.v_shapes
                .as_ref()
                .unwrap()
                .iter()
                .map(|s| s.iter().product::<usize>())
                .sum()
        };
        // exact per-family footprints — these pin the EXPERIMENTS.md
        // memory-accounting table
        for (model, total, slim_v) in [
            ("mlp_tiny", 3072usize, 176usize),
            ("gpt_micro", 5296, 448),
            ("gpt_deep", 10512, 848),
            ("conv_mini", 1456, 34),
        ] {
            let adam = artifact(&format!("{model}.train.adam")).unwrap();
            let slim = artifact(&format!("{model}.train.slimadam")).unwrap();
            let full = v_elems(&adam.manifest);
            let reduced = v_elems(&slim.manifest);
            assert_eq!(full, adam.manifest.total_param_elems());
            assert_eq!(full, total, "{model}: param count drifted");
            assert_eq!(reduced, slim_v, "{model}: slimadam V footprint drifted");
            assert!(
                (reduced as f64) < 0.2 * full as f64,
                "{model}: slimadam v_elems {reduced} vs adam {full}"
            );
        }
    }

    /// fig3's depth axis: `gpt_deep` has per-block named parameters at
    /// depths 0..=3 with embeddings at -1 and the head at 4; `gpt_micro`
    /// stays the 1-block instantiation of the same layout.
    #[test]
    fn gpt_deep_depth_axis_is_real() {
        let man = grad_manifest("gpt_deep").unwrap();
        assert_eq!(man.n_params(), 2 + 8 * 4 + 2);
        let depths: std::collections::BTreeSet<i64> =
            man.params.iter().map(|p| p.depth).collect();
        assert_eq!(
            depths.into_iter().collect::<Vec<_>>(),
            vec![-1, 0, 1, 2, 3, 4]
        );
        for b in 0..4 {
            assert!(
                man.params.iter().any(|p| p.name == format!("h{b}.attn_q")),
                "missing block {b}"
            );
        }
        let micro = grad_manifest("gpt_micro").unwrap();
        assert_eq!(micro.n_params(), 12);
        assert_eq!(micro.params[2].name, "h0.ln_attn");
    }

    /// conv geometry contract: OIHW weights, NHWC f32 image batch, one
    /// label per sample, and the matrix view `(C_out, C_in·kh·kw)` the
    /// k_mode rules compress over.
    #[test]
    fn conv_manifest_geometry() {
        let man = grad_manifest("conv_mini").unwrap();
        assert_eq!(man.family, "conv");
        assert_eq!(man.params[0].shape, vec![8, 2, 3, 3]);
        assert_eq!(man.params[1].shape, vec![16, 8, 3, 3]);
        assert_eq!(man.params[2].shape, vec![10, 16]); // 8x8 -> 6 -> 3 -> 1
        assert_eq!(man.batch[0].dtype, "f32");
        assert_eq!(man.batch[0].shape, vec![8, 8, 8, 2]);
        assert_eq!(man.batch[1].shape, vec![8]);
        assert_eq!(man.params[0].matrix_dims(), (8, 18));
        assert_eq!(man.token_bound(), 10);
    }

    /// Central-difference gradient check for every model family: the
    /// handwritten backward passes must match the loss surface.
    #[test]
    fn gradients_match_finite_differences() {
        for model in MODELS {
            let dims = dims_for(model).unwrap();
            let man = grad_manifest(model).unwrap();
            let params = init_params(&man, 11);
            let batch = sample_batch(&dims, 12);
            let (_, grads) = loss_and_grads(&dims, &params, &batch);
            let mut rng = Rng::new(13);
            let eps = 1e-3f32;
            for (pi, p) in params.iter().enumerate() {
                // probe a handful of coordinates per tensor
                for _ in 0..4 {
                    let j = rng.usize_below(p.numel());
                    let mut plus = params.clone();
                    plus[pi].data[j] += eps;
                    let mut minus = params.clone();
                    minus[pi].data[j] -= eps;
                    let fd = (loss_and_grads(&dims, &plus, &batch).0
                        - loss_and_grads(&dims, &minus, &batch).0)
                        / (2.0 * eps as f64);
                    let an = grads[pi].data[j] as f64;
                    assert!(
                        (fd - an).abs() <= 1e-4 + 5e-2 * an.abs().max(fd.abs()),
                        "{model} param {pi} ({}) elem {j}: fd {fd} vs analytic {an}",
                        man.params[pi].name
                    );
                }
            }
        }
    }

    #[test]
    fn grad_step_is_deterministic() {
        for model in ["gpt_deep", "conv_mini"] {
            let dims = dims_for(model).unwrap();
            let man = grad_manifest(model).unwrap();
            let params = init_params(&man, 3);
            let batch = sample_batch(&dims, 4);
            let (l1, g1) = loss_and_grads(&dims, &params, &batch);
            let (l2, g2) = loss_and_grads(&dims, &params, &batch);
            assert_eq!(l1.to_bits(), l2.to_bits(), "{model}");
            for (a, b) in g1.iter().zip(&g2) {
                assert_eq!(a.data, b.data, "{model}");
            }
        }
    }

    #[test]
    fn executable_runs_grad_for_every_model() {
        for model in MODELS {
            let backend = NativeBackend::default();
            let art = artifact(&format!("{model}.grad")).unwrap();
            let exe = backend.compile(&art).unwrap();
            let man = &art.manifest;
            let dims = dims_for(model).unwrap();
            let params = init_params(man, 5);
            let mut inputs: Vec<Literal> = params
                .iter()
                .map(|t| tensor_to_literal(t).unwrap())
                .collect();
            inputs.extend(batch_literals(&dims, &sample_batch(&dims, 6)));
            let outs = exe.run(&inputs).unwrap();
            assert_eq!(outs.len(), 1 + man.n_params());
            let loss = crate::runtime::literal::scalar_value(&outs[0]).unwrap();
            // random inputs: loss should start near ln(vocab/classes)
            assert!(
                (loss as f64 - (dims.vocab as f64).ln()).abs() < 1.0,
                "{model}: {loss}"
            );
        }
    }

    /// Fused training on one repeated batch must reduce loss for every
    /// family — MLP, deep transformer and conv alike.
    #[test]
    fn fused_train_step_decreases_loss() {
        use crate::runtime::engine::{BatchData, TrainEngine};
        for (model, lr) in [("mlp_tiny", 3e-3f32), ("gpt_deep", 1e-3), ("conv_mini", 3e-3)] {
            let backend = NativeBackend::default();
            let art = artifact(&format!("{model}.train.adam")).unwrap();
            let compiled = std::rc::Rc::new(art.compile(&backend).unwrap());
            let mut eng = TrainEngine::with_compiled(compiled, "mitchell", 7).unwrap();
            let dims = dims_for(model).unwrap();
            let b = match sample_batch(&dims, 8) {
                BatchIn::Tokens { x, y } => vec![BatchData::I32(x), BatchData::I32(y)],
                BatchIn::Images { x, y } => vec![BatchData::F32(x), BatchData::I32(y)],
            };
            let first = eng.step(&b, lr).unwrap();
            let mut last = first;
            for _ in 0..30 {
                last = eng.step(&b, lr).unwrap();
            }
            assert!(first.loss.is_finite() && last.grad_norm.is_finite(), "{model}");
            assert!(
                last.loss < first.loss,
                "{model}: native fused step did not reduce loss: {} -> {}",
                first.loss,
                last.loss
            );
        }
    }

    /// Split-vs-fused optimizer identity: each bake-off lane kernel
    /// mirrors its split optimizer op for op, so feeding both the same
    /// clipped gradients must produce bit-identical parameters (native
    /// builtins all have fan_out_axis 0, where matrix-view index == raw
    /// index).
    #[test]
    fn fused_optimizer_kernels_match_split_optimizers() {
        for token in ["lion", "sgdm", "sm3", "adafactor", "lowrank_v", "lowrank_v2"] {
            let man = train_manifest("gpt_micro", token).unwrap();
            let hypers = man.hypers.unwrap_or_default();
            let k_modes = man.k_modes.clone().unwrap();
            let mut split = crate::optim::presets::build(token, &man, hypers).unwrap();
            let mut params = init_params(&man, 41);
            let mut w_l: Vec<Vec<f32>> = params.iter().map(|t| t.data.clone()).collect();
            let n = man.n_params();
            let mut m_l: Vec<Vec<f32>> = (0..n)
                .map(|i| vec![0.0; man.m_shape(i).iter().product()])
                .collect();
            let mut v_l: Vec<Vec<f32>> = man
                .v_shapes
                .as_ref()
                .unwrap()
                .iter()
                .map(|s| vec![0.0; s.iter().product()])
                .collect();
            let mut rng = Rng::new(43);
            for t in 1..=5usize {
                let mut grads: Vec<Tensor> = man
                    .params
                    .iter()
                    .map(|p| {
                        Tensor::from_vec(
                            &p.shape,
                            (0..p.numel()).map(|_| rng.normal() as f32).collect(),
                        )
                    })
                    .collect();
                crate::optim::clip_global_norm(&mut grads, hypers.clip_norm);
                let g_l: Vec<Vec<f32>> = grads.iter().map(|g| g.data.clone()).collect();
                split.step(&mut params, &grads, t, 1e-3);
                fused_optim_update_l(
                    &man,
                    &k_modes,
                    &hypers,
                    &mut w_l,
                    &mut m_l,
                    &mut v_l,
                    &g_l,
                    &[t],
                    &[1e-3],
                    1,
                )
                .unwrap();
                for (i, (p, wl)) in params.iter().zip(&w_l).enumerate() {
                    let a: Vec<u32> = p.data.iter().map(|x| x.to_bits()).collect();
                    let b: Vec<u32> = wl.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(a, b, "{token} t={t} param {i} ({})", man.params[i].name);
                }
            }
        }
    }

    /// Every bake-off optimizer trains end-to-end through the fused
    /// engine on one repeated batch.
    #[test]
    fn bakeoff_optimizers_train_fused() {
        use crate::runtime::engine::{BatchData, TrainEngine};
        let dims = dims_for("mlp_tiny").unwrap();
        let b = match sample_batch(&dims, 8) {
            BatchIn::Tokens { x, y } => vec![BatchData::I32(x), BatchData::I32(y)],
            BatchIn::Images { x, y } => vec![BatchData::F32(x), BatchData::I32(y)],
        };
        for token in OPTIMIZERS {
            let backend = NativeBackend::default();
            let art = artifact(&format!("mlp_tiny.train.{token}")).unwrap();
            let compiled = std::rc::Rc::new(art.compile(&backend).unwrap());
            let mut eng = TrainEngine::with_compiled(compiled, "mitchell", 7).unwrap();
            // Lion's sign updates move every weight by the full LR; give
            // it the customary ~10x smaller step.
            let lr = if *token == "lion" { 3e-4 } else { 3e-3 };
            let first = eng.step(&b, lr).unwrap();
            let mut last = first;
            for _ in 0..40 {
                last = eng.step(&b, lr).unwrap();
            }
            assert!(first.loss.is_finite() && last.grad_norm.is_finite(), "{token}");
            assert!(
                last.loss < first.loss,
                "{token}: fused step did not reduce loss: {} -> {}",
                first.loss,
                last.loss
            );
        }
    }

    /// The lowrank_v sketch is a pure function of (name, rank, col):
    /// same seed, same trajectory, bit for bit.
    #[test]
    fn lowrank_fused_same_seed_is_bit_identical() {
        use crate::runtime::engine::{BatchData, TrainEngine};
        let dims = dims_for("mlp_tiny").unwrap();
        let b = match sample_batch(&dims, 9) {
            BatchIn::Tokens { x, y } => vec![BatchData::I32(x), BatchData::I32(y)],
            BatchIn::Images { x, y } => vec![BatchData::F32(x), BatchData::I32(y)],
        };
        let run = || {
            let backend = NativeBackend::default();
            let art = artifact("mlp_tiny.train.lowrank_v").unwrap();
            let compiled = std::rc::Rc::new(art.compile(&backend).unwrap());
            let mut eng = TrainEngine::with_compiled(compiled, "mitchell", 11).unwrap();
            (0..10)
                .map(|_| eng.step(&b, 1e-3).unwrap().loss.to_bits())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "same seed must give a bit-identical trajectory");
    }

    /// The lane-stacked batched interpreter must be bit-for-bit identical
    /// to sequential `run` calls — for every model family, both manifest
    /// kinds and every ruleset, with per-lane step/lr scalars differing.
    #[test]
    fn run_batch_bit_identical_to_sequential() {
        fn lit_bits(lit: &Literal) -> (Vec<i64>, Vec<u32>) {
            let dims = lit.array_shape().unwrap().dims().to_vec();
            let bits = lit
                .to_vec::<f32>()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            (dims, bits)
        }
        fn assert_jobs_eq(seq: &[Vec<Literal>], bat: &[Vec<Literal>], what: &str) {
            assert_eq!(seq.len(), bat.len(), "{what}");
            for (b, (s, t)) in seq.iter().zip(bat).enumerate() {
                assert_eq!(s.len(), t.len(), "{what} job {b}");
                for (slot, (a, c)) in s.iter().zip(t).enumerate() {
                    assert_eq!(lit_bits(a), lit_bits(c), "{what} job {b} output {slot}");
                }
            }
        }

        let backend = NativeBackend::default();
        for model in MODELS {
            let dims = dims_for(model).unwrap();

            // grad_step
            let art = artifact(&format!("{model}.grad")).unwrap();
            let exe = backend.compile(&art).unwrap();
            let man = art.manifest.clone();
            let jobs: Vec<Vec<Literal>> = (0..3)
                .map(|jj| {
                    let params = init_params(&man, 100 + jj as u64);
                    let mut inputs: Vec<Literal> = params
                        .iter()
                        .map(|t| tensor_to_literal(t).unwrap())
                        .collect();
                    inputs
                        .extend(batch_literals(&dims, &sample_batch(&dims, 200 + jj as u64)));
                    inputs
                })
                .collect();
            let seq: Vec<Vec<Literal>> = jobs.iter().map(|j| exe.run(j).unwrap()).collect();
            let bat = exe.run_batch(&jobs).unwrap();
            assert_jobs_eq(&seq, &bat, &format!("{model}.grad"));

            // train_step × every ruleset and bake-off optimizer, lanes at
            // different t / lr and non-zero moments so per-lane bias
            // corrections matter
            for token in RULESETS.iter().chain(OPTIMIZERS.iter()) {
                let art = artifact(&format!("{model}.train.{token}")).unwrap();
                let exe = backend.compile(&art).unwrap();
                let man = art.manifest.clone();
                let v_shapes = man.v_shapes.clone().unwrap();
                let jobs: Vec<Vec<Literal>> = (0..3)
                    .map(|jj| {
                        let mut rng = Rng::new(300 + jj as u64);
                        let mut inputs: Vec<Literal> = Vec::new();
                        for p in &man.params {
                            inputs.push(
                                tensor_to_literal(
                                    &p.init_mitchell.materialize(&p.shape, &mut rng),
                                )
                                .unwrap(),
                            );
                        }
                        for i in 0..man.n_params() {
                            inputs.push(
                                tensor_to_literal(&Tensor::full(
                                    man.m_shape(i),
                                    0.01 * (jj + 1) as f32,
                                ))
                                .unwrap(),
                            );
                        }
                        for vs in &v_shapes {
                            inputs.push(
                                tensor_to_literal(&Tensor::full(vs, 0.002 * (jj + 1) as f32))
                                    .unwrap(),
                            );
                        }
                        inputs.extend(batch_literals(
                            &dims,
                            &sample_batch(&dims, 400 + jj as u64),
                        ));
                        inputs.push(scalar_f32((jj + 1) as f32));
                        inputs.push(scalar_f32(1e-3 * (jj + 1) as f32));
                        inputs
                    })
                    .collect();
                let seq: Vec<Vec<Literal>> =
                    jobs.iter().map(|j| exe.run(j).unwrap()).collect();
                let bat = exe.run_batch(&jobs).unwrap();
                assert_jobs_eq(&seq, &bat, &format!("{model}.train.{token}"));
            }
        }
    }

    #[test]
    fn run_batch_single_job_delegates_to_run() {
        let backend = NativeBackend::default();
        let art = artifact("mlp_tiny.grad").unwrap();
        let exe = backend.compile(&art).unwrap();
        let man = art.manifest.clone();
        let dims = dims_for("mlp_tiny").unwrap();
        let params = init_params(&man, 9);
        let mut inputs: Vec<Literal> = params
            .iter()
            .map(|t| tensor_to_literal(t).unwrap())
            .collect();
        inputs.extend(batch_literals(&dims, &sample_batch(&dims, 10)));
        let seq = exe.run(&inputs).unwrap();
        let bat = exe.run_batch(std::slice::from_ref(&inputs)).unwrap();
        assert_eq!(bat.len(), 1);
        let loss_a = crate::runtime::literal::scalar_value(&seq[0]).unwrap();
        let loss_b = crate::runtime::literal::scalar_value(&bat[0][0]).unwrap();
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
    }

    #[test]
    fn hlo_artifacts_rejected() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("linear2_v64.grad.hlo.txt").exists() {
            return;
        }
        let art = Artifact::load(dir, "linear2_v64.grad").unwrap();
        let err = NativeBackend::default().compile(&art).unwrap_err();
        assert!(format!("{err}").contains("builtin"), "{err}");
    }

    /// KernelMode is thread-local: flipping it on one thread must not
    /// leak into concurrently running tests (libtest runs this binary's
    /// tests in parallel).
    #[test]
    fn kernel_mode_is_thread_local() {
        assert_eq!(kernel_mode(), KernelMode::Simd);
        set_kernel_mode(KernelMode::ScalarRef);
        assert_eq!(kernel_mode(), KernelMode::ScalarRef);
        let other = std::thread::spawn(kernel_mode).join().unwrap();
        assert_eq!(other, KernelMode::Simd, "mode leaked across threads");
        set_kernel_mode(KernelMode::Simd);
    }

    /// SIMD tree reductions vs. the scalar-order reference: identical
    /// losses/gradients to reassociation tolerance for every family
    /// (the per-kernel property harness lives in
    /// `rust/tests/kernel_equivalence.rs`; this is the end-to-end smoke).
    #[test]
    fn simd_kernels_match_scalar_reference() {
        for model in MODELS {
            let dims = dims_for(model).unwrap();
            let man = grad_manifest(model).unwrap();
            let params = init_params(&man, 21);
            let batch = sample_batch(&dims, 22);
            set_kernel_mode(KernelMode::ScalarRef);
            let (l_ref, g_ref) = loss_and_grads(&dims, &params, &batch);
            set_kernel_mode(KernelMode::Simd);
            let (l_simd, g_simd) = loss_and_grads(&dims, &params, &batch);
            assert!(
                (l_ref - l_simd).abs() <= 1e-9 * l_ref.abs().max(1.0),
                "{model}: loss {l_ref} vs {l_simd}"
            );
            for ((a, b), p) in g_ref.iter().zip(&g_simd).zip(&man.params) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert!(
                        (x - y).abs() <= 1e-5 + 1e-4 * x.abs(),
                        "{model} {}: {x} vs {y}",
                        p.name
                    );
                }
            }
        }
    }

    /// `--precision f32` lands near the f64 verify reference and is
    /// itself bitwise deterministic.
    #[test]
    fn f32_precision_matches_f64_within_tolerance() {
        for model in MODELS {
            let art = artifact(&format!("{model}.grad")).unwrap();
            let dims = dims_for(model).unwrap();
            let params = init_params(&art.manifest, 31);
            let mut inputs: Vec<Literal> = params
                .iter()
                .map(|t| tensor_to_literal(t).unwrap())
                .collect();
            inputs.extend(batch_literals(&dims, &sample_batch(&dims, 32)));
            let exe64 = NativeBackend::default().compile(&art).unwrap();
            let exe32 = NativeBackend::with_precision(DeviceTag::Cpu(0), Precision::F32)
                .compile(&art)
                .unwrap();
            let o64 = exe64.run(&inputs).unwrap();
            let o32 = exe32.run(&inputs).unwrap();
            let l64 = crate::runtime::literal::scalar_value(&o64[0]).unwrap();
            let l32 = crate::runtime::literal::scalar_value(&o32[0]).unwrap();
            assert!(
                (l64 - l32).abs() <= 2e-3 + 2e-3 * l64.abs(),
                "{model}: f64 loss {l64} vs f32 loss {l32}"
            );
            let o32b = exe32.run(&inputs).unwrap();
            let again = crate::runtime::literal::scalar_value(&o32b[0]).unwrap();
            assert_eq!(l32.to_bits(), again.to_bits(), "{model}: f32 not deterministic");
        }
    }
}
