//! Backend trait layer: device-tagged execution API (DESIGN.md §11).
//!
//! Every execution path used to be hardwired to the PJRT CPU client; this
//! module abstracts "something that can turn an [`Artifact`] into an
//! executable step function" behind two traits:
//!
//! * [`Backend`] — a compiler bound to one [`DeviceTag`]. Two
//!   implementations ship:
//!   * [`pjrt::PjrtBackend`] (cargo feature `pjrt`, on by default) — the
//!     `vendor/xla` path: HLO text → `PjRtClient::compile`. Swapping the
//!     vendored stub for the real `xla_extension` bindings lights this up
//!     without touching coordinator code.
//!   * [`native::NativeBackend`] (always available) — a pure-Rust
//!     interpreter of the manifest's model family (MLP and a small
//!     transformer, fwd/bwd with global-norm clipping), so
//!     `slimadam run/sweep --backend native` trains end to end offline
//!     with no artifacts and no PJRT.
//! * [`Executable`] — a compiled step function. `GradEngine` /
//!   `TrainEngine` consume it generically through
//!   [`super::engine::Compiled`]; they never know which backend produced
//!   it.
//!
//! A [`BackendSpec`] names a `(kind, device, precision)` triple. It is
//! carried by
//! `TrainConfig`, hashed into `runstore::config_key`, and is part of the
//! executable-cache key and the sweep scheduler's shard key — so mixed
//! device pools schedule and resume correctly (`coordinator::exec_cache`).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::fmt;
use std::rc::Rc;

use anyhow::{bail, Result};
use xla::Literal;

use super::engine::Artifact;

/// Which physical device a backend executes on. Today only CPU backends
/// exist; the tag is threaded through every cache/shard key so GPU/TPU
/// pools slot in without another rekeying pass (ROADMAP "multi-backend
/// scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceTag {
    Cpu(u16),
    Gpu(u16),
    Tpu(u16),
}

impl DeviceTag {
    /// Parse `"cpu"`, `"cpu:0"`, `"gpu:1"`, `"tpu:3"`.
    pub fn parse(s: &str) -> Result<DeviceTag> {
        let (kind, idx) = match s.split_once(':') {
            Some((k, i)) => {
                let idx: u16 = i
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad device index in {s:?}"))?;
                (k, idx)
            }
            None => (s, 0),
        };
        Ok(match kind {
            "cpu" => DeviceTag::Cpu(idx),
            "gpu" => DeviceTag::Gpu(idx),
            "tpu" => DeviceTag::Tpu(idx),
            other => bail!("unknown device kind {other:?} (want cpu/gpu/tpu)"),
        })
    }
}

impl fmt::Display for DeviceTag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeviceTag::Cpu(i) => write!(f, "cpu:{i}"),
            DeviceTag::Gpu(i) => write!(f, "gpu:{i}"),
            DeviceTag::Tpu(i) => write!(f, "tpu:{i}"),
        }
    }
}

/// Compute precision of a backend's interpreter (DESIGN.md §14).
///
/// `F64` is the verify reference — the seed repo's only mode, so its
/// spec keys are unchanged. `F32` is the opt-in fast mode
/// (`--precision f32`): same kernels instantiated at f32, deterministic
/// for a fixed `(lanes, workers, precision)` triple but *not* expected
/// to match f64 bitwise — differential suites always compare within one
/// precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Precision {
    /// f64 compute, the verify reference (default).
    #[default]
    F64,
    /// f32 compute, opt-in via `--precision f32` / `"native+f32"`.
    F32,
}

impl Precision {
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse `"f64"` / `"f32"`.
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f64" => Precision::F64,
            "f32" => Precision::F32,
            other => bail!("unknown precision {other:?} (want f64 or f32)"),
        })
    }
}

/// Which backend implementation compiles and runs artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BackendKind {
    /// `vendor/xla` PJRT path (HLO artifacts; cargo feature `pjrt`).
    Pjrt,
    /// Pure-Rust manifest interpreter (builtin models; always available).
    Native,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        }
    }
}

/// A `(backend kind, device, precision)` triple — the unit of execution
/// identity. Part of `TrainConfig`, the run-store config key, the
/// executable-cache key and the scheduler shard key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BackendSpec {
    pub kind: BackendKind,
    pub device: DeviceTag,
    pub precision: Precision,
}

impl Default for BackendSpec {
    /// The PJRT CPU path — the seed repo's only execution path, so
    /// existing configs, tests and stored run keys keep their meaning.
    fn default() -> Self {
        BackendSpec::pjrt()
    }
}

impl BackendSpec {
    pub fn pjrt() -> BackendSpec {
        BackendSpec {
            kind: BackendKind::Pjrt,
            device: DeviceTag::Cpu(0),
            precision: Precision::F64,
        }
    }

    pub fn native() -> BackendSpec {
        BackendSpec {
            kind: BackendKind::Native,
            device: DeviceTag::Cpu(0),
            precision: Precision::F64,
        }
    }

    /// The native interpreter in its opt-in f32 compute mode.
    pub fn native_f32() -> BackendSpec {
        BackendSpec {
            kind: BackendKind::Native,
            device: DeviceTag::Cpu(0),
            precision: Precision::F32,
        }
    }

    /// Parse `"pjrt"`, `"native"`, `"native+f32"`, or
    /// `"<kind>[+<precision>]@<device>"` (e.g. `"pjrt@gpu:1"`,
    /// `"native+f32@cpu:0"`).
    ///
    /// ```
    /// use slimadam::runtime::backend::{BackendKind, BackendSpec, DeviceTag, Precision};
    ///
    /// let s = BackendSpec::parse("native").unwrap();
    /// assert_eq!(s.kind, BackendKind::Native);
    /// assert_eq!(s.precision, Precision::F64);
    /// let s = BackendSpec::parse("pjrt@gpu:1").unwrap();
    /// assert_eq!(s.device, DeviceTag::Gpu(1));
    /// assert_eq!(s.key(), "pjrt@gpu:1");
    /// let s = BackendSpec::parse("native+f32").unwrap();
    /// assert_eq!(s.precision, Precision::F32);
    /// assert_eq!(s.key(), "native+f32@cpu:0");
    /// assert!(BackendSpec::parse("cuda").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<BackendSpec> {
        let (kind, device) = match s.split_once('@') {
            Some((k, d)) => (k, DeviceTag::parse(d)?),
            None => (s, DeviceTag::Cpu(0)),
        };
        let (kind, precision) = match kind.split_once('+') {
            Some((k, p)) => (k, Precision::parse(p)?),
            None => (kind, Precision::F64),
        };
        let kind = match kind {
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            other => bail!("unknown backend {other:?} (want pjrt or native)"),
        };
        Ok(BackendSpec {
            kind,
            device,
            precision,
        })
    }

    /// Stable textual identity, e.g. `"native@cpu:0"` — used in config
    /// keys, cache keys and shard keys. The `+f32` marker appears only
    /// for the non-default precision, so every pre-existing f64 key (and
    /// therefore every stored run row) is byte-identical to before the
    /// precision field existed.
    pub fn key(&self) -> String {
        match self.precision {
            Precision::F64 => format!("{}@{}", self.kind.as_str(), self.device),
            Precision::F32 => format!("{}+f32@{}", self.kind.as_str(), self.device),
        }
    }
}

impl fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.key())
    }
}

/// A compiled step function: input literals in manifest order → output
/// literals in manifest order. Implementations are thread-confined (the
/// PJRT wrapper types are not `Send`), matching the per-worker cache
/// architecture.
pub trait Executable {
    fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>>;

    /// Execute the same compiled step for several independent jobs in one
    /// backend call (DESIGN.md §12). `jobs[b]` is job `b`'s full input
    /// list in manifest order; the result is each job's output list in
    /// the same order.
    ///
    /// Contract: `run_batch` must be **bit-for-bit equivalent** to
    /// calling [`Executable::run`] once per job — batching is a dispatch
    /// optimization, never a numerics change. The native backend
    /// overrides this with a lane-stacked interpreter pass
    /// (`rust/tests/batched_agreement.rs` proves the equivalence); this
    /// default is the always-correct sequential fallback.
    fn run_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        jobs.iter().map(|inputs| self.run(inputs)).collect()
    }
}

/// A compiler bound to one device: turns a loaded [`Artifact`] into an
/// [`Executable`]. `GradEngine`/`TrainEngine` are backend-agnostic — they
/// see only the `Compiled` wrapper this produces.
pub trait Backend {
    /// Implementation name (`"pjrt"` / `"native"`).
    fn name(&self) -> &'static str;

    /// The device this backend executes on.
    fn device(&self) -> DeviceTag;

    /// Compile an artifact for this device.
    fn compile(&self, art: &Artifact) -> Result<Box<dyn Executable>>;

    /// Resolve an artifact by name (`<model>.grad`,
    /// `<model>.train.<ruleset>`). The default reads `make artifacts`
    /// output from `dir`; the native backend generates its builtin
    /// manifest and ignores `dir`.
    fn load_artifact(&self, dir: &std::path::Path, name: &str) -> Result<Artifact> {
        Artifact::load(dir, name)
    }
}

/// Construct the backend an execution spec names. Fails with a buildable
/// hint when the `pjrt` feature is compiled out.
///
/// Non-CPU device tags parse and participate in scheduling/cache keys
/// (so key plumbing is exercised ahead of real device support), but
/// refusing to *construct* such a backend keeps run identity honest: no
/// row may ever claim `gpu:N` provenance for work a CPU client did.
pub fn backend_for(spec: &BackendSpec) -> Result<Rc<dyn Backend>> {
    if !matches!(spec.device, DeviceTag::Cpu(_)) {
        bail!(
            "device {} is not available: only cpu devices exist until real \
             GPU/TPU backends land (ROADMAP)",
            spec.device
        );
    }
    if spec.kind == BackendKind::Pjrt && spec.precision != Precision::F64 {
        bail!(
            "backend pjrt only supports the f64-reference compute path; \
             precision {} is a native-interpreter mode (use `--backend native`)",
            spec.precision.as_str()
        );
    }
    match spec.kind {
        BackendKind::Native => Ok(Rc::new(native::NativeBackend::with_precision(
            spec.device,
            spec.precision,
        ))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Rc::new(pjrt::PjrtBackend::new(spec.device)?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => bail!(
            "backend {:?} requires the `pjrt` cargo feature (this build used \
             --no-default-features) — rebuild with `--features pjrt` or use \
             `--backend native`",
            spec.key()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_tag_roundtrip() {
        for (s, want) in [
            ("cpu", DeviceTag::Cpu(0)),
            ("cpu:3", DeviceTag::Cpu(3)),
            ("gpu:1", DeviceTag::Gpu(1)),
            ("tpu:7", DeviceTag::Tpu(7)),
        ] {
            let tag = DeviceTag::parse(s).unwrap();
            assert_eq!(tag, want);
            assert_eq!(DeviceTag::parse(&tag.to_string()).unwrap(), tag);
        }
        assert!(DeviceTag::parse("cuda:0").is_err());
        assert!(DeviceTag::parse("gpu:x").is_err());
    }

    #[test]
    fn spec_parse_and_key() {
        assert_eq!(BackendSpec::parse("pjrt").unwrap(), BackendSpec::pjrt());
        assert_eq!(
            BackendSpec::parse("native").unwrap(),
            BackendSpec::native()
        );
        let s = BackendSpec::parse("native@gpu:2").unwrap();
        assert_eq!(s.key(), "native@gpu:2");
        assert_eq!(BackendSpec::parse(&s.key()).unwrap(), s);
        assert!(BackendSpec::parse("tensorrt").is_err());
    }

    #[test]
    fn f32_precision_parses_and_keys_roundtrip() {
        let s = BackendSpec::parse("native+f32").unwrap();
        assert_eq!(s, BackendSpec::native_f32());
        assert_eq!(s.key(), "native+f32@cpu:0");
        assert_eq!(BackendSpec::parse(&s.key()).unwrap(), s);
        // explicit +f64 is accepted and keys back to the unmarked form
        let s = BackendSpec::parse("native+f64@cpu:1").unwrap();
        assert_eq!(s.precision, Precision::F64);
        assert_eq!(s.key(), "native@cpu:1");
        assert!(BackendSpec::parse("native+bf16").is_err());
    }

    #[test]
    fn f64_keys_are_unchanged_by_the_precision_field() {
        // stored run rows key on this string: the default precision must
        // never alter it
        assert_eq!(BackendSpec::native().key(), "native@cpu:0");
        assert_eq!(BackendSpec::pjrt().key(), "pjrt@cpu:0");
        assert_eq!(BackendSpec::default().precision, Precision::F64);
    }

    #[test]
    fn pjrt_rejects_non_reference_precision() {
        let spec = BackendSpec::parse("pjrt+f32").unwrap();
        let err = backend_for(&spec).unwrap_err();
        assert!(format!("{err}").contains("f64-reference"), "{err}");
    }

    #[test]
    fn native_f32_backend_constructs() {
        let b = backend_for(&BackendSpec::native_f32()).unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.device(), DeviceTag::Cpu(0));
    }

    #[test]
    fn default_spec_is_pjrt_cpu() {
        assert_eq!(BackendSpec::default(), BackendSpec::pjrt());
        assert_eq!(BackendSpec::default().key(), "pjrt@cpu:0");
    }

    #[test]
    fn native_backend_always_constructs() {
        let b = backend_for(&BackendSpec::native()).unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.device(), DeviceTag::Cpu(0));
    }

    #[test]
    fn non_cpu_devices_are_rejected_until_real() {
        // keys/scheduling accept gpu tags, but constructing a backend for
        // one must fail: no row may claim device provenance it never had
        for spec in ["native@gpu:0", "pjrt@tpu:1"] {
            let spec = BackendSpec::parse(spec).unwrap();
            let err = backend_for(&spec).unwrap_err();
            assert!(format!("{err}").contains("not available"), "{err}");
        }
    }
}
