//! PJRT backend (cargo feature `pjrt`): compiles AOT-lowered HLO text on
//! a `PjRtClient` — the `vendor/xla` path. With the offline stub crate,
//! compilation errors helpfully; swapping in the real `xla_extension`
//! bindings lights up artifact execution without coordinator changes
//! (DESIGN.md §2, §11).
//!
//! Threading contract: the `xla` wrapper types are not `Send`, so each
//! sweep worker owns its own `PjrtBackend` (a CPU client is cheap) — see
//! `coordinator::exec_cache::thread_backend`.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::runtime::engine::Artifact;

use super::{Backend, DeviceTag, Executable};

/// Create the PJRT CPU client.
pub fn cpu_client() -> Result<PjRtClient> {
    PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))
}

/// The `vendor/xla` execution path, bound to one device.
pub struct PjrtBackend {
    client: Rc<PjRtClient>,
    device: DeviceTag,
}

impl PjrtBackend {
    /// Client for `device`. Only CPU clients exist until the real PJRT
    /// bindings land — `backend_for` rejects non-CPU tags before this
    /// constructor runs, so `device` is always a `cpu:N` here.
    pub fn new(device: DeviceTag) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            client: Rc::new(cpu_client()?),
            device,
        })
    }

    pub fn cpu() -> Result<PjrtBackend> {
        Self::new(DeviceTag::Cpu(0))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn device(&self) -> DeviceTag {
        self.device
    }

    fn compile(&self, art: &Artifact) -> Result<Box<dyn Executable>> {
        let Some(hlo_path) = art.hlo_path() else {
            bail!(
                "artifact {:?} has no HLO text (builtin native model) — the \
                 pjrt backend compiles `make artifacts` output only; use \
                 `--backend native`",
                art.name
            );
        };
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {hlo_path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {hlo_path:?}: {e}"))?;
        Ok(Box::new(PjrtExecutable {
            exe,
            name: art.manifest.model_name.clone(),
        }))
    }
}

/// A loaded PJRT executable. PJRT returns one tupled output buffer; `run`
/// syncs it to the host and untuples (on the CPU client "device" memory
/// is host memory, so this is a memcpy — see `runtime` module docs).
struct PjrtExecutable {
    exe: PjRtLoadedExecutable,
    name: String,
}

impl Executable for PjrtExecutable {
    fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("syncing output: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling output: {e}"))
    }

    /// Batched dispatch (DESIGN.md §12): every job's host literals are
    /// submitted to the loaded executable back to back and only then are
    /// the output buffers synced to the host — one dispatch burst instead
    /// of a submit/sync round-trip per job. On a real PJRT client the
    /// submissions overlap with the host-side work of the next job; on
    /// the CPU client (device memory *is* host memory) it amortizes the
    /// per-call wrapper overhead. Per-job results are identical to
    /// sequential [`Executable::run`] calls — the executable itself is
    /// unchanged, only the dispatch pattern differs.
    fn run_batch(&self, jobs: &[Vec<Literal>]) -> Result<Vec<Vec<Literal>>> {
        let mut pending = Vec::with_capacity(jobs.len());
        for (b, inputs) in jobs.iter().enumerate() {
            let out = self
                .exe
                .execute::<Literal>(inputs)
                .map_err(|e| anyhow!("executing {} (job {b}): {e}", self.name))?;
            pending.push(out);
        }
        pending
            .into_iter()
            .enumerate()
            .map(|(b, out)| {
                let lit = out[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("syncing output (job {b}): {e}"))?;
                lit.to_tuple()
                    .map_err(|e| anyhow!("untupling output (job {b}): {e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_backend_constructs() {
        let b = PjrtBackend::cpu().unwrap();
        assert_eq!(b.name(), "pjrt");
        assert_eq!(b.device(), DeviceTag::Cpu(0));
    }

    #[test]
    fn builtin_artifact_rejected() {
        // A native builtin artifact carries no HLO; the pjrt backend must
        // refuse it with a pointer at --backend native.
        let art = crate::runtime::backend::native::artifact("mlp_tiny.grad").unwrap();
        let b = PjrtBackend::cpu().unwrap();
        let err = b.compile(&art).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
    }
}
