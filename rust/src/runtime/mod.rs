//! Runtime layer: loads AOT artifacts (HLO text + JSON manifest, produced
//! by `python/compile/aot.py`) or builtin native models, compiles them on
//! a [`backend::Backend`] and exposes typed step functions to the
//! training loop.
//!
//! Backends (DESIGN.md §11):
//!
//! * `pjrt` (cargo feature `pjrt`, default) — the `vendor/xla` PJRT
//!   path. HLO *text* is the interchange format because jax >= 0.5
//!   serializes protos with 64-bit instruction ids that xla_extension
//!   0.5.1 rejects; the text parser reassigns ids (DESIGN.md §7). The
//!   mlir→XlaComputation conversion tuples the root, so every step does
//!   one device→host literal sync + tuple decomposition (a memcpy on the
//!   CPU client).
//! * `native` (always available) — a pure-Rust interpreter of the
//!   manifest's model family; trains end to end offline with no
//!   artifacts (see [`backend::native`]).
//!
//! Python never runs here — artifacts are self-contained, and the native
//! backend needs no files at all.

pub mod backend;
pub mod engine;
pub mod literal;
pub mod manifest;

pub use backend::{Backend, BackendKind, BackendSpec, DeviceTag, Executable};
pub use engine::{Artifact, GradEngine, TrainEngine};
pub use manifest::{BatchInfo, KMode, Manifest, ParamInfo};
