//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + JSON manifest), compiles them on the PJRT CPU client and
//! exposes typed step functions to the training loop.
//!
//! Python never runs here — the artifacts are self-contained. HLO *text*
//! is the interchange format because jax >= 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §7 and /opt/xla-example/README.md).
//!
//! Note on output structure: the mlir→XlaComputation conversion tuples the
//! root, and PJRT 0.5.1 returns a single tuple buffer, so every step does
//! one device→host literal sync + tuple decomposition. On the CPU PJRT
//! backend "device" memory is host memory, so this is a memcpy, not a
//! transfer; the perf pass (EXPERIMENTS.md §Perf) quantifies it.

pub mod engine;
pub mod literal;
pub mod manifest;

pub use engine::{Artifact, GradEngine, TrainEngine};
pub use manifest::{BatchInfo, KMode, Manifest, ParamInfo};
