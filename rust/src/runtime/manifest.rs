//! Artifact manifest parsing — the contract between `python/compile/aot.py`
//! and the Rust runtime. The manifest pins the exact input/output ordering
//! of the lowered HLO, per-parameter metadata (layer type, fan axes, init
//! schemes, weight-decay flags) and, for fused train-step artifacts, the
//! baked-in K modes / reduced V shapes and optimizer hyperparameters.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::tensor::Init;

/// Sharing-dimension mode, the paper's K (Eq. 2).
///
/// `Blocks(n)` shares one second moment per contiguous block of rows in the
/// matrix view (used by Adam-mini's per-attention-head partitioning; not
/// produced by the Python side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KMode {
    /// K = ∅: exact Adam.
    None,
    /// K = 0: average over the fan_out axis; V stored as (1, fan_in).
    FanOut,
    /// K = 1: average over the fan_in axis; V stored as (fan_out, 1).
    FanIn,
    /// K = (0, 1): one scalar per tensor (AdaLayer-style).
    Both,
    /// One scalar per contiguous row-block (Adam-mini per-head / per-neuron).
    Blocks(usize),
}

impl KMode {
    pub fn parse(s: &str) -> Result<KMode> {
        Ok(match s {
            "none" => KMode::None,
            "fan_out" => KMode::FanOut,
            "fan_in" => KMode::FanIn,
            "both" | "all" => KMode::Both,
            other => bail!("unknown k_mode {other:?}"),
        })
    }

    pub fn as_str(&self) -> String {
        match self {
            KMode::None => "none".into(),
            KMode::FanOut => "fan_out".into(),
            KMode::FanIn => "fan_in".into(),
            KMode::Both => "both".into(),
            KMode::Blocks(n) => format!("blocks{n}"),
        }
    }

    /// Stored V element count for a `(rows, cols)` matrix view.
    pub fn v_elems(&self, rows: usize, cols: usize) -> usize {
        match self {
            KMode::None => rows * cols,
            KMode::FanOut => cols,
            KMode::FanIn => rows,
            KMode::Both => 1,
            KMode::Blocks(n) => *n,
        }
    }
}

/// Per-parameter metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub layer_type: String,
    pub depth: i64,
    pub init_mitchell: Init,
    pub init_default: Init,
    pub wd: bool,
    pub fan_out_axis: usize,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_vector(&self) -> bool {
        self.shape.len() <= 1
    }

    /// `(fan_out, fan_in)` dims of the matrix view.
    pub fn matrix_dims(&self) -> (usize, usize) {
        crate::tensor::Tensor::matrix_dims(&self.shape, self.fan_out_axis)
    }

    fn from_json(v: &Value) -> Result<ParamInfo> {
        let shape = v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamInfo {
            name: v.get("name")?.as_str()?.to_string(),
            shape,
            layer_type: v.get("layer_type")?.as_str()?.to_string(),
            depth: v.get("depth")?.as_i64()?,
            init_mitchell: Init::from_json(v.get("init_mitchell")?)?,
            init_default: Init::from_json(v.get("init_default")?)?,
            wd: v.get("wd")?.as_bool()?,
            fan_out_axis: v.get("fan_out_axis")?.as_usize()?,
        })
    }
}

/// Batch input descriptor.
#[derive(Debug, Clone)]
pub struct BatchInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

/// Optimizer hyperparameters baked into fused train-step artifacts.
#[derive(Debug, Clone, Copy)]
pub struct Hypers {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub clip_norm: f64,
}

impl Default for Hypers {
    fn default() -> Self {
        // Paper App. B.1 language-model defaults.
        Hypers {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
            clip_norm: 1.0,
        }
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub kind: String, // "grad_step" | "train_step"
    pub model_name: String,
    pub family: String,
    pub meta: Value,
    pub params: Vec<ParamInfo>,
    pub batch: Vec<BatchInfo>,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    /// Fused artifacts only:
    pub k_modes: Option<Vec<KMode>>,
    pub v_shapes: Option<Vec<Vec<usize>>>,
    pub hypers: Option<Hypers>,
    pub ruleset: Option<String>,
    /// Fused update rule baked into the artifact. Absent means the
    /// K-moded AdamW family (`adam` / `slimadam` / `adalayer` rulesets);
    /// the native optimizer bake-off sets `lion`, `sgdm`, `sm3`,
    /// `adafactor`, or `lowrank_v<r>` here.
    pub optimizer: Option<String>,
    /// Stored first-moment shapes, when they differ from the parameter
    /// shapes (e.g. Adafactor v1 carries no momentum: `[0]` per tensor).
    /// Absent means one full-shape moment per parameter.
    pub m_shapes: Option<Vec<Vec<usize>>>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text).context("parsing manifest JSON")?;
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(ParamInfo::from_json)
            .collect::<Result<Vec<_>>>()?;
        let batch = v
            .get("batch")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BatchInfo {
                    name: b.get("name")?.as_str()?.to_string(),
                    shape: b
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    dtype: b.get("dtype")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect()
        };
        let meta = v.get("model")?.clone();

        let k_modes = match v.opt("k_modes") {
            Some(arr) => Some(
                arr.as_arr()?
                    .iter()
                    .map(|x| KMode::parse(x.as_str()?))
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        };
        let shape_list = |key: &str| -> Result<Option<Vec<Vec<usize>>>> {
            match v.opt(key) {
                Some(arr) => Ok(Some(
                    arr.as_arr()?
                        .iter()
                        .map(|x| {
                            x.as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<Vec<_>>>()
                        })
                        .collect::<Result<Vec<_>>>()?,
                )),
                None => Ok(None),
            }
        };
        let v_shapes = shape_list("v_shapes")?;
        let m_shapes = shape_list("m_shapes")?;
        let hypers = match v.opt("hypers") {
            Some(h) => Some(Hypers {
                beta1: h.get("beta1")?.as_f64()?,
                beta2: h.get("beta2")?.as_f64()?,
                eps: h.get("eps")?.as_f64()?,
                weight_decay: h.get("weight_decay")?.as_f64()?,
                clip_norm: h.get("clip_norm")?.as_f64()?,
            }),
            None => None,
        };

        Ok(Manifest {
            kind: v.get("kind")?.as_str()?.to_string(),
            model_name: meta.get("name")?.as_str()?.to_string(),
            family: meta.get("family")?.as_str()?.to_string(),
            meta,
            params,
            batch,
            inputs: strings("inputs")?,
            outputs: strings("outputs")?,
            k_modes,
            v_shapes,
            hypers,
            ruleset: v
                .opt("ruleset")
                .and_then(|r| r.as_str().ok().map(|s| s.to_string())),
            optimizer: v
                .opt("optimizer")
                .and_then(|r| r.as_str().ok().map(|s| s.to_string())),
            m_shapes,
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Manifest::parse(&text)
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Model vocab / class count (for batch synthesis bounds).
    pub fn token_bound(&self) -> usize {
        self.meta
            .opt("vocab")
            .or_else(|| self.meta.opt("classes"))
            .and_then(|v| v.as_usize().ok())
            .unwrap_or(2)
    }

    pub fn batch_size(&self) -> usize {
        self.batch.first().map(|b| b.shape[0]).unwrap_or(1)
    }

    /// Expected input literal count for this artifact.
    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Stored first-moment shape of parameter `i` (fused artifacts):
    /// the explicit `m_shapes` entry when present, else the parameter
    /// shape (one full-shape moment per tensor, the AdamW layout).
    pub fn m_shape(&self, i: usize) -> &[usize] {
        match &self.m_shapes {
            Some(shapes) => &shapes[i],
            None => &self.params[i].shape,
        }
    }

    /// Fused update rule this artifact bakes in (`adamw` when the
    /// manifest predates the optimizer bake-off).
    pub fn optimizer_name(&self) -> &str {
        self.optimizer.as_deref().unwrap_or("adamw")
    }

    /// Sanity-check input/output layout against the manifest kind.
    pub fn validate(&self) -> Result<()> {
        let n = self.n_params();
        match self.kind.as_str() {
            "grad_step" => {
                anyhow::ensure!(
                    self.inputs.len() == n + self.batch.len(),
                    "grad_step input count mismatch"
                );
                anyhow::ensure!(
                    self.outputs.len() == 1 + n,
                    "grad_step output count mismatch"
                );
            }
            "train_step" => {
                anyhow::ensure!(
                    self.inputs.len() == 3 * n + self.batch.len() + 2,
                    "train_step input count mismatch"
                );
                anyhow::ensure!(
                    self.outputs.len() == 2 + 3 * n,
                    "train_step output count mismatch"
                );
                anyhow::ensure!(self.k_modes.as_ref().map(|k| k.len()) == Some(n));
                anyhow::ensure!(self.v_shapes.as_ref().map(|v| v.len()) == Some(n));
                if let Some(m) = &self.m_shapes {
                    anyhow::ensure!(m.len() == n, "m_shapes length mismatch");
                }
            }
            k => bail!("unknown manifest kind {k:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "kind": "grad_step",
      "model": {"name": "m", "family": "gpt", "vocab": 512, "batch": 4},
      "params": [
        {"name": "w", "shape": [4, 8], "layer_type": "attn_q", "depth": 0,
         "init_mitchell": {"scheme": "normal", "std": 0.02},
         "init_default": {"scheme": "uniform", "limit": 0.35},
         "wd": true, "fan_out_axis": 0},
        {"name": "b", "shape": [4], "layer_type": "ln_attn", "depth": 0,
         "init_mitchell": {"scheme": "ones"},
         "init_default": {"scheme": "ones"},
         "wd": false, "fan_out_axis": 0}
      ],
      "batch": [{"name": "x", "shape": [4, 16], "dtype": "s32"}],
      "inputs": ["param:w", "param:b", "batch:x"],
      "outputs": ["loss", "grad:w", "grad:b"]
    }"#;

    #[test]
    fn parse_grad_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.kind, "grad_step");
        assert_eq!(m.model_name, "m");
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.params[0].matrix_dims(), (4, 8));
        assert!(m.params[1].is_vector());
        assert_eq!(m.token_bound(), 512);
        m.validate().unwrap();
    }

    #[test]
    fn kmode_roundtrip() {
        for s in ["none", "fan_out", "fan_in", "both"] {
            let k = KMode::parse(s).unwrap();
            if s == "both" {
                assert_eq!(k, KMode::Both);
            } else {
                assert_eq!(k.as_str(), s);
            }
        }
        assert!(KMode::parse("bogus").is_err());
    }

    #[test]
    fn kmode_v_elems() {
        assert_eq!(KMode::None.v_elems(4, 8), 32);
        assert_eq!(KMode::FanOut.v_elems(4, 8), 8);
        assert_eq!(KMode::FanIn.v_elems(4, 8), 4);
        assert_eq!(KMode::Both.v_elems(4, 8), 1);
        assert_eq!(KMode::Blocks(2).v_elems(4, 8), 2);
    }

    #[test]
    fn validate_catches_mismatch() {
        let mut m = Manifest::parse(MINI).unwrap();
        m.outputs.pop();
        assert!(m.validate().is_err());
    }

    #[test]
    fn parses_real_artifact_manifests() {
        // Loaded only when artifacts exist (make artifacts ran).
        let dir = std::path::Path::new("artifacts");
        if !dir.exists() {
            return;
        }
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().map(|e| e == "json").unwrap_or(false)
                && path.to_string_lossy().contains("manifest")
            {
                let m = Manifest::load(&path)
                    .unwrap_or_else(|e| panic!("{path:?}: {e}"));
                m.validate().unwrap_or_else(|e| panic!("{path:?}: {e}"));
            }
        }
    }
}
