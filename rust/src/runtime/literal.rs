//! Tensor / batch ↔ `xla::Literal` conversion helpers.
//!
//! The hot path preallocates literals once and refills them in place with
//! `copy_raw_from` (no per-step allocation); see `refill_f32` / `refill_i32`.

use anyhow::{Context, Result};
use xla::Literal;

use crate::tensor::Tensor;

/// Host f32 tensor → literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let flat = Literal::vec1(&t.data);
    if t.shape.len() == 1 {
        Ok(flat)
    } else {
        flat.reshape(&dims).context("reshaping literal")
    }
}

/// Literal → host f32 tensor (shape taken from the literal).
pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// i32 batch array → literal of the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        Ok(flat)
    } else {
        flat.reshape(&dims).context("reshaping i32 literal")
    }
}

/// f32 batch array → literal of the given shape.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        Ok(flat)
    } else {
        flat.reshape(&dims).context("reshaping f32 literal")
    }
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// In-place refill of an existing f32 literal (hot path, no allocation).
pub fn refill_f32(lit: &mut Literal, data: &[f32]) -> Result<()> {
    lit.copy_raw_from(data).context("refilling f32 literal")
}

/// In-place refill of an existing i32 literal (hot path, no allocation).
pub fn refill_i32(lit: &mut Literal, data: &[i32]) -> Result<()> {
    lit.copy_raw_from(data).context("refilling i32 literal")
}

/// Read a scalar f32 out of a literal.
pub fn scalar_value(l: &Literal) -> Result<f32> {
    l.get_first_element::<f32>().context("reading scalar")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn vector_roundtrip() {
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        let l = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&l).unwrap(), t);
    }

    #[test]
    fn conv_shape_roundtrip() {
        let t = Tensor::from_vec(&[2, 2, 3, 1], (0..12).map(|x| x as f32).collect());
        let l = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&l).unwrap(), t);
    }

    #[test]
    fn i32_batch() {
        let l = i32_literal(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
    }

    #[test]
    fn refill_in_place() {
        let t = Tensor::zeros(&[2, 2]);
        let mut l = tensor_to_literal(&t).unwrap();
        refill_f32(&mut l, &[9., 8., 7., 6.]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![9., 8., 7., 6.]);
    }

    #[test]
    fn scalar_roundtrip() {
        let l = scalar_f32(2.5);
        assert_eq!(scalar_value(&l).unwrap(), 2.5);
    }
}
