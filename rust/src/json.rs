//! Minimal JSON substrate (lexer + parser + writer) — replaces `serde_json`.
//!
//! The offline toolchain for this repo ships only the `xla` and `anyhow`
//! crates, so artifact manifests, experiment configs, rule files and
//! metric sinks are all read/written through this module. It implements
//! the full JSON grammar (RFC 8259), including surrogate-pair `\u`
//! escapes (lone surrogates are rejected).
//!
//! The module is split in two layers so the token scanner can be shared:
//!
//! * [`Lexer`] — byte-level tokenizer (strings, strict numbers, literals,
//!   whitespace). Escape-free strings are returned as borrowed slices, so
//!   consumers that only *look* at values never allocate.
//! * [`Value`] — the DOM layer, used where a materialized tree is the
//!   right shape (manifests, rule files).
//!
//! There is exactly **one** structural-grammar implementation: the
//! streaming scanner [`scan_value`] (re-exported by
//! `crate::runstore::reader` for its JSONL callers). The DOM parser is a
//! small tree-building visitor over its event stream (`TreeBuilder`
//! below), so both layers accept and reject *identical* inputs by
//! construction — there is no second object/array walker to drift out
//! of sync.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// Maximum nesting depth either JSON layer will follow — manifests and
/// sweep rows are a handful of levels deep; the bound exists so corrupt
/// or adversarial input cannot overflow the stack. Shared with the
/// streaming reader so both layers accept identical inputs.
pub const MAX_DEPTH: usize = 64;

// ---------------------------------------------------------------------------
// Lexer: the shared token scanner
// ---------------------------------------------------------------------------

/// Byte-level JSON tokenizer shared by the DOM parser and the streaming
/// JSONL reader (`runstore::reader`). Grammar strictness lives here so
/// every consumer agrees on what is valid JSON:
///
/// * numbers follow RFC 8259 exactly — no leading zeros (`01`), no bare
///   or trailing dot (`.5`, `1.`), no leading `+`, and the `NaN` /
///   `Infinity` literals are rejected;
/// * `\uXXXX` escapes decode surrogate *pairs* to their astral code
///   point and reject lone surrogates;
/// * raw control characters (< 0x20) inside strings are rejected.
pub struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(text: &'a str) -> Lexer<'a> {
        Lexer { b: text.as_bytes(), i: 0 }
    }

    /// Current byte offset (for error reporting).
    pub fn pos(&self) -> usize {
        self.i
    }

    pub fn at_end(&self) -> bool {
        self.i >= self.b.len()
    }

    pub fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    pub fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    pub fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, got {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    /// Consume an exact keyword (`true` / `false` / `null`).
    pub fn lit(&mut self, word: &str) -> Result<()> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    /// Scan a string token. Escape-free strings borrow from the input
    /// (the zero-copy hot path for JSONL scans); strings with escapes are
    /// decoded into an owned buffer, including surrogate-pair `\u`
    /// sequences. Lone surrogates and raw control characters are errors.
    pub fn string(&mut self) -> Result<Cow<'a, str>> {
        self.eat(b'"')?;
        let start = self.i;
        // Fast path: find the closing quote without touching an escape.
        loop {
            match self.b.get(self.i) {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    let s = std::str::from_utf8(&self.b[start..self.i])?;
                    self.i += 1;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => break,
                Some(&c) if c < 0x20 => {
                    bail!("raw control character {c:#04x} in string")
                }
                Some(_) => self.i += 1,
            }
        }
        // Slow path: escapes present — decode into an owned buffer.
        let mut s = String::new();
        s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(Cow::Owned(s)),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => s.push(self.unicode_escape()?),
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character {c:#04x} in string"),
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: find the sequence length and copy.
                    let len = utf8_len(c)?;
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    /// Decode the payload of a `\u` escape (the `\u` itself is consumed).
    /// High surrogates must be followed by a `\u`-escaped low surrogate;
    /// the pair combines to one astral code point (RFC 8259 §7).
    fn unicode_escape(&mut self) -> Result<char> {
        let cp = self.hex4()?;
        match cp {
            0xD800..=0xDBFF => {
                if self.b.get(self.i) != Some(&b'\\')
                    || self.b.get(self.i + 1) != Some(&b'u')
                {
                    bail!("lone high surrogate \\u{cp:04x}");
                }
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..=0xDFFF).contains(&lo) {
                    bail!("high surrogate \\u{cp:04x} followed by \\u{lo:04x}");
                }
                let astral = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                char::from_u32(astral)
                    .ok_or_else(|| anyhow!("bad codepoint {astral:#x}"))
            }
            0xDC00..=0xDFFF => bail!("lone low surrogate \\u{cp:04x}"),
            cp => char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint {cp:#x}")),
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow!("bad \\u escape {hex:?}"))?;
        self.i += 4;
        Ok(cp)
    }

    /// Scan a number token, validating the RFC 8259 grammar
    /// (`-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`) before parsing.
    /// Rejects leading zeros, bare/trailing dots, leading `+`, and the
    /// non-JSON `NaN` / `Infinity` spellings `str::parse::<f64>` accepts.
    pub fn number(&mut self) -> Result<f64> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        // integer part: 0 | [1-9][0-9]*
        match self.b.get(self.i) {
            Some(b'0') => {
                self.i += 1;
                if matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                    bail!("leading zero in number at byte {start}");
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                    self.i += 1;
                }
            }
            _ => bail!("invalid number at byte {start}"),
        }
        // fraction: . [0-9]+
        if self.b.get(self.i) == Some(&b'.') {
            self.i += 1;
            if !matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                bail!("digit required after decimal point at byte {}", self.i);
            }
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        // exponent: [eE] [+-]? [0-9]+
        if matches!(self.b.get(self.i), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.b.get(self.i), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                bail!("digit required in exponent at byte {}", self.i);
            }
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        text.parse::<f64>()
            .map_err(|_| anyhow!("bad number {text:?}"))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

// ---------------------------------------------------------------------------
// Streaming scanner: THE structural-grammar implementation
// ---------------------------------------------------------------------------

/// One element of the streaming scan. String payloads are `Cow`: borrowed
/// from the input unless the JSON contained an escape sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// Object key (always immediately followed by its value's events).
    Key(Cow<'a, str>),
    Str(Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

/// Receiver for the event stream. Implemented for closures, so simple
/// scans can be written inline: `scan_value(&mut lex, &mut |ev| ...)`.
pub trait Visitor<'a> {
    fn event(&mut self, ev: Event<'a>) -> Result<()>;
}

impl<'a, F> Visitor<'a> for F
where
    F: FnMut(Event<'a>) -> Result<()>,
{
    fn event(&mut self, ev: Event<'a>) -> Result<()> {
        self(ev)
    }
}

/// Scan one JSON value from `lex`, emitting events to `visitor`. This is
/// the only object/array grammar walker in the crate: the DOM parser
/// folds these events into a [`Value`] (`TreeBuilder` below) and the
/// run-store's JSONL reader consumes them zero-copy
/// (`crate::runstore::reader`), so every consumer accepts and rejects
/// identical inputs by construction.
pub fn scan_value<'a, V: Visitor<'a> + ?Sized>(
    lex: &mut Lexer<'a>,
    visitor: &mut V,
) -> Result<()> {
    scan_at_depth(lex, visitor, 0)
}

fn scan_at_depth<'a, V: Visitor<'a> + ?Sized>(
    lex: &mut Lexer<'a>,
    v: &mut V,
    depth: usize,
) -> Result<()> {
    if depth > MAX_DEPTH {
        bail!("JSON nested deeper than {MAX_DEPTH} levels");
    }
    lex.skip_ws();
    match lex.peek()? {
        b'{' => {
            lex.eat(b'{')?;
            v.event(Event::ObjBegin)?;
            lex.skip_ws();
            if lex.peek()? == b'}' {
                lex.eat(b'}')?;
                return v.event(Event::ObjEnd);
            }
            loop {
                lex.skip_ws();
                let key = lex.string()?;
                v.event(Event::Key(key))?;
                lex.skip_ws();
                lex.eat(b':')?;
                scan_at_depth(lex, v, depth + 1)?;
                lex.skip_ws();
                match lex.peek()? {
                    b',' => lex.eat(b',')?,
                    b'}' => {
                        lex.eat(b'}')?;
                        return v.event(Event::ObjEnd);
                    }
                    c => bail!("expected ',' or '}}', got {:?}", c as char),
                }
            }
        }
        b'[' => {
            lex.eat(b'[')?;
            v.event(Event::ArrBegin)?;
            lex.skip_ws();
            if lex.peek()? == b']' {
                lex.eat(b']')?;
                return v.event(Event::ArrEnd);
            }
            loop {
                scan_at_depth(lex, v, depth + 1)?;
                lex.skip_ws();
                match lex.peek()? {
                    b',' => lex.eat(b',')?,
                    b']' => {
                        lex.eat(b']')?;
                        return v.event(Event::ArrEnd);
                    }
                    c => bail!("expected ',' or ']', got {:?}", c as char),
                }
            }
        }
        b'"' => {
            let s = lex.string()?;
            v.event(Event::Str(s))
        }
        b't' => {
            lex.lit("true")?;
            v.event(Event::Bool(true))
        }
        b'f' => {
            lex.lit("false")?;
            v.event(Event::Bool(false))
        }
        b'n' => {
            lex.lit("null")?;
            v.event(Event::Null)
        }
        b'-' | b'0'..=b'9' => {
            let n = lex.number()?;
            v.event(Event::Num(n))
        }
        b'N' | b'I' | b'+' => bail!(
            "NaN/Infinity/leading '+' are not valid JSON (byte {})",
            lex.pos()
        ),
        c => bail!("unexpected character {:?} at byte {}", c as char, lex.pos()),
    }
}

// ---------------------------------------------------------------------------
// DOM layer
// ---------------------------------------------------------------------------

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable diffs for rule files and experiment outputs).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut lex = Lexer::new(text);
        lex.skip_ws();
        let mut builder = TreeBuilder::default();
        scan_value(&mut lex, &mut |ev| builder.event(ev))?;
        lex.skip_ws();
        if !lex.at_end() {
            bail!("trailing garbage at byte {}", lex.pos());
        }
        builder
            .root
            .ok_or_else(|| anyhow!("empty JSON input"))
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {}", self.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {}", self.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {}", self.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {}", self.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(anyhow!("expected object, got {}", self.kind())),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        if let Value::Obj(o) = self {
            o.insert(key.to_string(), v.into());
        }
        self
    }

    // -- serialization -----------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty-printed with 1-space indent (matches python `json.dump(indent=1)`
    /// closely enough for human diffing; not byte-identical).
    pub fn dump_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=indent {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Value::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=indent {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like python's allow_nan=False would
        // reject — we pick null so downstream readers fail loudly on access.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// DOM construction: a tree-building visitor over the streaming scanner
// (the `value_from_events` shape from `rust/tests/properties.rs`, promoted
// to be *the* DOM parser — one grammar implementation for both layers).
// ---------------------------------------------------------------------------

/// One open container on the build stack. Object frames carry the pending
/// key between its `Key` event and the value events that follow.
enum Frame {
    Obj(BTreeMap<String, Value>, Option<String>),
    Arr(Vec<Value>),
}

/// Folds the scanner's event stream into a [`Value`]. Depth bounding and
/// all grammar errors live in the scanner; the builder only assembles.
#[derive(Default)]
struct TreeBuilder {
    stack: Vec<Frame>,
    root: Option<Value>,
}

impl TreeBuilder {
    fn attach(&mut self, v: Value) -> Result<()> {
        match self.stack.last_mut() {
            None => self.root = Some(v),
            Some(Frame::Arr(items)) => items.push(v),
            Some(Frame::Obj(map, key)) => {
                let key = key
                    .take()
                    .ok_or_else(|| anyhow!("object value without a key"))?;
                map.insert(key, v);
            }
        }
        Ok(())
    }

    fn event(&mut self, ev: Event<'_>) -> Result<()> {
        match ev {
            Event::ObjBegin => {
                self.stack.push(Frame::Obj(BTreeMap::new(), None));
                Ok(())
            }
            Event::ArrBegin => {
                self.stack.push(Frame::Arr(Vec::new()));
                Ok(())
            }
            Event::Key(k) => match self.stack.last_mut() {
                Some(Frame::Obj(_, slot)) => {
                    *slot = Some(k.into_owned());
                    Ok(())
                }
                _ => bail!("key event outside an object"),
            },
            Event::ObjEnd | Event::ArrEnd => {
                let v = match self.stack.pop() {
                    Some(Frame::Obj(map, _)) => Value::Obj(map),
                    Some(Frame::Arr(items)) => Value::Arr(items),
                    None => bail!("container end without begin"),
                };
                self.attach(v)
            }
            Event::Str(s) => self.attach(Value::Str(s.into_owned())),
            Event::Num(n) => self.attach(Value::Num(n)),
            Event::Bool(b) => self.attach(Value::Bool(b)),
            Event::Null => self.attach(Value::Null),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            assert_eq!(Value::parse(&v.dump()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Value::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Value::parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
        assert_eq!(Value::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Value::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Value::parse("0").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(Value::parse("-0.5e+2").unwrap().as_f64().unwrap(), -50.0);
    }

    #[test]
    fn rejects_non_json_numbers() {
        // `str::parse::<f64>` accepts all of these — the lexer must not.
        for s in ["NaN", "Infinity", "-Infinity", "inf", "+1", "01", "1.",
                  ".5", "-", "1e", "1e+", "--1", "0x10"] {
            assert!(Value::parse(s).is_err(), "{s:?} must be rejected");
        }
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Value::parse(r#""aéb""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aéb");
    }

    #[test]
    fn surrogate_pairs_decode() {
        let v = Value::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // and round-trip: the writer emits raw UTF-8
        assert_eq!(Value::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn rejects_lone_surrogates() {
        assert!(Value::parse(r#""\ud800""#).is_err()); // lone high
        assert!(Value::parse(r#""\udc00""#).is_err()); // lone low
        assert!(Value::parse(r#""\ud800x""#).is_err()); // high + non-escape
        assert!(Value::parse(r#""\ud800A""#).is_err()); // high + non-low
    }

    #[test]
    fn rejects_raw_control_chars() {
        assert!(Value::parse("\"a\u{1}b\"").is_err());
        // escaped control chars are fine
        assert_eq!(
            Value::parse(r#""a\u0001b""#).unwrap().as_str().unwrap(),
            "a\u{1}b"
        );
    }

    #[test]
    fn parse_raw_utf8() {
        let v = Value::parse("\"héllo → ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
    }

    #[test]
    fn integer_formatting() {
        // integers must serialize without a decimal point (manifest shapes)
        let v = Value::Num(768.0);
        assert_eq!(v.dump(), "768");
        let v = Value::Num(0.5);
        assert_eq!(v.dump(), "0.5");
    }

    #[test]
    fn deterministic_object_order() {
        let mut v = Value::obj();
        v.set("zeta", 1usize).set("alpha", 2usize);
        assert_eq!(v.dump(), r#"{"alpha":2,"zeta":1}"#);
    }

    #[test]
    fn builder_api() {
        let mut v = Value::obj();
        v.set("name", "slimadam")
            .set("lr", 3e-4)
            .set("steps", 100usize)
            .set("ok", true)
            .set("dims", vec![2usize, 3usize]);
        let back = Value::parse(&v.dump()).unwrap();
        assert_eq!(back.get("lr").unwrap().as_f64().unwrap(), 3e-4);
        assert_eq!(back.get("dims").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn pretty_roundtrip() {
        let src = r#"{"a":[1,2],"b":{"c":"d"}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(Value::parse(&v.dump_pretty()).unwrap(), v);
    }

    #[test]
    fn lexer_borrows_escape_free_strings() {
        let mut lex = Lexer::new(r#""plain text""#);
        match lex.string().unwrap() {
            Cow::Borrowed(s) => assert_eq!(s, "plain text"),
            Cow::Owned(_) => panic!("escape-free string must borrow"),
        }
        let mut lex = Lexer::new(r#""a\tb""#);
        match lex.string().unwrap() {
            Cow::Owned(s) => assert_eq!(s, "a\tb"),
            Cow::Borrowed(_) => panic!("escaped string must decode"),
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"kind":"grad_step","params":[{"name":"tok_embd",
            "shape":[512,64],"layer_type":"tok_embd","depth":-1,
            "init_mitchell":{"scheme":"normal","std":0.02},"wd":true,
            "fan_out_axis":0}],"outputs":["loss","grad:tok_embd"]}"#;
        let v = Value::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap()[0]
                   .as_usize().unwrap(), 512);
        assert_eq!(p.get("depth").unwrap().as_i64().unwrap(), -1);
    }
}
