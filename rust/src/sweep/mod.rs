//! Sweep harness: LR grids, optimizer comparisons, cutoff×LR savings
//! grids — the machinery behind every multi-run figure.
//!
//! Execution contract (DESIGN.md §9): grids are flattened to a config
//! list in `(optimizer, lr)` row-major order and handed to the
//! [`SweepScheduler`], which shards jobs across workers by artifact,
//! steals work when a shard drains, and keeps per-job metrics a pure
//! function of the config — so `workers = 1` and `workers = N` produce
//! identical [`LrSweep`]s. Every grid point shares the base config's
//! seed, which pairs the optimizer curves on identical data streams
//! (the paper's comparison setup); use [`LrSweep::run_seeded`] when grid
//! points should instead draw independent derived seeds.
//!
//! Resume (DESIGN.md §10): pass a scheduler built with
//! `SweepScheduler::resume_from` and already-completed grid points are
//! restored from the run store instead of re-executed — they occupy
//! their original `summaries[opt][lr]` slots, so charts, `best()` and
//! CSV output are oblivious to how many jobs actually ran
//! ([`LrSweep::restored`] reports the split).

use anyhow::Result;

use crate::coordinator::{EngineKind, RunSummary, SweepScheduler, TrainConfig};
use crate::json::Value;
use crate::metrics::{ascii_chart, CsvWriter};

/// The paper's LR grids are log-spaced; this helper builds one.
pub fn log_grid(lo: f64, hi: f64, points: usize) -> Vec<f64> {
    assert!(points >= 2 && hi > lo && lo > 0.0);
    let (llo, lhi) = (lo.ln(), hi.ln());
    (0..points)
        .map(|i| (llo + (lhi - llo) * i as f64 / (points - 1) as f64).exp())
        .collect()
}

/// Results of an (optimizer × lr) sweep.
pub struct LrSweep {
    pub optimizers: Vec<String>,
    pub lrs: Vec<f64>,
    /// summaries[opt_idx][lr_idx]
    pub summaries: Vec<Vec<RunSummary>>,
}

impl LrSweep {
    /// Flatten the `(optimizer × lr)` grid into scheduler jobs,
    /// row-major: job index = `opt_idx * lrs.len() + lr_idx`.
    ///
    /// A fused base engine routes each optimizer token to **its own**
    /// fused artifact (`EngineKind::Fused(token)`): a fused bake-off
    /// sweeps real per-optimizer kernels. The old behavior — every row
    /// silently re-running the single `base` ruleset while labeled with
    /// a different optimizer name — also aliased run-store config keys
    /// (identity never saw the row's optimizer), so resumed fused sweeps
    /// could skip rows that never actually ran.
    fn build_configs(
        base: &TrainConfig,
        optimizers: &[&str],
        lrs: &[f64],
    ) -> Vec<TrainConfig> {
        let mut configs = Vec::with_capacity(optimizers.len() * lrs.len());
        for opt in optimizers {
            for &lr in lrs {
                let mut cfg = base.clone();
                cfg.optimizer = opt.to_string();
                if matches!(base.engine, EngineKind::Fused(_)) {
                    cfg.engine = EngineKind::Fused(opt.to_string());
                }
                cfg.lr = lr;
                configs.push(cfg);
            }
        }
        configs
    }

    fn collect(
        optimizers: &[&str],
        lrs: &[f64],
        flat: Vec<RunSummary>,
    ) -> LrSweep {
        let mut summaries = Vec::new();
        let mut it = flat.into_iter();
        for _ in optimizers {
            summaries.push((&mut it).take(lrs.len()).collect());
        }
        LrSweep {
            optimizers: optimizers.iter().map(|s| s.to_string()).collect(),
            lrs: lrs.to_vec(),
            summaries,
        }
    }

    /// Run the sweep: `base` provides everything except optimizer and lr.
    /// `workers == 0` means one per core.
    pub fn run(
        base: &TrainConfig,
        optimizers: &[&str],
        lrs: &[f64],
        workers: usize,
    ) -> Result<LrSweep> {
        Self::run_with(base, optimizers, lrs, &SweepScheduler::new(workers))
    }

    /// Run on a caller-configured scheduler (worker count, streaming
    /// JSONL sink). Grid points share `base.seed` — paired curves.
    pub fn run_with(
        base: &TrainConfig,
        optimizers: &[&str],
        lrs: &[f64],
        scheduler: &SweepScheduler,
    ) -> Result<LrSweep> {
        let configs = Self::build_configs(base, optimizers, lrs);
        let flat = scheduler.run(&configs)?;
        Ok(Self::collect(optimizers, lrs, flat))
    }

    /// Like [`LrSweep::run_with`] but each grid point trains with the
    /// deterministic derived seed `rng::job_seed(base_seed, job_index)` —
    /// independent draws per point, still scheduling-invariant.
    pub fn run_seeded(
        base: &TrainConfig,
        optimizers: &[&str],
        lrs: &[f64],
        scheduler: &SweepScheduler,
        base_seed: u64,
    ) -> Result<LrSweep> {
        let configs = Self::build_configs(base, optimizers, lrs);
        let flat = scheduler.run_seeded(&configs, base_seed)?;
        Ok(Self::collect(optimizers, lrs, flat))
    }

    /// Loss metric used by the paper's sensitivity plots: eval loss if
    /// available, else final train loss; divergence maps to +inf.
    pub fn metric(s: &RunSummary) -> f64 {
        if s.result.diverged {
            return f64::INFINITY;
        }
        if s.result.eval_loss.is_finite() {
            s.result.eval_loss
        } else {
            s.result.final_train_loss
        }
    }

    /// (lr, loss) series for one optimizer.
    pub fn series(&self, opt_idx: usize) -> Vec<(f64, f64)> {
        self.summaries[opt_idx]
            .iter()
            .zip(&self.lrs)
            .map(|(s, &lr)| (lr, Self::metric(s)))
            .collect()
    }

    /// How many grid points were restored from the run store rather than
    /// executed (non-zero only for schedulers built with resume).
    pub fn restored(&self) -> usize {
        self.summaries
            .iter()
            .flatten()
            .filter(|s| s.restored())
            .count()
    }

    /// Best (lr, loss) for one optimizer.
    pub fn best(&self, opt_idx: usize) -> (f64, f64) {
        self.series(opt_idx)
            .into_iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    }

    /// Render the Fig. 1-style U-curves.
    pub fn chart(&self, title: &str) -> String {
        let series: Vec<(String, Vec<(f64, f64)>)> = self
            .optimizers
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let pts: Vec<(f64, f64)> = self
                    .series(i)
                    .into_iter()
                    .filter(|(_, l)| l.is_finite())
                    .collect();
                (name.clone(), pts)
            })
            .collect();
        let refs: Vec<(&str, &[(f64, f64)])> = series
            .iter()
            .map(|(n, p)| (n.as_str(), p.as_slice()))
            .collect();
        ascii_chart(title, &refs, 64, 16, true, false)
    }

    /// Write `rows.csv` (optimizer, lr, eval_loss, train_loss, diverged,
    /// v_saving) into the experiment directory.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["optimizer", "lr", "eval_loss", "final_train_loss", "diverged", "v_saving"],
        )?;
        for (i, opt) in self.optimizers.iter().enumerate() {
            for s in &self.summaries[i] {
                let saving = s
                    .memory
                    .as_ref()
                    .map(|m| m.v_saving)
                    .unwrap_or(f64::NAN);
                w.row(&[
                    opt.clone(),
                    format!("{:e}", s.lr),
                    fmtf(s.result.eval_loss),
                    fmtf(s.result.final_train_loss),
                    s.result.diverged.to_string(),
                    fmtf(saving),
                ])?;
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut arr = Vec::new();
        for (i, _) in self.optimizers.iter().enumerate() {
            for s in &self.summaries[i] {
                arr.push(s.to_json());
            }
        }
        Value::Arr(arr)
    }
}

fn fmtf(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.5}")
    } else {
        "inf".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_spacing() {
        let g = log_grid(1e-4, 1e-2, 3);
        assert_eq!(g.len(), 3);
        assert!((g[0] - 1e-4).abs() < 1e-12);
        assert!((g[1] - 1e-3).abs() < 1e-9);
        assert!((g[2] - 1e-2).abs() < 1e-8);
    }

    #[test]
    fn sweep_end_to_end_tiny() {
        if !std::path::Path::new("artifacts/linear2_v64.grad.hlo.txt").exists() {
            return;
        }
        let base = TrainConfig::lm("linear2_v64", "adam", 1e-3, 8);
        let sweep = LrSweep::run(&base, &["adam", "sgdm"], &[1e-3, 3e-3], 2).unwrap();
        assert_eq!(sweep.summaries.len(), 2);
        assert_eq!(sweep.summaries[0].len(), 2);
        let (best_lr, best_loss) = sweep.best(0);
        assert!(best_loss.is_finite());
        assert!(sweep.lrs.contains(&best_lr));
        let chart = sweep.chart("test");
        assert!(chart.contains("adam"));
    }
}
