//! Thread-pool substrate — replaces `rayon`/`tokio` for sweep fan-out.
//!
//! [`parallel_map`] runs a job per input on a bounded set of worker
//! threads and returns outputs in input order. Workers pull indices from a
//! shared atomic counter (work stealing is unnecessary: sweep jobs are
//! coarse — a whole training run each). Panics in jobs are converted to
//! errors rather than poisoning the whole sweep.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

/// Number of workers to use by default: min(n_jobs, available cores).
pub fn default_workers(n_jobs: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    n_jobs.min(cores).max(1)
}

/// Run `f(i, &inputs[i])` for every input on `workers` threads; returns
/// outputs in input order. `f` must be `Sync` (it is shared by reference).
pub fn parallel_map<I, O, F>(inputs: &[I], workers: usize, f: F) -> Result<Vec<O>>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> Result<O> + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<O>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &inputs[i])))
                    .unwrap_or_else(|p| {
                        // `p.as_ref()` (not `&p`) so we downcast the payload,
                        // not the Box itself.
                        Err(anyhow!("job {i} panicked: {}", panic_msg(p.as_ref())))
                    });
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });

    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| Err(anyhow!("job {i} produced no result")))
        })
        .collect()
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let inputs: Vec<usize> = (0..100).collect();
        let out = parallel_map(&inputs, 8, |_, &x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(&[], 4, |_, _x: &usize| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let order = AtomicU64::new(0);
        let inputs: Vec<usize> = (0..10).collect();
        let out = parallel_map(&inputs, 1, |i, _| {
            let prev = order.fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev as usize, i); // strictly in order with 1 worker
            Ok(i)
        })
        .unwrap();
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::AtomicUsize;
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let inputs: Vec<usize> = (0..16).collect();
        parallel_map(&inputs, 4, |_, _| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert!(peak.load(Ordering::SeqCst) >= 2, "no observed parallelism");
    }

    #[test]
    fn error_propagates() {
        let inputs = vec![1usize, 2, 3];
        let res = parallel_map(&inputs, 2, |_, &x| {
            if x == 2 {
                Err(anyhow!("boom"))
            } else {
                Ok(x)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn panic_becomes_error() {
        let inputs = vec![0usize, 1];
        let res = parallel_map(&inputs, 2, |_, &x| {
            if x == 1 {
                panic!("kaboom {x}");
            }
            Ok(x)
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("kaboom"), "{err}");
    }

    #[test]
    fn default_workers_bounds() {
        assert_eq!(default_workers(0), 1);
        assert!(default_workers(1000) >= 1);
        assert!(default_workers(2) <= 2);
    }
}
