//! Thread-pool substrate — replaces `rayon`/`tokio` for sweep fan-out.
//!
//! Two schedulers, both returning outputs in input order and converting
//! job panics into errors rather than poisoning the whole sweep:
//!
//! * [`parallel_map`] — workers pull indices from a shared atomic
//!   counter. Best when jobs are interchangeable: dispatch order is
//!   global FIFO, so no worker idles while work remains.
//! * [`parallel_map_sharded`] — the sweep scheduler's engine
//!   (DESIGN.md §9). Jobs are pre-assigned to per-worker deques by a
//!   caller-supplied shard key (same key → same worker, which keeps
//!   per-thread caches such as the compiled-executable cache hot), and a
//!   worker whose deque drains steals from the back of the fullest
//!   remaining deque, so locality never costs utilization.
//!
//! Scheduling never influences results: a job's output is a pure
//! function of its input, and both schedulers write into an
//! index-addressed slot table, so worker count and steal order are
//! unobservable downstream (`rust/tests/scheduler_determinism.rs`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

/// Number of workers to use by default: min(n_jobs, available cores).
pub fn default_workers(n_jobs: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    n_jobs.min(cores).max(1)
}

/// Run `f(i, &inputs[i])` for every input on `workers` threads; returns
/// outputs in input order. `f` must be `Sync` (it is shared by reference).
pub fn parallel_map<I, O, F>(inputs: &[I], workers: usize, f: F) -> Result<Vec<O>>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> Result<O> + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<O>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &inputs[i])))
                    .unwrap_or_else(|p| {
                        // `p.as_ref()` (not `&p`) so we downcast the payload,
                        // not the Box itself.
                        Err(anyhow!("job {i} panicked: {}", panic_msg(p.as_ref())))
                    });
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });

    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| Err(anyhow!("job {i} produced no result")))
        })
        .collect()
}

/// Locality-aware work-stealing variant of [`parallel_map`].
///
/// `shard(i, &inputs[i])` maps each job to a shard key; jobs with the
/// same key land on the same worker's deque (key-stable assignment:
/// `key % workers`). Each worker pops its own deque from the front —
/// preserving submission order within a shard — and, once empty, steals
/// from the back of the fullest other deque. Outputs are returned in
/// input order regardless of which worker ran what.
///
/// Use this over [`parallel_map`] when jobs carry per-thread cached
/// state keyed by something coarser than the job (e.g. sweep jobs keyed
/// by their compiled artifact): sharding maximizes cache hits, stealing
/// bounds the tail latency of an unlucky shard.
pub fn parallel_map_sharded<I, O, F, S>(
    inputs: &[I],
    workers: usize,
    shard: S,
    f: F,
) -> Result<Vec<O>>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> Result<O> + Sync,
    S: Fn(usize, &I) -> u64,
{
    let n = inputs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);

    let mut assign: Vec<VecDeque<usize>> = (0..workers).map(|_| VecDeque::new()).collect();
    for i in 0..n {
        let w = (shard(i, &inputs[i]) % workers as u64) as usize;
        assign[w].push_back(i);
    }
    let deques: Vec<Mutex<VecDeque<usize>>> = assign.into_iter().map(Mutex::new).collect();
    let slots: Vec<Mutex<Option<Result<O>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for w in 0..workers {
            let deques = &deques;
            let slots = &slots;
            let f = &f;
            scope.spawn(move || loop {
                // Own deque first (front: submission order within the shard)…
                let own = deques[w].lock().unwrap().pop_front();
                let i = match own {
                    Some(i) => i,
                    None => {
                        // …then steal from the back of the fullest other
                        // deque. Jobs are only ever removed, so an
                        // all-empty scan means this worker is done; a
                        // steal lost to a race just rescans.
                        let mut victim = None;
                        let mut victim_len = 0;
                        for (v, dq) in deques.iter().enumerate() {
                            if v == w {
                                continue;
                            }
                            let len = dq.lock().unwrap().len();
                            if len > victim_len {
                                victim_len = len;
                                victim = Some(v);
                            }
                        }
                        let Some(v) = victim else { break };
                        match deques[v].lock().unwrap().pop_back() {
                            Some(i) => {
                                // observability: steal volume feeds the
                                // end-of-sweep summary + `obs report`
                                crate::obs::registry::counter("pool.steals").inc();
                                i
                            }
                            None => continue,
                        }
                    }
                };
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &inputs[i])))
                    .unwrap_or_else(|p| {
                        Err(anyhow!("job {i} panicked: {}", panic_msg(p.as_ref())))
                    });
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });

    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| Err(anyhow!("job {i} produced no result")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Intra-op parallelism (DESIGN.md §14)
//
// The schedulers above fan *jobs* out across workers. The helpers below
// fan the inside of one op out — e.g. the native backend's global-norm
// reduction and fused optimizer update split their per-tensor loops
// across threads. The contract is the same as for the job schedulers:
// thread count must never influence results. [`parallel_indexed`]
// guarantees it structurally (workers fill an index-addressed slot
// table; the caller folds slots in index order), and the worker count
// itself is a process-wide knob that is deliberately *not* part of any
// config fingerprint (`rust/tests/scheduler_determinism.rs` proves
// workers=1 ≡ 2 ≡ 8 for full train steps).
// ---------------------------------------------------------------------------

static INTRAOP_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Intra-op worker count for kernel-internal parallelism. Defaults to 1
/// (no extra threads — sweeps already parallelize across jobs); set via
/// [`set_intraop_workers`] (`--intraop`) or the `SLIMADAM_INTRAOP`
/// environment variable, read once on first use.
pub fn intraop_workers() -> usize {
    let v = INTRAOP_WORKERS.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let n = std::env::var("SLIMADAM_INTRAOP")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(1);
    INTRAOP_WORKERS.store(n, Ordering::Relaxed);
    n
}

/// Set the process-wide intra-op worker count (clamped to ≥ 1).
pub fn set_intraop_workers(n: usize) {
    INTRAOP_WORKERS.store(n.max(1), Ordering::Relaxed);
}

/// Compute `f(0..n)` on `workers` threads and return the results in
/// index order. Infallible flavor of [`parallel_map`] for kernel-internal
/// fan-out: the work items are index ranges the caller derived from data
/// shape alone, so the slot table (not scheduling) fixes the output
/// order and any subsequent fold is deterministic. A panicking task
/// propagates out of the scope.
pub fn parallel_indexed<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("task produced no result"))
        .collect()
}

/// Apply `f(i, &mut items[i])` to every item, splitting the slice into
/// one contiguous chunk per worker. For mutually independent per-tensor
/// work (each item owns its data), so thread count and chunk boundaries
/// cannot affect results.
pub fn parallel_chunks<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let per = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for (w, chunk) in items.chunks_mut(per).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, item) in chunk.iter_mut().enumerate() {
                    f(w * per + j, item);
                }
            });
        }
    });
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let inputs: Vec<usize> = (0..100).collect();
        let out = parallel_map(&inputs, 8, |_, &x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(&[], 4, |_, _x: &usize| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let order = AtomicU64::new(0);
        let inputs: Vec<usize> = (0..10).collect();
        let out = parallel_map(&inputs, 1, |i, _| {
            let prev = order.fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev as usize, i); // strictly in order with 1 worker
            Ok(i)
        })
        .unwrap();
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::AtomicUsize;
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let inputs: Vec<usize> = (0..16).collect();
        parallel_map(&inputs, 4, |_, _| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert!(peak.load(Ordering::SeqCst) >= 2, "no observed parallelism");
    }

    #[test]
    fn error_propagates() {
        let inputs = vec![1usize, 2, 3];
        let res = parallel_map(&inputs, 2, |_, &x| {
            if x == 2 {
                Err(anyhow!("boom"))
            } else {
                Ok(x)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn panic_becomes_error() {
        let inputs = vec![0usize, 1];
        let res = parallel_map(&inputs, 2, |_, &x| {
            if x == 1 {
                panic!("kaboom {x}");
            }
            Ok(x)
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("kaboom"), "{err}");
    }

    #[test]
    fn sharded_maps_in_order() {
        let inputs: Vec<usize> = (0..100).collect();
        // shard by value parity: two shards on four workers
        let out =
            parallel_map_sharded(&inputs, 4, |_, &x| (x % 2) as u64, |_, &x| Ok(x * 2))
                .unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_empty_input() {
        let out: Vec<usize> =
            parallel_map_sharded(&[], 4, |_, _: &usize| 0, |_, _x: &usize| Ok(1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn sharded_steals_from_hot_shard() {
        use std::sync::atomic::AtomicUsize;
        // every job lands on shard 0; stealing must still engage all workers
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let inputs: Vec<usize> = (0..16).collect();
        let out = parallel_map_sharded(&inputs, 4, |_, _| 0, |_, &x| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(15));
            live.fetch_sub(1, Ordering::SeqCst);
            Ok(x)
        })
        .unwrap();
        assert_eq!(out, (0..16).collect::<Vec<_>>());
        assert!(
            peak.load(Ordering::SeqCst) >= 2,
            "work stealing never engaged a second worker"
        );
    }

    #[test]
    fn sharded_panic_becomes_error() {
        let inputs = vec![0usize, 1, 2, 3];
        let res = parallel_map_sharded(&inputs, 2, |i, _| i as u64, |_, &x| {
            if x == 3 {
                panic!("sharded kaboom");
            }
            Ok(x)
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("sharded kaboom"), "{err}");
    }

    #[test]
    fn default_workers_bounds() {
        assert_eq!(default_workers(0), 1);
        assert!(default_workers(1000) >= 1);
        assert!(default_workers(2) <= 2);
    }

    #[test]
    fn indexed_returns_in_order_for_any_worker_count() {
        let want: Vec<usize> = (0..37).map(|i| i * i).collect();
        for workers in [1, 2, 5, 64] {
            let got = parallel_indexed(37, workers, |i| i * i);
            assert_eq!(got, want, "workers={workers}");
        }
        assert!(parallel_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn chunks_visits_every_item_with_its_index() {
        for workers in [1, 3, 8] {
            let mut items: Vec<usize> = vec![0; 23];
            parallel_chunks(&mut items, workers, |i, slot| *slot = i + 1);
            let want: Vec<usize> = (1..=23).collect();
            assert_eq!(items, want, "workers={workers}");
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_chunks(&mut empty, 4, |_, _| unreachable!());
    }

    #[test]
    fn intraop_knob_round_trips() {
        // results are worker-count invariant by design, so briefly raising
        // the global knob cannot perturb concurrently running tests
        let before = intraop_workers();
        set_intraop_workers(3);
        assert_eq!(intraop_workers(), 3);
        set_intraop_workers(0); // clamps to 1
        assert_eq!(intraop_workers(), 1);
        set_intraop_workers(before);
    }
}
