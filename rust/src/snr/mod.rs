//! The paper's SNR framework (Eq. 3 / Eq. 4): quantifies when Adam's
//! second-moment tensors can be replaced by their means along sharing
//! dimensions K.
//!
//! ```text
//! SNR_K(V) = E_{K'}[ (E_K[V])^2 / Var_K[V] ]          (Eq. 3)
//! ```
//!
//! `SNR_K >~ 1` — entries along K are described by their mean (compressible);
//! `SNR_K <~ 1` — individual entries carry information (incompressible).
//!
//! [`SnrProbe`] records trajectories at the paper's measurement cadence
//! (every 100 steps for the first 1k, then every 1k — scaled for this
//! testbed) and [`SnrSummary`] holds the Eq. 4 time averages that drive
//! rule derivation in [`crate::rules`].

use std::collections::BTreeMap;

use crate::optim::Optimizer;
use crate::runtime::manifest::{KMode, ParamInfo};
use crate::tensor::Tensor;

/// Variance floor: a constant slice has zero variance and is perfectly
/// compressible; the floor maps it to a very large finite SNR (same
/// convention as the Python oracle ref.py).
pub const VAR_FLOOR: f64 = 1e-30;

/// SNR_K of a matrix view (rows = fan_out, cols = fan_in), Eq. 3.
///
/// * `KMode::FanOut` reduces over rows (axis 0); the outer mean runs over
///   columns.
/// * `KMode::FanIn` reduces over columns (axis 1); outer mean over rows.
/// * `KMode::Both` reduces over everything (single group).
///
/// Groups whose variance underflows [`VAR_FLOOR`] (e.g. constant slices)
/// report a very large finite SNR: a constant slice is perfectly
/// described by its mean, hence perfectly compressible.
///
/// ```
/// use slimadam::runtime::KMode;
/// use slimadam::snr::snr_of_view;
///
/// // Rows are constant -> each row is its own mean: compressing along
/// // fan_in (averaging within rows) loses nothing, so SNR is huge...
/// let v = [1.0f32, 1.0, 1.0, 5.0, 5.0, 5.0];
/// assert!(snr_of_view(2, 3, &v, KMode::FanIn) > 1e6);
///
/// // ...while collapsing the whole tensor to one scalar mixes the two
/// // distinct rows: mean 3, variance 4 -> SNR = 9/4, "averse" zone.
/// let both = snr_of_view(2, 3, &v, KMode::Both);
/// assert!((both - 2.25).abs() < 1e-9);
/// ```
pub fn snr_of_view(rows: usize, cols: usize, data: &[f32], k: KMode) -> f64 {
    debug_assert_eq!(rows * cols, data.len());
    let group = |s1: f64, s2: f64, n: f64| -> f64 {
        let mean = s1 / n;
        let var = (s2 / n - mean * mean).max(VAR_FLOOR);
        mean * mean / var
    };
    match k {
        KMode::FanOut => {
            // per-column moments over rows
            let mut s1 = vec![0.0f64; cols];
            let mut s2 = vec![0.0f64; cols];
            for r in 0..rows {
                for c in 0..cols {
                    let x = data[r * cols + c] as f64;
                    s1[c] += x;
                    s2[c] += x * x;
                }
            }
            let n = rows as f64;
            (0..cols).map(|c| group(s1[c], s2[c], n)).sum::<f64>() / cols as f64
        }
        KMode::FanIn => {
            let n = cols as f64;
            (0..rows)
                .map(|r| {
                    let row = &data[r * cols..(r + 1) * cols];
                    let s1: f64 = row.iter().map(|&x| x as f64).sum();
                    let s2: f64 = row.iter().map(|&x| (x as f64) * (x as f64)).sum();
                    group(s1, s2, n)
                })
                .sum::<f64>()
                / rows as f64
        }
        KMode::Both => {
            let s1: f64 = data.iter().map(|&x| x as f64).sum();
            let s2: f64 = data.iter().map(|&x| (x as f64) * (x as f64)).sum();
            group(s1, s2, (rows * cols) as f64)
        }
        KMode::None => f64::INFINITY, // no compression — SNR undefined/∞
        KMode::Blocks(nb) => {
            // mean over each row-block (Adam-mini-style partition)
            let rows_per = (rows / nb).max(1);
            let n = (rows_per * cols) as f64;
            (0..nb)
                .map(|b| {
                    let lo = b * rows_per * cols;
                    let hi = ((b + 1) * rows_per * cols).min(data.len());
                    let blk = &data[lo..hi];
                    let s1: f64 = blk.iter().map(|&x| x as f64).sum();
                    let s2: f64 = blk.iter().map(|&x| (x as f64) * (x as f64)).sum();
                    group(s1, s2, n)
                })
                .sum::<f64>()
                / nb as f64
        }
    }
}

/// SNR triple for one tensor at one step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnrSample {
    pub step: usize,
    pub fan_out: f64,
    pub fan_in: f64,
    pub both: f64,
}

impl SnrSample {
    pub fn get(&self, k: KMode) -> f64 {
        match k {
            KMode::FanOut => self.fan_out,
            KMode::FanIn => self.fan_in,
            KMode::Both => self.both,
            _ => f64::NAN,
        }
    }
}

/// Measure the SNR triple of a (full-shape) second-moment tensor.
pub fn measure(v: &Tensor, info: &ParamInfo) -> SnrSample {
    let view = v.matrix_view(info.fan_out_axis);
    let (r, c) = (view.rows, view.cols);
    SnrSample {
        step: 0,
        fan_out: snr_of_view(r, c, &view.data, KMode::FanOut),
        fan_in: snr_of_view(r, c, &view.data, KMode::FanIn),
        both: snr_of_view(r, c, &view.data, KMode::Both),
    }
}

/// Paper measurement cadence, scaled: the paper probes every 100 steps for
/// the first 1000 and every 1000 after; our runs are ~10-50x shorter, so we
/// probe every `early_every` for the first `early_until` steps and
/// `late_every` after.
#[derive(Debug, Clone, Copy)]
pub struct ProbeSchedule {
    pub early_every: usize,
    pub early_until: usize,
    pub late_every: usize,
}

impl Default for ProbeSchedule {
    fn default() -> Self {
        ProbeSchedule {
            early_every: 10,
            early_until: 100,
            late_every: 50,
        }
    }
}

impl ProbeSchedule {
    pub fn should_probe(&self, step: usize) -> bool {
        if step == 0 {
            return false;
        }
        if step <= self.early_until {
            step % self.early_every == 0
        } else {
            step % self.late_every == 0
        }
    }
}

/// Trajectory recorder over a training run.
#[derive(Debug, Default, Clone)]
pub struct SnrProbe {
    /// param index -> samples over time
    pub records: BTreeMap<usize, Vec<SnrSample>>,
}

impl SnrProbe {
    pub fn new() -> SnrProbe {
        SnrProbe::default()
    }

    /// Record the current second moments of `opt` (skips optimizers without
    /// an Adam-style V, e.g. Lion/SGD-M).
    pub fn record(&mut self, step: usize, opt: &dyn Optimizer, metas: &[ParamInfo]) {
        for (i, info) in metas.iter().enumerate() {
            if let Some(v) = opt.second_moment(i) {
                let mut s = measure(&v, info);
                s.step = step;
                self.records.entry(i).or_default().push(s);
            }
        }
    }

    /// Record from already-materialized V tensors (fused engine path).
    pub fn record_tensors(&mut self, step: usize, vs: &[Tensor], metas: &[ParamInfo]) {
        for (i, (v, info)) in vs.iter().zip(metas).enumerate() {
            let mut s = measure(v, info);
            s.step = step;
            self.records.entry(i).or_default().push(s);
        }
    }

    /// Eq. 4 time-averaged SNR per parameter.
    pub fn summary(&self, metas: &[ParamInfo]) -> SnrSummary {
        let per_param = metas
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let samples = self.records.get(&i).map(|v| v.as_slice()).unwrap_or(&[]);
                average(samples)
            })
            .collect();
        SnrSummary {
            per_param,
            metas: metas.to_vec(),
        }
    }
}

fn average(samples: &[SnrSample]) -> SnrAvg {
    if samples.is_empty() {
        return SnrAvg {
            fan_out: f64::NAN,
            fan_in: f64::NAN,
            both: f64::NAN,
            n: 0,
        };
    }
    let n = samples.len() as f64;
    SnrAvg {
        fan_out: samples.iter().map(|s| s.fan_out).sum::<f64>() / n,
        fan_in: samples.iter().map(|s| s.fan_in).sum::<f64>() / n,
        both: samples.iter().map(|s| s.both).sum::<f64>() / n,
        n: samples.len(),
    }
}

/// Time-averaged SNR triple (Eq. 4).
#[derive(Debug, Clone, Copy)]
pub struct SnrAvg {
    pub fan_out: f64,
    pub fan_in: f64,
    pub both: f64,
    pub n: usize,
}

impl SnrAvg {
    pub fn get(&self, k: KMode) -> f64 {
        match k {
            KMode::FanOut => self.fan_out,
            KMode::FanIn => self.fan_in,
            KMode::Both => self.both,
            _ => f64::NAN,
        }
    }

    /// `(best K, its SNR)` among the three compression modes.
    pub fn best(&self) -> (KMode, f64) {
        let mut best = (KMode::FanOut, self.fan_out);
        if self.fan_in > best.1 {
            best = (KMode::FanIn, self.fan_in);
        }
        if self.both > best.1 {
            best = (KMode::Both, self.both);
        }
        best
    }
}

/// Eq. 4 summary over a whole model.
#[derive(Debug, Clone)]
pub struct SnrSummary {
    pub per_param: Vec<SnrAvg>,
    pub metas: Vec<ParamInfo>,
}

impl SnrSummary {
    /// Average the summary over depth for each layer type (the paper's
    /// Fig. 3-style aggregation; also the SlimAdam-mean rule basis).
    pub fn by_layer_type(&self) -> BTreeMap<String, SnrAvg> {
        let mut groups: BTreeMap<String, Vec<SnrAvg>> = BTreeMap::new();
        for (avg, info) in self.per_param.iter().zip(&self.metas) {
            if info.is_vector() {
                continue;
            }
            groups
                .entry(info.layer_type.clone())
                .or_default()
                .push(*avg);
        }
        groups
            .into_iter()
            .map(|(k, v)| {
                let n = v.len() as f64;
                (
                    k,
                    SnrAvg {
                        fan_out: v.iter().map(|a| a.fan_out).sum::<f64>() / n,
                        fan_in: v.iter().map(|a| a.fan_in).sum::<f64>() / n,
                        both: v.iter().map(|a| a.both).sum::<f64>() / n,
                        n: v.len(),
                    },
                )
            })
            .collect()
    }

    pub fn to_json(&self) -> crate::json::Value {
        let mut arr = Vec::new();
        for (avg, info) in self.per_param.iter().zip(&self.metas) {
            let mut o = crate::json::Value::obj();
            o.set("name", info.name.clone())
                .set("layer_type", info.layer_type.clone())
                .set("depth", info.depth)
                .set("fan_out", finite(avg.fan_out))
                .set("fan_in", finite(avg.fan_in))
                .set("both", finite(avg.both))
                .set("samples", avg.n);
            arr.push(o);
        }
        crate::json::Value::Arr(arr)
    }
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn info(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            shape: shape.to_vec(),
            layer_type: "attn_q".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    fn conv_info(shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: "conv".into(),
            shape: shape.to_vec(),
            layer_type: "conv".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    /// Conv-shaped second moments (OIHW, matrix view `(C_out, C_in·kh·kw)`)
    /// at the degenerate geometries the zoo's k_mode rules must survive:
    /// 1×1 kernels and single-channel filters.
    #[test]
    fn conv_view_edge_cases() {
        // 1×1 kernels: (C_out, C_in, 1, 1) → view (C_out, C_in). Constant
        // filters are perfectly fan_in compressible (variance floor).
        let mut v = Tensor::zeros(&[4, 3, 1, 1]);
        for (i, x) in v.data.iter_mut().enumerate() {
            *x = (i / 3) as f32 + 1.0;
        }
        let s = measure(&v, &conv_info(&[4, 3, 1, 1]));
        assert!(s.fan_in > 1e6, "constant filters: fan_in {}", s.fan_in);
        assert!(s.fan_out.is_finite() && s.fan_out < 1e3, "{}", s.fan_out);

        // single input channel: (C_out, 1, kh, kw) → view (C_out, kh·kw);
        // a uniform tensor is compressible along every K
        let t = Tensor::ones(&[5, 1, 3, 3]);
        let s = measure(&t, &conv_info(&[5, 1, 3, 3]));
        for (k, snr) in [("fan_out", s.fan_out), ("fan_in", s.fan_in), ("both", s.both)] {
            assert!(snr > 1e6, "{k}: {snr}");
        }

        // 1×1 kernel AND single channel: (C_out, 1, 1, 1) degenerates to
        // an N×1 view — fan_in groups are singletons (floor), fan_out is
        // ordinary column statistics
        let d = Tensor::from_vec(&[2, 1, 1, 1], vec![1.0, 3.0]);
        let s = measure(&d, &conv_info(&[2, 1, 1, 1]));
        assert!(s.fan_in > 1e20, "{}", s.fan_in);
        assert!(s.fan_out.is_finite() && s.fan_out < 1e6, "{}", s.fan_out);
    }

    #[test]
    fn constant_matrix_has_huge_snr() {
        let data = vec![0.3f32; 24];
        for k in [KMode::FanOut, KMode::FanIn, KMode::Both] {
            assert!(snr_of_view(4, 6, &data, k) > 1e6, "{k:?}");
        }
    }

    #[test]
    fn heavy_row_kills_fan_out_snr() {
        // one dominant row -> columns have huge variance relative to mean
        let mut data = vec![1e-3f32; 8 * 4];
        for c in 0..4 {
            data[c] = 100.0;
        }
        let fan_out = snr_of_view(8, 4, &data, KMode::FanOut);
        let fan_in = snr_of_view(8, 4, &data, KMode::FanIn);
        assert!(fan_out < 1.0, "{fan_out}");
        // rows themselves are constant -> fan_in SNR huge
        assert!(fan_in > 1e3, "{fan_in}");
    }

    #[test]
    fn degenerate_1xn_and_nx1_views() {
        // 1×N: fan_out groups are single elements (zero variance → floor
        // → huge SNR); fan_in is ordinary row statistics. N×1 mirrors it.
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let mean: f64 = 2.5;
        let var: f64 = 1.25; // E[x²] − mean² = 7.5 − 6.25
        let want = mean * mean / var;

        let fo = snr_of_view(1, 4, &data, KMode::FanOut);
        assert!(fo > 1e20, "1xN fan_out should hit the floor: {fo}");
        let fi = snr_of_view(1, 4, &data, KMode::FanIn);
        assert!((fi - want).abs() < 1e-9, "{fi} vs {want}");

        let fo2 = snr_of_view(4, 1, &data, KMode::FanOut);
        assert!((fo2 - want).abs() < 1e-9, "{fo2} vs {want}");
        let fi2 = snr_of_view(4, 1, &data, KMode::FanIn);
        assert!(fi2 > 1e20, "Nx1 fan_in should hit the floor: {fi2}");

        // Both-mode agrees between the two layouts (same flat data)
        let b1 = snr_of_view(1, 4, &data, KMode::Both);
        let b2 = snr_of_view(4, 1, &data, KMode::Both);
        assert!((b1 - b2).abs() < 1e-12);
    }

    #[test]
    fn constant_slices_hit_var_floor_exactly() {
        // Row r holds the constant r+1: each fan_in group has zero
        // variance, so SNR_r = (r+1)² / VAR_FLOOR and the outer mean is
        // the exact average of those floored ratios.
        let mut data = vec![0.0f32; 3 * 5];
        for r in 0..3 {
            for c in 0..5 {
                data[r * 5 + c] = (r + 1) as f32;
            }
        }
        let fi = snr_of_view(3, 5, &data, KMode::FanIn);
        let want = (1.0 + 4.0 + 9.0) / 3.0 / VAR_FLOOR;
        assert!((fi - want).abs() / want < 1e-9, "{fi} vs {want}");
        assert!(fi.is_finite(), "floor must keep SNR finite");
    }

    #[test]
    fn matches_two_pass_reference() {
        // independent naive implementation as oracle
        let mut rng = crate::rng::Rng::new(7);
        let rows = 13;
        let cols = 9;
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| (rng.normal().abs() + 1e-3) as f32)
            .collect();
        // fan_in oracle
        let mut acc = 0.0f64;
        for r in 0..rows {
            let row: Vec<f64> = (0..cols).map(|c| data[r * cols + c] as f64).collect();
            let mean = row.iter().sum::<f64>() / cols as f64;
            let var = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / cols as f64;
            acc += mean * mean / var.max(VAR_FLOOR);
        }
        let want = acc / rows as f64;
        let got = snr_of_view(rows, cols, &data, KMode::FanIn);
        assert!((got - want).abs() / want < 1e-9);
    }

    #[test]
    fn permutation_invariance_along_compressed_dim() {
        // SNR_fan_in must be invariant to permuting columns
        crate::proptest::check(20, |g| {
            let rows = g.usize(2, 10);
            let cols = g.usize(2, 10);
            let data: Vec<f32> = (0..rows * cols)
                .map(|_| g.f32(1e-4, 1.0))
                .collect();
            let base = snr_of_view(rows, cols, &data, KMode::FanIn);
            // swap two columns
            let (c1, c2) = (g.usize(0, cols - 1), g.usize(0, cols - 1));
            let mut perm = data.clone();
            for r in 0..rows {
                perm.swap(r * cols + c1, r * cols + c2);
            }
            let after = snr_of_view(rows, cols, &perm, KMode::FanIn);
            crate::proptest::prop_assert(
                crate::proptest::close(base, after, 1e-9, 1e-12),
                format!("{base} vs {after}"),
            )
        });
    }

    #[test]
    fn probe_and_summary() {
        use crate::optim::adamk::AdamK;
        use crate::optim::{Hypers, KMode as K};
        let meta = info(&[6, 8]);
        let mut opt = AdamK::new("adam", vec![meta.clone()], vec![K::None], Hypers::default());
        let mut probe = SnrProbe::new();
        let mut rng = crate::rng::Rng::new(1);
        let mut params = vec![Tensor::from_vec(
            &[6, 8],
            (0..48).map(|_| rng.normal() as f32).collect(),
        )];
        for t in 1..=20 {
            let g = Tensor::from_vec(&[6, 8], (0..48).map(|_| rng.normal() as f32).collect());
            opt.step(&mut params, &[g], t, 1e-3);
            if t % 5 == 0 {
                probe.record(t, &opt, std::slice::from_ref(&meta));
            }
        }
        let summary = probe.summary(std::slice::from_ref(&meta));
        assert_eq!(summary.per_param.len(), 1);
        let avg = summary.per_param[0];
        assert_eq!(avg.n, 4);
        assert!(avg.fan_out.is_finite() && avg.fan_out > 0.0);
        // isotropic gaussian grads: all modes compressible, SNR >> 1
        assert!(avg.both > 1.0);
    }

    #[test]
    fn schedule_cadence() {
        let s = ProbeSchedule::default();
        assert!(!s.should_probe(0));
        assert!(s.should_probe(10));
        assert!(!s.should_probe(15));
        assert!(s.should_probe(100));
        assert!(!s.should_probe(110));
        assert!(s.should_probe(150));
    }

    #[test]
    fn by_layer_type_averages_depth() {
        let metas = vec![
            ParamInfo { depth: 0, ..info(&[4, 4]) },
            ParamInfo { depth: 1, ..info(&[4, 4]) },
        ];
        let mut probe = SnrProbe::new();
        let vs = vec![Tensor::ones(&[4, 4]), Tensor::full(&[4, 4], 2.0)];
        probe.record_tensors(1, &vs, &metas);
        let by_type = probe.summary(&metas).by_layer_type();
        assert_eq!(by_type.len(), 1);
        assert_eq!(by_type["attn_q"].n, 2);
    }
}
