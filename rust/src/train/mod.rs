//! Training-loop driver: LR schedules (paper App. B: linear warmup →
//! cosine decay to η/10), gradient clipping, the split- and fused-engine
//! step loops, SNR probing hooks, checkpointing and divergence detection.

pub mod checkpoint;

use anyhow::Result;

use crate::data::DataSource;
use crate::obs::{self, registry, telemetry, SpanKind};
use crate::optim::{clip_global_norm, KMode, Optimizer};
use crate::rules::adaptive::{AdaptivePolicy, AdaptiveReport, Controller, Direction};
use crate::runtime::engine::{BatchData, GradEngine, TrainEngine};
use crate::snr::{ProbeSchedule, SnrProbe};
use crate::tensor::Tensor;

/// Linear-warmup + cosine-decay schedule (paper App. B.1).
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub base_lr: f64,
    pub warmup: usize,
    pub total: usize,
    /// final LR = base_lr * min_ratio (paper: 1/10)
    pub min_ratio: f64,
}

impl Schedule {
    pub fn new(base_lr: f64, warmup: usize, total: usize) -> Schedule {
        Schedule {
            base_lr,
            warmup,
            total,
            min_ratio: 0.1,
        }
    }

    /// LR at 1-based step `t`: linear warmup to `base_lr`, then cosine
    /// decay to `base_lr * min_ratio`, held flat past `total`.
    ///
    /// ```
    /// use slimadam::train::Schedule;
    ///
    /// let s = Schedule::new(1e-3, 10, 100);
    /// assert!((s.lr(5) - 5e-4).abs() < 1e-12);    // linear warmup
    /// assert!((s.lr(10) - 1e-3).abs() < 1e-12);   // peak at warmup end
    /// assert!(s.lr(55) < 1e-3 && s.lr(55) > 1e-4); // cosine decay
    /// assert!((s.lr(100) - 1e-4).abs() < 1e-12);  // floor: base_lr / 10
    /// assert_eq!(s.lr(400), s.lr(100));           // flat after `total`
    /// ```
    pub fn lr(&self, t: usize) -> f64 {
        if self.warmup > 0 && t <= self.warmup {
            return self.base_lr * t as f64 / self.warmup as f64;
        }
        let min_lr = self.base_lr * self.min_ratio;
        if t >= self.total {
            return min_lr;
        }
        let progress = (t - self.warmup) as f64 / (self.total - self.warmup).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        min_lr + (self.base_lr - min_lr) * cos
    }
}

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// (step, train loss) at every step
    pub losses: Vec<(usize, f32)>,
    /// mean train loss over the final 10% of steps
    pub final_train_loss: f64,
    /// held-out loss averaged over `eval_batches` at the end
    pub eval_loss: f64,
    /// true if loss became non-finite or exceeded 5x the initial loss
    pub diverged: bool,
    pub probe: SnrProbe,
    pub wallclock_s: f64,
}

impl RunResult {
    /// Order-stable digest of the run's metrics: every `(step, loss)`
    /// pair bit-exactly, plus final/eval loss and the divergence flag.
    /// Two runs are "byte-identical" iff their fingerprints match — the
    /// scheduler's determinism tests and streamed JSONL rows rely on
    /// this (wall-clock and probe data are deliberately excluded).
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.losses.len() * 12 + 17);
        for &(step, loss) in &self.losses {
            bytes.extend_from_slice(&(step as u64).to_le_bytes());
            bytes.extend_from_slice(&loss.to_bits().to_le_bytes());
        }
        bytes.extend_from_slice(&self.final_train_loss.to_bits().to_le_bytes());
        bytes.extend_from_slice(&self.eval_loss.to_bits().to_le_bytes());
        bytes.push(self.diverged as u8);
        crate::rng::stable_hash64(&bytes)
    }
}

fn finalize(
    losses: Vec<(usize, f32)>,
    eval_loss: f64,
    diverged: bool,
    probe: SnrProbe,
    t0: std::time::Instant,
) -> RunResult {
    let tail = (losses.len() / 10).max(1);
    let final_train_loss = losses
        .iter()
        .rev()
        .take(tail)
        .map(|&(_, l)| l as f64)
        .sum::<f64>()
        / tail as f64;
    RunResult {
        losses,
        final_train_loss,
        eval_loss,
        diverged,
        probe,
        wallclock_s: t0.elapsed().as_secs_f64(),
    }
}

/// Divergence guard: stop early when training explodes (the paper's
/// LR-sensitivity plots mark these points at the top of the loss axis).
fn is_diverged(loss: f32, initial: f32) -> bool {
    !loss.is_finite() || loss > 5.0 * initial + 5.0
}

/// Intern the model name as a span label only when tracing is live.
fn obs_label(model_name: &str) -> u32 {
    if obs::enabled() {
        obs::intern(model_name)
    } else {
        obs::NO_LABEL
    }
}

/// Count a divergence exit (one per job that leaves a loop early).
fn note_divergence() {
    registry::counter("train.divergence_exits").inc();
}

/// Split-engine loop: HLO grad_step + Rust optimizer.
///
/// `accum` > 1 averages gradients over that many micro-batches before each
/// update (the paper's gradient-accumulation setup, scaled).
#[allow(clippy::too_many_arguments)]
pub fn train_split(
    engine: &GradEngine,
    opt: &mut dyn Optimizer,
    params: &mut Vec<Tensor>,
    data: &mut dyn DataSource,
    schedule: &Schedule,
    steps: usize,
    probe_schedule: Option<ProbeSchedule>,
    accum: usize,
    eval_batches: usize,
) -> Result<RunResult> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let clip = man.hypers.map(|h| h.clip_norm).unwrap_or(1.0);
    let label = obs_label(&man.model_name);
    let mut probe = SnrProbe::new();
    let mut losses = Vec::with_capacity(steps);
    let mut initial = f32::NAN;
    let mut diverged = false;

    for t in 1..=steps {
        let step_t0 = obs::clock();
        // accumulate grads over micro-batches
        let mut loss_acc = 0.0f32;
        let mut grads: Option<Vec<Tensor>> = None;
        for _ in 0..accum.max(1) {
            let batch = data.next_batch();
            let (loss, g) = engine.step(params, &batch)?;
            loss_acc += loss;
            grads = Some(match grads {
                None => g,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(&g) {
                        for (x, y) in a.data.iter_mut().zip(&b.data) {
                            *x += *y;
                        }
                    }
                    acc
                }
            });
        }
        let mut grads = grads.unwrap();
        let inv = 1.0 / accum.max(1) as f32;
        if accum > 1 {
            for g in grads.iter_mut() {
                for x in g.data.iter_mut() {
                    *x *= inv;
                }
            }
        }
        let loss = loss_acc * inv;
        if t == 1 {
            initial = loss;
        }
        losses.push((t, loss));
        if is_diverged(loss, initial) {
            diverged = true;
            note_divergence();
            break;
        }

        clip_global_norm(&mut grads, clip);
        let lr = schedule.lr(t) as f32;
        opt.step(params, &grads, t, lr);
        obs::emit_since(SpanKind::Step, label, step_t0, [t as u64, 0, 0, 0]);

        if telemetry::active(t) {
            telemetry::record_opt(t, label, &*opt, &man.params);
        }
        if let Some(ps) = &probe_schedule {
            if ps.should_probe(t) {
                probe.record(t, opt, &man.params);
            }
        }
    }

    // held-out evaluation
    let eval_t0 = obs::clock();
    let mut eval_loss = 0.0f64;
    let n_eval = if diverged { 0 } else { eval_batches };
    for _ in 0..n_eval {
        let batch = data.eval_batch();
        let (loss, _) = engine.step(params, &batch)?;
        eval_loss += loss as f64;
    }
    if n_eval > 0 {
        obs::emit_since(SpanKind::Eval, label, eval_t0, [n_eval as u64, 0, 0, 0]);
    }
    let eval_loss = if n_eval > 0 {
        eval_loss / n_eval as f64
    } else {
        f64::INFINITY
    };

    Ok(finalize(losses, eval_loss, diverged, probe, t0))
}

/// Fused-engine loop: one PJRT dispatch per step; probing reads the
/// device-resident V tensors at the schedule cadence.
pub fn train_fused(
    engine: &mut TrainEngine,
    data: &mut dyn DataSource,
    schedule: &Schedule,
    steps: usize,
    probe_schedule: Option<ProbeSchedule>,
) -> Result<RunResult> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let label = obs_label(&man.model_name);
    let mut probe = SnrProbe::new();
    let mut losses = Vec::with_capacity(steps);
    let mut initial = f32::NAN;
    let mut diverged = false;

    for t in 1..=steps {
        let step_t0 = obs::clock();
        let batch = data.next_batch();
        let stats = engine.step(&batch, schedule.lr(t) as f32)?;
        obs::emit_since(SpanKind::Step, label, step_t0, [t as u64, 0, 0, 0]);
        if t == 1 {
            initial = stats.loss;
        }
        losses.push((t, stats.loss));
        if is_diverged(stats.loss, initial) {
            diverged = true;
            note_divergence();
            break;
        }
        if telemetry::active(t) {
            let vs = engine.second_moments()?;
            telemetry::record_tensors(t, label, &vs, &man.params);
        }
        if let Some(ps) = &probe_schedule {
            if ps.should_probe(t) {
                // Only exact (K=∅) second moments give the paper's Adam SNR;
                // compressed artifacts still record their reduced-V SNR.
                let vs = engine.second_moments()?;
                probe.record_tensors(t, &vs, &man.params);
            }
        }
    }

    // eval via extra fused steps at lr=0 would perturb state; instead use
    // the final training-loss tail as the comparable metric for fused runs.
    Ok(finalize(losses, f64::NAN, diverged, probe, t0))
}

/// Self-tuning fused loop (DESIGN.md §18): [`train_fused`] plus the
/// adaptive controller. At the policy cadence the controller reads each
/// ruled tensor's SNR and may migrate its second moment between the
/// artifact's baked reduced mode and full-V Adam; the native backend
/// infers the effective K from the stored length on the next dispatch.
///
/// The controller's signal is the SNR of m⊙m under the tensor's target
/// K. M is always stored at the full parameter shape in *both* storage
/// modes, so the signal — and therefore the whole decision sequence — is
/// a pure function of the training trajectory, never of the controller's
/// own past decisions' storage layout. (V-based SNR would degenerate the
/// moment a tensor compresses: reduced V is constant within each sharing
/// group by construction.) m and v track the same g/g² streams through
/// matching EMAs, so m² ranks tensors the way the paper's V-based probe
/// does.
///
/// With a policy that never fires (e.g. [`AdaptivePolicy::never_fire`])
/// this loop is bit-identical to [`train_fused`] on the same engine:
/// controller reads don't touch engine state
/// (`rust/tests/batched_agreement.rs` locks this differentially).
#[allow(clippy::too_many_arguments)]
pub fn train_fused_adaptive(
    engine: &mut TrainEngine,
    data: &mut dyn DataSource,
    schedule: &Schedule,
    steps: usize,
    probe_schedule: Option<ProbeSchedule>,
    policy: AdaptivePolicy,
) -> Result<(RunResult, AdaptiveReport)> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let label = obs_label(&man.model_name);
    let target = man
        .k_modes
        .clone()
        .ok_or_else(|| anyhow::anyhow!("adaptive training needs a train_step manifest with k_modes"))?;
    anyhow::ensure!(
        man.optimizer_name() == "adamw",
        "adaptive rule switching is defined for the AdamW family, not {:?}",
        man.optimizer_name()
    );
    let names: Vec<String> = man.params.iter().map(|p| p.name.clone()).collect();
    let mut ctl = Controller::slim_start(policy, names, target.clone());
    let ruled = (0..ctl.n_tensors()).filter(|&i| !ctl.is_inert(i)).count();
    let full_v_elems = man.total_param_elems();
    let mut timeline = vec![(0usize, engine.v_elem_counts()?.iter().sum::<usize>())];

    let mut probe = SnrProbe::new();
    let mut losses = Vec::with_capacity(steps);
    let mut initial = f32::NAN;
    let mut diverged = false;

    for t in 1..=steps {
        let step_t0 = obs::clock();
        let batch = data.next_batch();
        let stats = engine.step(&batch, schedule.lr(t) as f32)?;
        obs::emit_since(SpanKind::Step, label, step_t0, [t as u64, 0, 0, 0]);
        if t == 1 {
            initial = stats.loss;
        }
        losses.push((t, stats.loss));
        if is_diverged(stats.loss, initial) {
            diverged = true;
            note_divergence();
            break;
        }
        if ctl.due(t) {
            let eval_t0 = obs::clock();
            let ms = engine.first_moments()?;
            let snrs: Vec<f64> = ms
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    if ctl.is_inert(i) {
                        return f64::NAN;
                    }
                    let info = &man.params[i];
                    let m2 = Tensor::from_vec(
                        &info.shape,
                        m.data.iter().map(|&x| x * x).collect(),
                    );
                    let view = m2.matrix_view(info.fan_out_axis);
                    crate::snr::snr_of_view(
                        view.rows,
                        view.cols,
                        &view.data,
                        crate::optim::adamk::effective_k(info, target[i]),
                    )
                })
                .collect();
            let fired = ctl.observe(t, &snrs);
            for d in &fired {
                let (from_k, to_k) = match d.dir {
                    Direction::Compress => (KMode::None, target[d.tensor]),
                    Direction::Decompress => (target[d.tensor], KMode::None),
                };
                engine.migrate_v(d.tensor, from_k, to_k)?;
                registry::counter(match d.dir {
                    Direction::Compress => "adaptive.switches.compress",
                    Direction::Decompress => "adaptive.switches.decompress",
                })
                .inc();
                if obs::enabled() {
                    obs::emit(obs::Span {
                        kind: SpanKind::AdaptiveSwitch,
                        start_ns: obs::clock(),
                        dur_ns: 0,
                        label: obs::intern(&d.name),
                        args: [
                            t as u64,
                            matches!(d.dir, Direction::Decompress) as u64,
                            d.snr.to_bits(),
                            0,
                        ],
                    });
                }
            }
            if !fired.is_empty() {
                timeline.push((t, engine.v_elem_counts()?.iter().sum::<usize>()));
            }
            registry::counter("adaptive.evals").inc();
            obs::emit_since(
                SpanKind::AdaptiveEval,
                label,
                eval_t0,
                [
                    t as u64,
                    ctl.n_compressed() as u64,
                    ruled as u64,
                    compressed_frac(&ctl, &man).to_bits(),
                ],
            );
        }
        if telemetry::active(t) {
            let vs = engine.second_moments()?;
            telemetry::record_tensors(t, label, &vs, &man.params);
        }
        if let Some(ps) = &probe_schedule {
            if ps.should_probe(t) {
                let vs = engine.second_moments()?;
                probe.record_tensors(t, &vs, &man.params);
            }
        }
    }

    let final_v_elems = engine.v_elem_counts()?.iter().sum::<usize>();
    let report = AdaptiveReport {
        policy,
        evals: ctl.evals(),
        decisions: ctl.log().to_vec(),
        timeline,
        final_v_elems,
        full_v_elems,
        compressed_frac: compressed_frac(&ctl, &man),
    };
    Ok((finalize(losses, f64::NAN, diverged, probe, t0), report))
}

/// Fraction of Adam's second-moment elements stored compressed: the sum
/// of `numel` over tensors currently in reduced mode, over the total.
fn compressed_frac(
    ctl: &Controller,
    man: &crate::runtime::manifest::Manifest,
) -> f64 {
    let total = man.total_param_elems();
    if total == 0 {
        return 0.0;
    }
    let compressed: usize = (0..ctl.n_tensors())
        .filter(|&i| !ctl.is_inert(i) && ctl.mode(i) == crate::rules::adaptive::Mode::Reduced)
        .map(|i| man.params[i].numel())
        .sum();
    compressed as f64 / total as f64
}

// ---------------------------------------------------------------------------
// Batched lockstep loops (DESIGN.md §12)
//
// `train_split_batch` / `train_fused_batch` drive B same-artifact jobs in
// lockstep: at every step the jobs' inputs are handed to the backend as
// one `run_batch` call. Each job keeps its own data stream, optimizer /
// engine state, schedule and divergence guard, and every per-job call
// sequence (next_batch, eval_batch, clip, update) matches the sequential
// loops above exactly — so per-job results are bit-identical to running
// the jobs one at a time (`rust/tests/batched_agreement.rs`). Jobs that
// diverge leave the lockstep set at the same step they would have exited
// the sequential loop; the rest keep going.
//
// SNR probing is not supported here: the batch planner
// (`coordinator::batch`) routes probed configs through the sequential
// path as singleton groups.
// ---------------------------------------------------------------------------

/// One job's context in a [`train_split_batch`] run.
pub struct SplitJob<'a> {
    pub opt: &'a mut dyn Optimizer,
    pub params: Vec<Tensor>,
    pub data: Box<dyn DataSource>,
    pub schedule: Schedule,
}

/// Split-engine lockstep loop over B jobs sharing one grad executable.
/// Equivalent to calling [`train_split`] once per job (no probing, shared
/// step count / accumulation / eval setup — the batch planner's
/// feasibility key guarantees those match).
pub fn train_split_batch(
    engine: &GradEngine,
    jobs: &mut [SplitJob<'_>],
    steps: usize,
    accum: usize,
    eval_batches: usize,
) -> Result<Vec<RunResult>> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let clip = man.hypers.map(|h| h.clip_norm).unwrap_or(1.0);
    let label = obs_label(&man.model_name);
    let nj = jobs.len();
    let mut losses: Vec<Vec<(usize, f32)>> = (0..nj).map(|_| Vec::with_capacity(steps)).collect();
    let mut initial = vec![f32::NAN; nj];
    let mut diverged = vec![false; nj];
    let mut active: Vec<usize> = (0..nj).collect();

    for t in 1..=steps {
        if active.is_empty() {
            break;
        }
        let step_t0 = obs::clock();
        let lanes = active.len();
        let mut loss_acc = vec![0.0f32; nj];
        let mut grads_acc: Vec<Option<Vec<Tensor>>> = (0..nj).map(|_| None).collect();
        for _ in 0..accum.max(1) {
            let batches: Vec<Vec<BatchData>> =
                active.iter().map(|&i| jobs[i].data.next_batch()).collect();
            let reqs: Vec<(&[Tensor], &[BatchData])> = active
                .iter()
                .zip(&batches)
                .map(|(&i, b)| (jobs[i].params.as_slice(), b.as_slice()))
                .collect();
            let outs = engine.step_batch(&reqs)?;
            for (k, (loss, g)) in outs.into_iter().enumerate() {
                let i = active[k];
                loss_acc[i] += loss;
                grads_acc[i] = Some(match grads_acc[i].take() {
                    None => g,
                    Some(mut acc) => {
                        for (a, b) in acc.iter_mut().zip(&g) {
                            for (x, y) in a.data.iter_mut().zip(&b.data) {
                                *x += *y;
                            }
                        }
                        acc
                    }
                });
            }
        }
        let inv = 1.0 / accum.max(1) as f32;
        let mut still = Vec::with_capacity(active.len());
        for &i in &active {
            let mut grads = grads_acc[i].take().expect("stepped job has grads");
            if accum > 1 {
                for g in grads.iter_mut() {
                    for x in g.data.iter_mut() {
                        *x *= inv;
                    }
                }
            }
            let loss = loss_acc[i] * inv;
            if t == 1 {
                initial[i] = loss;
            }
            losses[i].push((t, loss));
            if is_diverged(loss, initial[i]) {
                diverged[i] = true;
                note_divergence();
                continue;
            }
            clip_global_norm(&mut grads, clip);
            let lr = jobs[i].schedule.lr(t) as f32;
            let job = &mut jobs[i];
            job.opt.step(&mut job.params, &grads, t, lr);
            still.push(i);
        }
        active = still;
        obs::emit_since(
            SpanKind::BatchedStep,
            label,
            step_t0,
            [t as u64, active.len() as u64, lanes as u64, 0],
        );
    }

    // held-out evaluation: batched across non-diverged jobs, preserving
    // each job's eval_batch call sequence
    let eval_t0 = obs::clock();
    let mut eval_acc = vec![0.0f64; nj];
    let survivors: Vec<usize> = (0..nj).filter(|&i| !diverged[i]).collect();
    if eval_batches > 0 && !survivors.is_empty() {
        for _ in 0..eval_batches {
            let batches: Vec<Vec<BatchData>> =
                survivors.iter().map(|&i| jobs[i].data.eval_batch()).collect();
            let reqs: Vec<(&[Tensor], &[BatchData])> = survivors
                .iter()
                .zip(&batches)
                .map(|(&i, b)| (jobs[i].params.as_slice(), b.as_slice()))
                .collect();
            let outs = engine.step_batch(&reqs)?;
            for (k, (loss, _)) in outs.into_iter().enumerate() {
                eval_acc[survivors[k]] += loss as f64;
            }
        }
        obs::emit_since(SpanKind::Eval, label, eval_t0, [eval_batches as u64, 0, 0, 0]);
    }

    let mut out = Vec::with_capacity(nj);
    for (i, job_losses) in losses.into_iter().enumerate() {
        let eval_loss = if diverged[i] || eval_batches == 0 {
            f64::INFINITY
        } else {
            eval_acc[i] / eval_batches as f64
        };
        out.push(finalize(job_losses, eval_loss, diverged[i], SnrProbe::new(), t0));
    }
    amortize_wallclock(&mut out, nj);
    Ok(out)
}

/// Per-job timing inside a lockstep dispatch is not separable, so each
/// job reports its amortized share of the group's wall time — keeping
/// streamed `wallclock_s` / `steps_per_s` comparable with unbatched rows
/// (fingerprints exclude timing entirely, so equivalence is unaffected).
fn amortize_wallclock(results: &mut [RunResult], group_size: usize) {
    for r in results.iter_mut() {
        r.wallclock_s /= group_size.max(1) as f64;
    }
}

/// Fused-engine lockstep loop over B engines sharing one compiled
/// train-step executable. Equivalent to calling [`train_fused`] once per
/// engine (no probing — see the section docs above).
pub fn train_fused_batch(
    engines: &mut [TrainEngine],
    datas: &mut [Box<dyn DataSource>],
    schedules: &[Schedule],
    steps: usize,
) -> Result<Vec<RunResult>> {
    let t0 = std::time::Instant::now();
    let nj = engines.len();
    anyhow::ensure!(
        datas.len() == nj && schedules.len() == nj,
        "train_fused_batch: {} engines, {} data sources, {} schedules",
        nj,
        datas.len(),
        schedules.len()
    );
    let label = engines
        .first()
        .map(|e| obs_label(&e.manifest().model_name))
        .unwrap_or(obs::NO_LABEL);
    let mut losses: Vec<Vec<(usize, f32)>> = (0..nj).map(|_| Vec::with_capacity(steps)).collect();
    let mut initial = vec![f32::NAN; nj];
    let mut diverged = vec![false; nj];
    let mut active: Vec<usize> = (0..nj).collect();

    for t in 1..=steps {
        if active.is_empty() {
            break;
        }
        let step_t0 = obs::clock();
        let lanes = active.len();
        let batches: Vec<Vec<BatchData>> =
            active.iter().map(|&i| datas[i].next_batch()).collect();
        let lrs: Vec<f32> = active.iter().map(|&i| schedules[i].lr(t) as f32).collect();
        // &mut refs to exactly the active engines (active is ascending)
        let mut subset: Vec<&mut TrainEngine> = Vec::with_capacity(active.len());
        {
            let mut next = 0;
            for (i, e) in engines.iter_mut().enumerate() {
                if next < active.len() && active[next] == i {
                    subset.push(e);
                    next += 1;
                }
            }
        }
        let stats = TrainEngine::step_many(&mut subset, &batches, &lrs)?;
        let mut still = Vec::with_capacity(active.len());
        for (k, s) in stats.iter().enumerate() {
            let i = active[k];
            if t == 1 {
                initial[i] = s.loss;
            }
            losses[i].push((t, s.loss));
            if is_diverged(s.loss, initial[i]) {
                diverged[i] = true;
                note_divergence();
            } else {
                still.push(i);
            }
        }
        active = still;
        obs::emit_since(
            SpanKind::BatchedStep,
            label,
            step_t0,
            [t as u64, active.len() as u64, lanes as u64, 0],
        );
    }

    let mut out: Vec<RunResult> = losses
        .into_iter()
        .enumerate()
        .map(|(i, job_losses)| {
            finalize(job_losses, f64::NAN, diverged[i], SnrProbe::new(), t0)
        })
        .collect();
    amortize_wallclock(&mut out, nj);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_warmup_is_linear() {
        let s = Schedule::new(1e-3, 10, 100);
        assert!((s.lr(1) - 1e-4).abs() < 1e-12);
        assert!((s.lr(5) - 5e-4).abs() < 1e-12);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn schedule_cosine_decays_to_min() {
        let s = Schedule::new(1e-3, 10, 100);
        assert!(s.lr(11) < 1e-3);
        assert!(s.lr(99) > 1e-4);
        assert!((s.lr(100) - 1e-4).abs() < 1e-12);
        assert!((s.lr(500) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn schedule_monotone_after_warmup() {
        let s = Schedule::new(3e-3, 20, 200);
        let mut prev = f64::INFINITY;
        for t in 21..=200 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-15, "t={t}");
            prev = lr;
        }
    }

    #[test]
    fn schedule_property_bounds() {
        crate::proptest::check(50, |g| {
            let base = g.log_f64(1e-5, 1e-1);
            let warmup = g.usize(0, 50);
            let total = warmup + g.usize(1, 200);
            let s = Schedule::new(base, warmup, total);
            for _ in 0..20 {
                let t = g.usize(1, total * 2);
                let lr = s.lr(t);
                crate::proptest::prop_assert(
                    lr > 0.0 && lr <= base * (1.0 + 1e-12),
                    format!("lr {lr} out of (0, {base}] at t={t}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fingerprint_tracks_metrics_not_timing() {
        let base = RunResult {
            losses: vec![(1, 2.0), (2, 1.5)],
            final_train_loss: 1.5,
            eval_loss: 1.6,
            diverged: false,
            probe: SnrProbe::new(),
            wallclock_s: 1.0,
        };
        let mut same = base.clone();
        same.wallclock_s = 99.0; // wall-clock must not affect identity
        assert_eq!(base.fingerprint(), same.fingerprint());
        let mut diff = base.clone();
        diff.losses[1].1 = 1.500_000_1;
        assert_ne!(base.fingerprint(), diff.fingerprint());
        let mut div = base.clone();
        div.diverged = true;
        assert_ne!(base.fingerprint(), div.fingerprint());
    }

    #[test]
    fn divergence_guard() {
        assert!(is_diverged(f32::NAN, 1.0));
        assert!(is_diverged(f32::INFINITY, 1.0));
        assert!(is_diverged(100.0, 1.0));
        assert!(!is_diverged(1.2, 1.0));
    }
}
