//! Training-loop driver: LR schedules (paper App. B: linear warmup →
//! cosine decay to η/10), gradient clipping, the split- and fused-engine
//! step loops, SNR probing hooks, checkpointing and divergence detection.

pub mod checkpoint;

use anyhow::Result;

use crate::data::DataSource;
use crate::optim::{clip_global_norm, Optimizer};
use crate::runtime::engine::{GradEngine, TrainEngine};
use crate::snr::{ProbeSchedule, SnrProbe};
use crate::tensor::Tensor;

/// Linear-warmup + cosine-decay schedule (paper App. B.1).
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub base_lr: f64,
    pub warmup: usize,
    pub total: usize,
    /// final LR = base_lr * min_ratio (paper: 1/10)
    pub min_ratio: f64,
}

impl Schedule {
    pub fn new(base_lr: f64, warmup: usize, total: usize) -> Schedule {
        Schedule {
            base_lr,
            warmup,
            total,
            min_ratio: 0.1,
        }
    }

    /// LR at 1-based step `t`: linear warmup to `base_lr`, then cosine
    /// decay to `base_lr * min_ratio`, held flat past `total`.
    ///
    /// ```
    /// use slimadam::train::Schedule;
    ///
    /// let s = Schedule::new(1e-3, 10, 100);
    /// assert!((s.lr(5) - 5e-4).abs() < 1e-12);    // linear warmup
    /// assert!((s.lr(10) - 1e-3).abs() < 1e-12);   // peak at warmup end
    /// assert!(s.lr(55) < 1e-3 && s.lr(55) > 1e-4); // cosine decay
    /// assert!((s.lr(100) - 1e-4).abs() < 1e-12);  // floor: base_lr / 10
    /// assert_eq!(s.lr(400), s.lr(100));           // flat after `total`
    /// ```
    pub fn lr(&self, t: usize) -> f64 {
        if self.warmup > 0 && t <= self.warmup {
            return self.base_lr * t as f64 / self.warmup as f64;
        }
        let min_lr = self.base_lr * self.min_ratio;
        if t >= self.total {
            return min_lr;
        }
        let progress = (t - self.warmup) as f64 / (self.total - self.warmup).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        min_lr + (self.base_lr - min_lr) * cos
    }
}

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// (step, train loss) at every step
    pub losses: Vec<(usize, f32)>,
    /// mean train loss over the final 10% of steps
    pub final_train_loss: f64,
    /// held-out loss averaged over `eval_batches` at the end
    pub eval_loss: f64,
    /// true if loss became non-finite or exceeded 5x the initial loss
    pub diverged: bool,
    pub probe: SnrProbe,
    pub wallclock_s: f64,
}

impl RunResult {
    /// Order-stable digest of the run's metrics: every `(step, loss)`
    /// pair bit-exactly, plus final/eval loss and the divergence flag.
    /// Two runs are "byte-identical" iff their fingerprints match — the
    /// scheduler's determinism tests and streamed JSONL rows rely on
    /// this (wall-clock and probe data are deliberately excluded).
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.losses.len() * 12 + 17);
        for &(step, loss) in &self.losses {
            bytes.extend_from_slice(&(step as u64).to_le_bytes());
            bytes.extend_from_slice(&loss.to_bits().to_le_bytes());
        }
        bytes.extend_from_slice(&self.final_train_loss.to_bits().to_le_bytes());
        bytes.extend_from_slice(&self.eval_loss.to_bits().to_le_bytes());
        bytes.push(self.diverged as u8);
        crate::rng::stable_hash64(&bytes)
    }
}

fn finalize(
    losses: Vec<(usize, f32)>,
    eval_loss: f64,
    diverged: bool,
    probe: SnrProbe,
    t0: std::time::Instant,
) -> RunResult {
    let tail = (losses.len() / 10).max(1);
    let final_train_loss = losses
        .iter()
        .rev()
        .take(tail)
        .map(|&(_, l)| l as f64)
        .sum::<f64>()
        / tail as f64;
    RunResult {
        losses,
        final_train_loss,
        eval_loss,
        diverged,
        probe,
        wallclock_s: t0.elapsed().as_secs_f64(),
    }
}

/// Divergence guard: stop early when training explodes (the paper's
/// LR-sensitivity plots mark these points at the top of the loss axis).
fn is_diverged(loss: f32, initial: f32) -> bool {
    !loss.is_finite() || loss > 5.0 * initial + 5.0
}

/// Split-engine loop: HLO grad_step + Rust optimizer.
///
/// `accum` > 1 averages gradients over that many micro-batches before each
/// update (the paper's gradient-accumulation setup, scaled).
#[allow(clippy::too_many_arguments)]
pub fn train_split(
    engine: &GradEngine,
    opt: &mut dyn Optimizer,
    params: &mut Vec<Tensor>,
    data: &mut dyn DataSource,
    schedule: &Schedule,
    steps: usize,
    probe_schedule: Option<ProbeSchedule>,
    accum: usize,
    eval_batches: usize,
) -> Result<RunResult> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let clip = man.hypers.map(|h| h.clip_norm).unwrap_or(1.0);
    let mut probe = SnrProbe::new();
    let mut losses = Vec::with_capacity(steps);
    let mut initial = f32::NAN;
    let mut diverged = false;

    for t in 1..=steps {
        // accumulate grads over micro-batches
        let mut loss_acc = 0.0f32;
        let mut grads: Option<Vec<Tensor>> = None;
        for _ in 0..accum.max(1) {
            let batch = data.next_batch();
            let (loss, g) = engine.step(params, &batch)?;
            loss_acc += loss;
            grads = Some(match grads {
                None => g,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(&g) {
                        for (x, y) in a.data.iter_mut().zip(&b.data) {
                            *x += *y;
                        }
                    }
                    acc
                }
            });
        }
        let mut grads = grads.unwrap();
        let inv = 1.0 / accum.max(1) as f32;
        if accum > 1 {
            for g in grads.iter_mut() {
                for x in g.data.iter_mut() {
                    *x *= inv;
                }
            }
        }
        let loss = loss_acc * inv;
        if t == 1 {
            initial = loss;
        }
        losses.push((t, loss));
        if is_diverged(loss, initial) {
            diverged = true;
            break;
        }

        clip_global_norm(&mut grads, clip);
        let lr = schedule.lr(t) as f32;
        opt.step(params, &grads, t, lr);

        if let Some(ps) = &probe_schedule {
            if ps.should_probe(t) {
                probe.record(t, opt, &man.params);
            }
        }
    }

    // held-out evaluation
    let mut eval_loss = 0.0f64;
    let n_eval = if diverged { 0 } else { eval_batches };
    for _ in 0..n_eval {
        let batch = data.eval_batch();
        let (loss, _) = engine.step(params, &batch)?;
        eval_loss += loss as f64;
    }
    let eval_loss = if n_eval > 0 {
        eval_loss / n_eval as f64
    } else {
        f64::INFINITY
    };

    Ok(finalize(losses, eval_loss, diverged, probe, t0))
}

/// Fused-engine loop: one PJRT dispatch per step; probing reads the
/// device-resident V tensors at the schedule cadence.
pub fn train_fused(
    engine: &mut TrainEngine,
    data: &mut dyn DataSource,
    schedule: &Schedule,
    steps: usize,
    probe_schedule: Option<ProbeSchedule>,
) -> Result<RunResult> {
    let t0 = std::time::Instant::now();
    let man = engine.manifest().clone();
    let mut probe = SnrProbe::new();
    let mut losses = Vec::with_capacity(steps);
    let mut initial = f32::NAN;
    let mut diverged = false;

    for t in 1..=steps {
        let batch = data.next_batch();
        let stats = engine.step(&batch, schedule.lr(t) as f32)?;
        if t == 1 {
            initial = stats.loss;
        }
        losses.push((t, stats.loss));
        if is_diverged(stats.loss, initial) {
            diverged = true;
            break;
        }
        if let Some(ps) = &probe_schedule {
            if ps.should_probe(t) {
                // Only exact (K=∅) second moments give the paper's Adam SNR;
                // compressed artifacts still record their reduced-V SNR.
                let vs = engine.second_moments()?;
                probe.record_tensors(t, &vs, &man.params);
            }
        }
    }

    // eval via extra fused steps at lr=0 would perturb state; instead use
    // the final training-loss tail as the comparable metric for fused runs.
    Ok(finalize(losses, f64::NAN, diverged, probe, t0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_warmup_is_linear() {
        let s = Schedule::new(1e-3, 10, 100);
        assert!((s.lr(1) - 1e-4).abs() < 1e-12);
        assert!((s.lr(5) - 5e-4).abs() < 1e-12);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn schedule_cosine_decays_to_min() {
        let s = Schedule::new(1e-3, 10, 100);
        assert!(s.lr(11) < 1e-3);
        assert!(s.lr(99) > 1e-4);
        assert!((s.lr(100) - 1e-4).abs() < 1e-12);
        assert!((s.lr(500) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn schedule_monotone_after_warmup() {
        let s = Schedule::new(3e-3, 20, 200);
        let mut prev = f64::INFINITY;
        for t in 21..=200 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-15, "t={t}");
            prev = lr;
        }
    }

    #[test]
    fn schedule_property_bounds() {
        crate::proptest::check(50, |g| {
            let base = g.log_f64(1e-5, 1e-1);
            let warmup = g.usize(0, 50);
            let total = warmup + g.usize(1, 200);
            let s = Schedule::new(base, warmup, total);
            for _ in 0..20 {
                let t = g.usize(1, total * 2);
                let lr = s.lr(t);
                crate::proptest::prop_assert(
                    lr > 0.0 && lr <= base * (1.0 + 1e-12),
                    format!("lr {lr} out of (0, {base}] at t={t}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fingerprint_tracks_metrics_not_timing() {
        let base = RunResult {
            losses: vec![(1, 2.0), (2, 1.5)],
            final_train_loss: 1.5,
            eval_loss: 1.6,
            diverged: false,
            probe: SnrProbe::new(),
            wallclock_s: 1.0,
        };
        let mut same = base.clone();
        same.wallclock_s = 99.0; // wall-clock must not affect identity
        assert_eq!(base.fingerprint(), same.fingerprint());
        let mut diff = base.clone();
        diff.losses[1].1 = 1.500_000_1;
        assert_ne!(base.fingerprint(), diff.fingerprint());
        let mut div = base.clone();
        div.diverged = true;
        assert_ne!(base.fingerprint(), div.fingerprint());
    }

    #[test]
    fn divergence_guard() {
        assert!(is_diverged(f32::NAN, 1.0));
        assert!(is_diverged(f32::INFINITY, 1.0));
        assert!(is_diverged(100.0, 1.0));
        assert!(!is_diverged(1.2, 1.0));
    }
}
