//! Parameter checkpointing via the in-repo npz substrate — the same format
//! the Python fixture generator (`np.savez`) uses, so checkpoints
//! interchange across the language boundary.

use std::path::Path;

use anyhow::{anyhow, ensure, Result};

use crate::npy::{read_npz, write_npz, NpyArray};
use crate::runtime::manifest::ParamInfo;
use crate::tensor::Tensor;

/// Save named parameters to `<path>` (npz).
pub fn save(path: impl AsRef<Path>, metas: &[ParamInfo], params: &[Tensor]) -> Result<()> {
    ensure!(metas.len() == params.len());
    let arrays: Vec<(&str, NpyArray)> = metas
        .iter()
        .zip(params)
        .map(|(m, t)| {
            (
                m.name.as_str(),
                NpyArray::F32 {
                    shape: t.shape.clone(),
                    data: t.data.clone(),
                },
            )
        })
        .collect();
    write_npz(path, &arrays)
}

/// Load parameters by name (order taken from `metas`).
pub fn load(path: impl AsRef<Path>, metas: &[ParamInfo]) -> Result<Vec<Tensor>> {
    let entries = read_npz(path.as_ref())?;
    let map: std::collections::HashMap<String, NpyArray> = entries.into_iter().collect();
    metas
        .iter()
        .map(|m| {
            let arr = map
                .get(&m.name)
                .ok_or_else(|| anyhow!("checkpoint missing tensor {:?}", m.name))?;
            let (shape, data) = arr.as_f32()?;
            ensure!(
                shape == m.shape.as_slice(),
                "checkpoint {:?} has shape {:?}, expected {:?}",
                m.name,
                shape,
                m.shape
            );
            Ok(Tensor::from_vec(shape, data.to_vec()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Init;

    fn meta(name: &str, shape: &[usize]) -> ParamInfo {
        ParamInfo {
            name: name.into(),
            shape: shape.to_vec(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Zeros,
            init_default: Init::Zeros,
            wd: true,
            fan_out_axis: 0,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("slimadam_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.npz");
        let metas = vec![meta("a", &[2, 3]), meta("b", &[4])];
        let params = vec![
            Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::from_vec(&[4], vec![9., 8., 7., 6.]),
        ];
        save(&path, &metas, &params).unwrap();
        let back = load(&path, &metas).unwrap();
        assert_eq!(back, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("slimadam_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.npz");
        let metas = vec![meta("a", &[2, 2])];
        save(&path, &metas, &[Tensor::zeros(&[2, 2])]).unwrap();
        let wrong = vec![meta("a", &[4])];
        assert!(load(&path, &wrong).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_rejected() {
        let dir = std::env::temp_dir().join("slimadam_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.npz");
        save(&path, &[meta("a", &[1])], &[Tensor::zeros(&[1])]).unwrap();
        let err = load(&path, &[meta("zz", &[1])]).unwrap_err();
        assert!(format!("{err}").contains("zz"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
