//! Compaction: merge a store's stream files into one deduplicated
//! `stream.jsonl`, dropping torn/bad rows.
//!
//! A long sweep campaign accretes files — the primary stream plus any
//! side streams a user pointed `--stream` at — and crashes leave torn
//! tails and resumed reruns leave duplicates. `compact` rewrites the
//! store to its minimal form: every surviving row byte-identical to the
//! original (lines are copied verbatim, never re-serialized, so
//! fingerprint audits of pre- and post-compact stores agree), first
//! occurrence wins on duplicate config keys, salvage mode for damage.
//!
//! Crash safety of the pass itself: the merged output is fully written
//! and fsynced to a temp file first, atomically renamed onto
//! `stream.jsonl`, and only then are the other source files unlinked. A
//! crash mid-compact therefore leaves duplicates (rerun `compact`),
//! never lost rows.

use std::collections::HashSet;
use std::fs;
use std::io::Write;

use anyhow::{Context, Result};

use super::reader::Tolerance;
use super::RunStore;
use crate::rng::stable_hash64;

/// What a compaction pass did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactReport {
    pub files_in: usize,
    pub rows_in: usize,
    pub rows_out: usize,
    pub dropped_duplicates: usize,
    pub dropped_bad: usize,
    pub torn: usize,
}

impl CompactReport {
    pub fn line(&self) -> String {
        format!(
            "compacted {} file(s): {} rows -> {} ({} duplicate, {} bad, {} torn dropped)",
            self.files_in,
            self.rows_in,
            self.rows_out,
            self.dropped_duplicates,
            self.dropped_bad,
            self.torn
        )
    }
}

/// Merge every stream file of `store` into `stream.jsonl`. See the
/// module docs for the crash-safety contract.
pub fn compact(store: &RunStore) -> Result<CompactReport> {
    let files = store.stream_files()?;
    let mut report = CompactReport { files_in: files.len(), ..Default::default() };
    if files.is_empty() {
        return Ok(report);
    }

    let tmp_path = store.dir().join("compact.jsonl.tmp");
    let mut tmp = fs::File::create(&tmp_path)
        .with_context(|| format!("creating {tmp_path:?}"))?;
    // Rows with run-store keys dedup by config key; legacy rows (no key)
    // dedup by whole-line hash so an accidental double-append still folds.
    let mut seen: HashSet<u64> = HashSet::new();

    for path in &files {
        // lossy read: salvage must survive a tail torn mid-character
        let text = super::reader::read_stream_file(path)?;
        let stats = super::reader::scan_jsonl(
            &text,
            Tolerance::SkipBad,
            &mut |_, row| {
                report.rows_in += 1;
                let key = row
                    .hex_u64("config_key")
                    .unwrap_or_else(|| stable_hash64(row.line.as_bytes()));
                if seen.insert(key) {
                    report.rows_out += 1;
                    tmp.write_all(row.line.as_bytes())?;
                    tmp.write_all(b"\n")?;
                } else {
                    report.dropped_duplicates += 1;
                }
                Ok(())
            },
        )?;
        report.dropped_bad += stats.skipped;
        report.torn += stats.torn;
    }

    tmp.sync_all()?;
    drop(tmp);
    let primary = store.primary();
    fs::rename(&tmp_path, &primary)
        .with_context(|| format!("renaming {tmp_path:?} -> {primary:?}"))?;
    for path in &files {
        if *path != primary {
            fs::remove_file(path)
                .with_context(|| format!("removing merged {path:?}"))?;
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(key: u64, fp: u64) -> String {
        format!(
            r#"{{"config_key":"{key:016x}","fingerprint":"{fp:016x}","seed":"01","job":0,"label":"l","model":"m","optimizer":"adam","lr":0.001,"final_train_loss":1.0,"eval_loss":1.1,"diverged":false,"steps":4}}"#
        )
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("slimadam_compact_{name}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn merges_dedups_and_drops_damage() {
        let dir = tmpdir("merge");
        fs::write(
            dir.join("stream.jsonl"),
            format!("{}\n{}\nnot json\n{}\n", row(1, 10), row(2, 20), row(1, 10)),
        )
        .unwrap();
        fs::write(
            dir.join("extra.jsonl"),
            format!("{}\n{}", row(3, 30), "{\"torn"),
        )
        .unwrap();
        let store = RunStore::open(&dir).unwrap();
        let r = compact(&store).unwrap();
        assert_eq!(r.files_in, 2);
        assert_eq!(r.rows_out, 3);
        assert_eq!(r.dropped_duplicates, 1);
        assert_eq!(r.dropped_bad, 1);
        assert_eq!(r.torn, 1);
        // one merged file remains, indexable, with 3 entries
        assert_eq!(store.stream_files().unwrap().len(), 1);
        let idx = store.index().unwrap();
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.stats.torn + idx.stats.skipped + idx.stats.conflicts, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_preserves_row_bytes() {
        let dir = tmpdir("bytes");
        let r1 = row(5, 50);
        fs::write(dir.join("stream.jsonl"), format!("{r1}\n")).unwrap();
        let store = RunStore::open(&dir).unwrap();
        compact(&store).unwrap();
        let text = fs::read_to_string(store.primary()).unwrap();
        assert_eq!(text, format!("{r1}\n"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_store_is_a_noop() {
        let dir = tmpdir("empty");
        let store = RunStore::open(&dir).unwrap();
        let r = compact(&store).unwrap();
        assert_eq!(r, CompactReport::default());
        let _ = fs::remove_dir_all(&dir);
    }
}
