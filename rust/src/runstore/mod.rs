//! Crash-safe run store: the append-only record of completed sweep jobs
//! (DESIGN.md §10).
//!
//! The paper's headline figures are large LR×width×vocab sweeps, and at
//! production scale the dominant failure cost is *wasted recompute*: a
//! killed sweep that restarts from job zero re-burns every finished grid
//! point. The run store closes that hole with three parts:
//!
//! * [`reader`] — a streaming, visitor-based JSONL reader over the
//!   shared JSON [`Lexer`](crate::json::Lexer): zero-copy events, no
//!   `Value` materialization on the scan path, tolerant of the torn
//!   final line a `SIGKILL` leaves behind.
//! * [`index`] — [`RunIndex`]: O(1) membership over every completed job,
//!   keyed by [`config_key`] (the stable hash of the full config
//!   identity, job seed included), deduplicated across stream files.
//! * [`compact`] — merges stream files into one, dropping duplicate and
//!   torn rows, preserving surviving rows byte-for-byte.
//!
//! [`RunStore`] ties them to a directory on disk. The scheduler's resume
//! path (`SweepScheduler::resume_from`) opens a store, repairs torn
//! tails, builds the index, and skips every config already present —
//! re-executing zero completed jobs while producing a result set whose
//! fingerprints are byte-identical to an uninterrupted run
//! (`rust/tests/runstore_resume.rs`).
//!
//! CLI surface: `slimadam sweep --resume <dir>` and
//! `slimadam runs ls|report|compact --dir <dir>` (EXPERIMENTS.md shows
//! the report format).

pub mod compact;
pub mod index;
pub mod reader;

pub use compact::{compact, CompactReport};
pub use index::{RunEntry, RunIndex};
pub use reader::{scan_jsonl, scan_value, Event, RowView, ScanStats, Tolerance, Visitor};

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{EngineKind, TrainConfig};
use crate::rng::stable_hash64;

/// Stable identity of a sweep job: everything that makes its result —
/// model, engine, optimizer, LR (bit-exact), schedule, seed, init, data
/// spec, hypers, rule set — hashed to the u64 the run index keys on.
///
/// Two configs share a key iff a completed row for one is a valid result
/// for the other. Warm-start tensors are reduced to a presence flag (the
/// tensors themselves are not hashable identity); fine-tune sweeps that
/// vary *only* the warm start should use distinct seeds.
pub fn config_key(cfg: &TrainConfig) -> u64 {
    let engine = match &cfg.engine {
        EngineKind::Split => format!("split:{}", cfg.optimizer),
        EngineKind::Fused(ruleset) => format!("fused:{ruleset}"),
    };
    let ruleset = cfg
        .ruleset
        .as_ref()
        .map(|r| format!("{}@{:x}", r.label, r.cutoff.to_bits()))
        .unwrap_or_default();
    let mut s = String::with_capacity(192);
    let _ = write!(
        s,
        "{}|{engine}|{:x}|{}|{}|{:x}|{}|{}|{}|{ruleset}|{}|{:?}|{:?}|{:?}",
        cfg.model,
        cfg.lr.to_bits(),
        cfg.steps,
        cfg.warmup,
        cfg.seed,
        cfg.init,
        cfg.accum,
        cfg.eval_batches,
        cfg.warm_start.is_some(),
        cfg.data,
        cfg.probe,
        cfg.hypers,
    );
    stable_hash64(s.as_bytes())
}

/// Per-file summary from [`RunStore::ls`].
#[derive(Debug, Clone)]
pub struct FileInfo {
    pub path: PathBuf,
    pub bytes: u64,
    pub rows: usize,
    pub legacy: usize,
    pub torn: usize,
    pub skipped: usize,
}

/// A directory of append-only JSONL stream files plus the operations the
/// resume path needs: tail repair, index builds, listing, reporting.
#[derive(Debug, Clone)]
pub struct RunStore {
    dir: PathBuf,
}

impl RunStore {
    /// Open (creating if absent) the store at `path`. A path to an
    /// existing `.jsonl` *file* opens its parent directory — so
    /// `--resume results/sweep` and `--resume results/sweep/stream.jsonl`
    /// mean the same store.
    pub fn open(path: impl AsRef<Path>) -> Result<RunStore> {
        let path = path.as_ref();
        let dir = if path.extension().is_some_and(|e| e == "jsonl") {
            path.parent()
                .filter(|p| !p.as_os_str().is_empty())
                .unwrap_or(Path::new("."))
                .to_path_buf()
        } else {
            path.to_path_buf()
        };
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating run store {dir:?}"))?;
        Ok(RunStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The file new rows append to (and compaction merges into).
    pub fn primary(&self) -> PathBuf {
        self.dir.join("stream.jsonl")
    }

    /// Every `*.jsonl` stream file, sorted by name so scan order — and
    /// therefore first-wins dedup — is deterministic.
    pub fn stream_files(&self) -> Result<Vec<PathBuf>> {
        let mut files = Vec::new();
        for entry in fs::read_dir(&self.dir)
            .with_context(|| format!("listing {:?}", self.dir))?
        {
            let path = entry?.path();
            if path.extension().is_some_and(|e| e == "jsonl") && path.is_file() {
                files.push(path);
            }
        }
        files.sort();
        Ok(files)
    }

    /// Build the run index over every stream file.
    pub fn index(&self) -> Result<RunIndex> {
        let mut idx = RunIndex::new();
        for path in self.stream_files()? {
            idx.scan_file(&path)
                .with_context(|| format!("indexing {path:?}"))?;
        }
        Ok(idx)
    }

    /// Repair crash damage before appending: a file whose final line has
    /// no terminating newline would otherwise splice the next appended
    /// row onto the torn fragment, corrupting a *valid* row mid-file. If
    /// the unterminated tail parses as a complete row the newline is
    /// added (data kept); otherwise the tail is truncated away. Returns
    /// how many files were repaired.
    pub fn repair_tails(&self) -> Result<usize> {
        let mut repaired = 0;
        for path in self.stream_files()? {
            let bytes = fs::read(&path)?;
            if bytes.is_empty() || bytes.last() == Some(&b'\n') {
                continue;
            }
            let tail_start = bytes
                .iter()
                .rposition(|&b| b == b'\n')
                .map(|p| p + 1)
                .unwrap_or(0);
            let tail_ok = std::str::from_utf8(&bytes[tail_start..])
                .is_ok_and(|t| reader::parse_row(t).is_ok());
            if tail_ok {
                let mut f = fs::OpenOptions::new().append(true).open(&path)?;
                use std::io::Write;
                f.write_all(b"\n")?;
            } else {
                let f = fs::OpenOptions::new().write(true).open(&path)?;
                f.set_len(tail_start as u64)?;
            }
            repaired += 1;
        }
        Ok(repaired)
    }

    /// Per-file stats for `slimadam runs ls`, plus the combined index
    /// (dedup/conflict totals) from the same single pass over each file.
    pub fn ls(&self) -> Result<(Vec<FileInfo>, RunIndex)> {
        let mut idx = RunIndex::new();
        let mut out = Vec::new();
        for path in self.stream_files()? {
            let bytes = fs::metadata(&path)?.len();
            let legacy_before = idx.stats.legacy;
            let stats = idx.scan_file(&path)?;
            out.push(FileInfo {
                path,
                bytes,
                rows: stats.rows,
                legacy: idx.stats.legacy - legacy_before,
                torn: stats.torn,
                skipped: stats.skipped,
            });
        }
        Ok((out, idx))
    }

    /// Aggregate report over the store, grouped by `(model, optimizer)`:
    /// run counts, LR range, best loss, divergence counts. This is the
    /// measured half of EXPERIMENTS.md §Sweep-campaigns.
    pub fn report(&self) -> Result<String> {
        let idx = self.index()?;
        let mut groups: std::collections::BTreeMap<(String, String), Vec<&RunEntry>> =
            std::collections::BTreeMap::new();
        for e in idx.entries() {
            groups
                .entry((e.model.clone(), e.optimizer.clone()))
                .or_default()
                .push(e);
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "run store {:?}: {} completed jobs across {} file(s)",
            self.dir, idx.len(), idx.stats.files
        );
        if idx.stats.legacy + idx.stats.torn + idx.stats.skipped + idx.stats.conflicts > 0 {
            let _ = writeln!(
                out,
                "  ({} legacy rows, {} torn, {} bad, {} conflicts)",
                idx.stats.legacy, idx.stats.torn, idx.stats.skipped, idx.stats.conflicts
            );
        }
        let _ = writeln!(out);
        let _ = writeln!(
            out,
            "{:<14} {:<16} {:>5} {:>10} {:>10} {:>10} {:>9} {:>5}",
            "model", "optimizer", "runs", "lr_min", "lr_max", "best_loss", "@lr", "div"
        );
        for ((model, optimizer), entries) in &groups {
            let lr_min = entries.iter().map(|e| e.lr).fold(f64::INFINITY, f64::min);
            let lr_max = entries.iter().map(|e| e.lr).fold(0.0f64, f64::max);
            let best = entries
                .iter()
                .filter(|e| !e.diverged)
                .map(|e| {
                    // -1.0 is the writer's non-finite sentinel, not a loss
                    let loss = if e.eval_loss != -1.0 { e.eval_loss } else { e.final_train_loss };
                    (loss, e.lr)
                })
                .min_by(|a, b| a.0.total_cmp(&b.0));
            let diverged = entries.iter().filter(|e| e.diverged).count();
            let (best_loss, best_lr) = match best {
                Some((l, lr)) => (format!("{l:.4}"), format!("{lr:.1e}")),
                None => ("-".into(), "-".into()),
            };
            let _ = writeln!(
                out,
                "{:<14} {:<16} {:>5} {:>10.1e} {:>10.1e} {:>10} {:>9} {:>5}",
                model, optimizer, entries.len(), lr_min, lr_max, best_loss, best_lr, diverged
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("slimadam_runstore_{name}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn config_key_is_stable_and_sensitive() {
        let base = TrainConfig::lm("gpt_nano", "adam", 1e-3, 100);
        assert_eq!(config_key(&base), config_key(&base.clone()));
        let mut lr = base.clone();
        lr.lr = 1.0000000001e-3; // bit-exact LR identity
        assert_ne!(config_key(&base), config_key(&lr));
        let mut seed = base.clone();
        seed.seed = 1;
        assert_ne!(config_key(&base), config_key(&seed));
        let mut opt = base.clone();
        opt.optimizer = "slimadam".into();
        assert_ne!(config_key(&base), config_key(&opt));
        let mut fused = base.clone();
        fused.engine = EngineKind::Fused("slimadam".into());
        assert_ne!(config_key(&base), config_key(&fused));
    }

    #[test]
    fn open_accepts_file_or_dir() {
        let dir = tmpdir("open");
        let a = RunStore::open(&dir).unwrap();
        let b = RunStore::open(dir.join("stream.jsonl")).unwrap();
        assert_eq!(a.dir(), b.dir());
        assert_eq!(a.primary(), dir.join("stream.jsonl"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_truncates_garbage_tail() {
        let dir = tmpdir("repair_trunc");
        let path = dir.join("stream.jsonl");
        fs::write(&path, "{\"a\":1}\n{\"b\":2,\"tor").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.repair_tails().unwrap(), 1);
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"a\":1}\n");
        // idempotent
        assert_eq!(store.repair_tails().unwrap(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_keeps_complete_unterminated_row() {
        let dir = tmpdir("repair_keep");
        let path = dir.join("stream.jsonl");
        fs::write(&path, "{\"a\":1}\n{\"b\":2}").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.repair_tails().unwrap(), 1);
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"a\":1}\n{\"b\":2}\n");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_renders_groups() {
        let dir = tmpdir("report");
        let row = |key: u64, opt: &str, lr: f64, loss: f64| {
            format!(
                r#"{{"config_key":"{key:016x}","fingerprint":"{key:016x}","seed":"01","job":0,"label":"l","model":"gpt_nano","optimizer":"{opt}","lr":{lr},"final_train_loss":{loss},"eval_loss":{loss},"diverged":false,"steps":4}}"#
            )
        };
        fs::write(
            dir.join("stream.jsonl"),
            format!(
                "{}\n{}\n{}\n",
                row(1, "adam", 1e-3, 2.0),
                row(2, "adam", 3e-3, 1.5),
                row(3, "slimadam", 1e-3, 1.8)
            ),
        )
        .unwrap();
        let store = RunStore::open(&dir).unwrap();
        let rep = store.report().unwrap();
        assert!(rep.contains("3 completed jobs"));
        assert!(rep.contains("adam"));
        assert!(rep.contains("slimadam"));
        assert!(rep.contains("1.5000"), "best adam loss missing:\n{rep}");
        let _ = fs::remove_dir_all(&dir);
    }
}
