//! Crash-safe run store: the append-only record of completed sweep jobs
//! (DESIGN.md §10).
//!
//! The paper's headline figures are large LR×width×vocab sweeps, and at
//! production scale the dominant failure cost is *wasted recompute*: a
//! killed sweep that restarts from job zero re-burns every finished grid
//! point. The run store closes that hole with three parts:
//!
//! * [`reader`] — a streaming, visitor-based JSONL reader over the
//!   shared JSON [`Lexer`](crate::json::Lexer): zero-copy events, no
//!   `Value` materialization on the scan path, tolerant of the torn
//!   final line a `SIGKILL` leaves behind.
//! * [`index`] — [`RunIndex`]: O(1) membership over every completed job,
//!   keyed by [`config_key`] (the stable hash of the full config
//!   identity, job seed included), deduplicated across stream files.
//! * [`compact`] — merges stream files into one, dropping duplicate and
//!   torn rows, preserving surviving rows byte-for-byte.
//! * `store.json` ([`StoreMeta`]) — per-store manifest (schema version,
//!   base seed, creating backend) written on create and validated on
//!   every open; a schema-version mismatch fails loudly instead of
//!   misreading rows written under a different contract.
//!
//! [`RunStore`] ties them to a directory on disk. The scheduler's resume
//! path (`SweepScheduler::resume_from`) opens a store, repairs torn
//! tails, builds the index, and skips every config already present —
//! re-executing zero completed jobs while producing a result set whose
//! fingerprints are byte-identical to an uninterrupted run
//! (`rust/tests/runstore_resume.rs`).
//!
//! CLI surface: `slimadam sweep --resume <dir>` and
//! `slimadam runs ls|report|compact --dir <dir>` (EXPERIMENTS.md shows
//! the report format).

pub mod compact;
pub mod index;
pub mod reader;

pub use compact::{compact, CompactReport};
pub use index::{RunEntry, RunIndex};
pub use reader::{scan_jsonl, scan_value, Event, RowView, ScanStats, Tolerance, Visitor};

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::{EngineKind, TrainConfig};
use crate::rng::stable_hash64;

/// Stable identity of a sweep job: everything that makes its result —
/// model, backend+device, engine, optimizer, LR (bit-exact), schedule,
/// seed, init, data spec, hypers, rule set — hashed to the u64 the run
/// index keys on.
///
/// Two configs share a key iff a completed row for one is a valid result
/// for the other. The backend spec is part of the identity because the
/// native interpreter and the PJRT artifacts are different computations:
/// resume must never serve one backend's row for the other's config.
/// Warm-start tensors are reduced to a presence flag (the tensors
/// themselves are not hashable identity); fine-tune sweeps that vary
/// *only* the warm start should use distinct seeds.
pub fn config_key(cfg: &TrainConfig) -> u64 {
    let engine = match &cfg.engine {
        EngineKind::Split => format!("split:{}", cfg.optimizer),
        EngineKind::Fused(ruleset) => format!("fused:{ruleset}"),
    };
    let ruleset = cfg
        .ruleset
        .as_ref()
        .map(|r| format!("{}@{:x}", r.label, r.cutoff.to_bits()))
        .unwrap_or_default();
    let mut s = String::with_capacity(192);
    let _ = write!(
        s,
        "{}|{}|{engine}|{:x}|{}|{}|{:x}|{}|{}|{}|{ruleset}|{}|{:?}|{:?}|{:?}",
        cfg.model,
        cfg.backend.key(),
        cfg.lr.to_bits(),
        cfg.steps,
        cfg.warmup,
        cfg.seed,
        cfg.init,
        cfg.accum,
        cfg.eval_batches,
        cfg.warm_start.is_some(),
        cfg.data,
        cfg.probe,
        cfg.hypers,
    );
    // Bake-off optimizers carry identity beyond their preset name and
    // `cfg.hypers`: Lion's betas, SM3's beta/momentum, Adafactor's
    // variant and lowrank_v's rank are hardcoded behind the token. Fold
    // the canonical spec in so e.g. `sm3` and `sm3_b0` rows can never
    // alias. The segment is appended only when a spec exists, so
    // adam/slimadam/adalayer keys keep their historical bytes.
    let token = match &cfg.engine {
        EngineKind::Split => cfg.optimizer.as_str(),
        EngineKind::Fused(ruleset) => ruleset.as_str(),
    };
    if let Some(spec) = crate::optim::presets::spec_key(token) {
        let _ = write!(s, "|opt:{spec}");
    }
    // Adaptive rule switching changes the computation (DESIGN.md §18):
    // the policy's bit-exact key joins the identity, appended only when
    // set so non-adaptive keys keep their historical bytes. Telemetry
    // cadence and tracing stay OUT of the key — observation never forks
    // a run's identity, only decisions do.
    if let Some(policy) = &cfg.adaptive {
        let _ = write!(s, "|adaptive:{}", policy.key());
    }
    stable_hash64(s.as_bytes())
}

// ---------------------------------------------------------------------------
// Store manifest (store.json)
// ---------------------------------------------------------------------------

/// Current run-store schema version. Version 1 is the backend-aware
/// config-key format (the backend spec is part of [`config_key`]).
/// Bumped when the stream-row or store-layout contract changes
/// incompatibly; `RunStore::open` refuses stores from a different
/// version instead of misreading them. Stores created before the
/// manifest existed recorded no version and cannot be gated — adopting
/// one with rows prints a warning, because its rows were keyed without
/// the backend segment and will never match current configs.
pub const SCHEMA_VERSION: u64 = 1;

/// Per-store metadata, persisted as `store.json` next to the stream
/// files when the store is first created and validated on every open.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreMeta {
    pub schema_version: u64,
    /// Base seed of the sweep that created the store (0 when unknown).
    pub base_seed: u64,
    /// Backend spec the creating sweep ran on (`"unknown"` for stores
    /// created outside a sweep). Informational: config keys already pin
    /// each row's backend, so mixed-backend stores remain valid.
    pub backend: String,
}

impl Default for StoreMeta {
    fn default() -> Self {
        StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 0,
            backend: "unknown".into(),
        }
    }
}

impl StoreMeta {
    fn to_json(&self) -> crate::json::Value {
        let mut v = crate::json::Value::obj();
        v.set("schema_version", self.schema_version)
            .set("base_seed", format!("{:016x}", self.base_seed))
            .set("backend", self.backend.clone());
        v
    }

    fn parse(text: &str) -> Result<StoreMeta> {
        let v = crate::json::Value::parse(text).context("parsing store.json")?;
        Ok(StoreMeta {
            schema_version: v.get("schema_version")?.as_usize()? as u64,
            base_seed: u64::from_str_radix(v.get("base_seed")?.as_str()?, 16)
                .context("store.json base_seed")?,
            backend: v.get("backend")?.as_str()?.to_string(),
        })
    }
}

/// Per-file summary from [`RunStore::ls`].
#[derive(Debug, Clone)]
pub struct FileInfo {
    pub path: PathBuf,
    pub bytes: u64,
    pub rows: usize,
    pub legacy: usize,
    pub torn: usize,
    pub skipped: usize,
}

/// A directory of append-only JSONL stream files plus the operations the
/// resume path needs: tail repair, index builds, listing, reporting.
#[derive(Debug, Clone)]
pub struct RunStore {
    dir: PathBuf,
}

impl RunStore {
    /// Open the store at `path` for reading/inspection. A path to an
    /// existing `.jsonl` *file* opens its parent directory — so
    /// `--resume results/sweep` and `--resume results/sweep/stream.jsonl`
    /// mean the same store.
    ///
    /// An existing `store.json` manifest is validated — a schema-version
    /// mismatch fails loudly, never misreading rows written under a
    /// different contract. This path **never writes**: `runs
    /// ls/report/compact` work on read-only directories and cannot stamp
    /// placeholder provenance. Write paths (sweeps) use
    /// [`RunStore::open_with`], which creates the manifest.
    pub fn open(path: impl AsRef<Path>) -> Result<RunStore> {
        let store = Self::locate(path)?;
        store.validate_manifest()?;
        Ok(store)
    }

    /// Open for writing: like [`RunStore::open`], but when no manifest
    /// exists one is created from `meta` (sweeps pass their base seed and
    /// backend spec so the store records real provenance). An existing
    /// manifest is validated, never rewritten.
    pub fn open_with(path: impl AsRef<Path>, meta: &StoreMeta) -> Result<RunStore> {
        let store = Self::locate(path)?;
        store.validate_manifest()?;
        let manifest = store.manifest_path();
        if !manifest.exists() {
            let mut meta = meta.clone();
            meta.schema_version = SCHEMA_VERSION;
            // Crash-safe write: full temp file + atomic rename, so a kill
            // mid-create can never leave a torn manifest that bricks the
            // store (same discipline as `compact`).
            let tmp = store.dir.join("store.json.tmp");
            fs::write(&tmp, meta.to_json().dump_pretty())
                .with_context(|| format!("writing {tmp:?}"))?;
            fs::rename(&tmp, &manifest)
                .with_context(|| format!("installing {manifest:?}"))?;
        }
        Ok(store)
    }

    fn locate(path: impl AsRef<Path>) -> Result<RunStore> {
        let path = path.as_ref();
        let dir = if path.extension().is_some_and(|e| e == "jsonl") {
            path.parent()
                .filter(|p| !p.as_os_str().is_empty())
                .unwrap_or(Path::new("."))
                .to_path_buf()
        } else {
            path.to_path_buf()
        };
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating run store {dir:?}"))?;
        Ok(RunStore { dir })
    }

    fn validate_manifest(&self) -> Result<()> {
        let manifest = self.manifest_path();
        if !manifest.exists() {
            return Ok(()); // pre-manifest store: readable, ungated
        }
        let text = fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}"))?;
        let found = StoreMeta::parse(&text)
            .with_context(|| format!("invalid store manifest {manifest:?}"))?;
        if found.schema_version != SCHEMA_VERSION {
            bail!(
                "run store {:?} has schema version {} but this build reads \
                 version {SCHEMA_VERSION} — refusing to open (migrate or \
                 point --resume at a fresh directory)",
                self.dir,
                found.schema_version
            );
        }
        Ok(())
    }

    /// Path of the store's metadata manifest.
    pub fn manifest_path(&self) -> PathBuf {
        self.dir.join("store.json")
    }

    /// The store's persisted metadata.
    pub fn meta(&self) -> Result<StoreMeta> {
        let text = fs::read_to_string(self.manifest_path())?;
        StoreMeta::parse(&text)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The file new rows append to (and compaction merges into).
    pub fn primary(&self) -> PathBuf {
        self.dir.join("stream.jsonl")
    }

    /// Every `*.jsonl` stream file, sorted by name so scan order — and
    /// therefore first-wins dedup — is deterministic.
    pub fn stream_files(&self) -> Result<Vec<PathBuf>> {
        let mut files = Vec::new();
        for entry in fs::read_dir(&self.dir)
            .with_context(|| format!("listing {:?}", self.dir))?
        {
            let path = entry?.path();
            if path.extension().is_some_and(|e| e == "jsonl") && path.is_file() {
                files.push(path);
            }
        }
        files.sort();
        Ok(files)
    }

    /// Build the run index over every stream file.
    pub fn index(&self) -> Result<RunIndex> {
        let mut idx = RunIndex::new();
        for path in self.stream_files()? {
            idx.scan_file(&path)
                .with_context(|| format!("indexing {path:?}"))?;
        }
        Ok(idx)
    }

    /// Repair crash damage before appending: a file whose final line has
    /// no terminating newline would otherwise splice the next appended
    /// row onto the torn fragment, corrupting a *valid* row mid-file. If
    /// the unterminated tail parses as a complete row the newline is
    /// added (data kept); otherwise the tail is truncated away. Returns
    /// how many files were repaired.
    pub fn repair_tails(&self) -> Result<usize> {
        let mut repaired = 0;
        for path in self.stream_files()? {
            let bytes = fs::read(&path)?;
            if bytes.is_empty() || bytes.last() == Some(&b'\n') {
                continue;
            }
            let tail_start = bytes
                .iter()
                .rposition(|&b| b == b'\n')
                .map(|p| p + 1)
                .unwrap_or(0);
            let tail_ok = std::str::from_utf8(&bytes[tail_start..])
                .is_ok_and(|t| reader::parse_row(t).is_ok());
            if tail_ok {
                let mut f = fs::OpenOptions::new().append(true).open(&path)?;
                use std::io::Write;
                f.write_all(b"\n")?;
            } else {
                let f = fs::OpenOptions::new().write(true).open(&path)?;
                f.set_len(tail_start as u64)?;
            }
            repaired += 1;
        }
        if repaired > 0 {
            crate::obs::registry::counter("runstore.tails_repaired").add(repaired as u64);
        }
        Ok(repaired)
    }

    /// Per-file stats for `slimadam runs ls`, plus the combined index
    /// (dedup/conflict totals) from the same single pass over each file.
    pub fn ls(&self) -> Result<(Vec<FileInfo>, RunIndex)> {
        let mut idx = RunIndex::new();
        let mut out = Vec::new();
        for path in self.stream_files()? {
            let bytes = fs::metadata(&path)?.len();
            let legacy_before = idx.stats.legacy;
            let stats = idx.scan_file(&path)?;
            out.push(FileInfo {
                path,
                bytes,
                rows: stats.rows,
                legacy: idx.stats.legacy - legacy_before,
                torn: stats.torn,
                skipped: stats.skipped,
            });
        }
        Ok((out, idx))
    }

    /// Aggregate report over the store, grouped by `(model, optimizer)`:
    /// run counts, LR range, best loss, divergence counts. This is the
    /// measured half of EXPERIMENTS.md §Sweep-campaigns.
    pub fn report(&self) -> Result<String> {
        let idx = self.index()?;
        let mut groups: std::collections::BTreeMap<(String, String), Vec<&RunEntry>> =
            std::collections::BTreeMap::new();
        for e in idx.entries() {
            groups
                .entry((e.model.clone(), e.optimizer.clone()))
                .or_default()
                .push(e);
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "run store {:?}: {} completed jobs across {} file(s)",
            self.dir, idx.len(), idx.stats.files
        );
        if idx.stats.legacy + idx.stats.torn + idx.stats.skipped + idx.stats.conflicts > 0 {
            let _ = writeln!(
                out,
                "  ({} legacy rows, {} torn, {} bad, {} conflicts)",
                idx.stats.legacy, idx.stats.torn, idx.stats.skipped, idx.stats.conflicts
            );
        }
        let _ = writeln!(out);
        let _ = writeln!(
            out,
            "{:<14} {:<16} {:>5} {:>10} {:>10} {:>10} {:>9} {:>5}",
            "model", "optimizer", "runs", "lr_min", "lr_max", "best_loss", "@lr", "div"
        );
        for ((model, optimizer), entries) in &groups {
            let lr_min = entries.iter().map(|e| e.lr).fold(f64::INFINITY, f64::min);
            let lr_max = entries.iter().map(|e| e.lr).fold(0.0f64, f64::max);
            let best = entries
                .iter()
                .filter(|e| !e.diverged)
                .map(|e| {
                    // -1.0 is the writer's non-finite sentinel, not a loss
                    let loss = if e.eval_loss != -1.0 { e.eval_loss } else { e.final_train_loss };
                    (loss, e.lr)
                })
                .min_by(|a, b| a.0.total_cmp(&b.0));
            let diverged = entries.iter().filter(|e| e.diverged).count();
            let (best_loss, best_lr) = match best {
                Some((l, lr)) => (format!("{l:.4}"), format!("{lr:.1e}")),
                None => ("-".into(), "-".into()),
            };
            let _ = writeln!(
                out,
                "{:<14} {:<16} {:>5} {:>10.1e} {:>10.1e} {:>10} {:>9} {:>5}",
                model, optimizer, entries.len(), lr_min, lr_max, best_loss, best_lr, diverged
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("slimadam_runstore_{name}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn config_key_is_stable_and_sensitive() {
        let base = TrainConfig::lm("gpt_nano", "adam", 1e-3, 100);
        assert_eq!(config_key(&base), config_key(&base.clone()));
        let mut lr = base.clone();
        lr.lr = 1.0000000001e-3; // bit-exact LR identity
        assert_ne!(config_key(&base), config_key(&lr));
        let mut seed = base.clone();
        seed.seed = 1;
        assert_ne!(config_key(&base), config_key(&seed));
        let mut opt = base.clone();
        opt.optimizer = "slimadam".into();
        assert_ne!(config_key(&base), config_key(&opt));
        let mut fused = base.clone();
        fused.engine = EngineKind::Fused("slimadam".into());
        assert_ne!(config_key(&base), config_key(&fused));
    }

    /// Bake-off optimizer identity: the canonical spec segment pins each
    /// token's *behavior* (hardcoded betas, variant, rank), not just its
    /// name, so a future change to a hardcoded hyper changes the key and
    /// stale rows can never be served for the new behavior. The AdamW
    /// family gets no segment and keeps its historical key bytes.
    #[test]
    fn config_key_folds_optimizer_spec_in() {
        use crate::optim::presets::spec_key;
        let mk = |opt: &str| TrainConfig::lm("gpt_nano", opt, 1e-3, 100);
        // same hypers struct, different hardcoded behavior
        assert_ne!(config_key(&mk("sm3")), config_key(&mk("sm3_b0")));
        assert_ne!(config_key(&mk("adafactor")), config_key(&mk("adafactor_v2")));
        assert_ne!(config_key(&mk("lowrank_v")), config_key(&mk("lowrank_v8")));
        // the default-rank alias and its explicit spelling are the same
        // algorithm: their spec segments agree (the engine segment still
        // carries the spelled token)
        assert_eq!(spec_key("lowrank_v"), spec_key("lowrank_v4"));
        // the AdamW family carries no spec segment: keys stay bytewise
        // what they were before the segment existed
        for tok in ["adam", "slimadam", "adalayer"] {
            assert_eq!(spec_key(tok), None, "{tok} must not grow a spec segment");
        }
        // fused bake-off tokens key separately per rank too
        let mut fa = mk("adam");
        fa.engine = EngineKind::Fused("lowrank_v".into());
        let mut fb = mk("adam");
        fb.engine = EngineKind::Fused("lowrank_v8".into());
        assert_ne!(config_key(&fa), config_key(&fb));
    }

    /// Adaptive identity (DESIGN.md §18): the policy is part of the key —
    /// adaptive rows can never be served for static configs or for a
    /// different policy — but a `None` policy keeps the historical bytes.
    #[test]
    fn config_key_folds_adaptive_policy_in() {
        use crate::rules::adaptive::AdaptivePolicy;
        let mut base = TrainConfig::lm("gpt_nano", "adam", 1e-3, 100);
        base.engine = EngineKind::Fused("slimadam".into());
        let mut adaptive = base.clone();
        adaptive.adaptive = Some(AdaptivePolicy::default());
        assert_ne!(config_key(&base), config_key(&adaptive));
        // every policy field is identity: thresholds bit-exactly, and
        // patience/cadence because they change which evals can fire
        let mut other = adaptive.clone();
        other.adaptive.as_mut().unwrap().enter += 1e-12;
        assert_ne!(config_key(&adaptive), config_key(&other));
        let mut cadence = adaptive.clone();
        cadence.adaptive.as_mut().unwrap().every = 7;
        assert_ne!(config_key(&adaptive), config_key(&cadence));
        // same policy spelled twice → same key
        let again = adaptive.clone();
        assert_eq!(config_key(&adaptive), config_key(&again));
    }

    #[test]
    fn open_accepts_file_or_dir() {
        let dir = tmpdir("open");
        let a = RunStore::open(&dir).unwrap();
        let b = RunStore::open(dir.join("stream.jsonl")).unwrap();
        assert_eq!(a.dir(), b.dir());
        assert_eq!(a.primary(), dir.join("stream.jsonl"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_key_separates_backends() {
        use crate::runtime::backend::BackendSpec;
        let base = TrainConfig::lm("mlp_tiny", "adam", 1e-3, 50);
        let mut native = base.clone();
        native.backend = BackendSpec::native();
        assert_ne!(config_key(&base), config_key(&native));
        let mut gpu = base.clone();
        gpu.backend = BackendSpec::parse("pjrt@gpu:1").unwrap();
        assert_ne!(config_key(&base), config_key(&gpu));
    }

    #[test]
    fn store_manifest_written_on_create_and_validated() {
        let dir = tmpdir("manifest");
        let meta = StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 0x2a,
            backend: "native@cpu:0".into(),
        };
        let store = RunStore::open_with(&dir, &meta).unwrap();
        assert!(store.manifest_path().exists());
        let back = store.meta().unwrap();
        assert_eq!(back, meta);
        // reopening validates but does not rewrite
        let again = RunStore::open(&dir).unwrap();
        assert_eq!(again.meta().unwrap().backend, "native@cpu:0");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_version_mismatch_fails_loudly() {
        let dir = tmpdir("schema_mismatch");
        fs::write(
            dir.join("store.json"),
            r#"{"schema_version": 999, "base_seed": "0", "backend": "unknown"}"#,
        )
        .unwrap();
        let err = RunStore::open(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("schema version 999"), "{msg}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_store_manifest_fails_loudly() {
        let dir = tmpdir("manifest_corrupt");
        fs::write(dir.join("store.json"), "not json").unwrap();
        let err = RunStore::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("store.json"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_only_open_never_writes_a_manifest() {
        let dir = tmpdir("legacy_read");
        fs::write(dir.join("stream.jsonl"), "{\"a\":1}\n").unwrap();
        // inspection path: no store.json appears
        let store = RunStore::open(&dir).unwrap();
        assert!(!store.manifest_path().exists());
        // write path: manifest created with the caller's provenance
        let meta = StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 7,
            backend: "pjrt@cpu:0".into(),
        };
        let store = RunStore::open_with(&dir, &meta).unwrap();
        assert_eq!(store.meta().unwrap(), meta);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_truncates_garbage_tail() {
        let dir = tmpdir("repair_trunc");
        let path = dir.join("stream.jsonl");
        fs::write(&path, "{\"a\":1}\n{\"b\":2,\"tor").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.repair_tails().unwrap(), 1);
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"a\":1}\n");
        // idempotent
        assert_eq!(store.repair_tails().unwrap(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn repair_keeps_complete_unterminated_row() {
        let dir = tmpdir("repair_keep");
        let path = dir.join("stream.jsonl");
        fs::write(&path, "{\"a\":1}\n{\"b\":2}").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.repair_tails().unwrap(), 1);
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"a\":1}\n{\"b\":2}\n");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_renders_groups() {
        let dir = tmpdir("report");
        let row = |key: u64, opt: &str, lr: f64, loss: f64| {
            format!(
                r#"{{"config_key":"{key:016x}","fingerprint":"{key:016x}","seed":"01","job":0,"label":"l","model":"gpt_nano","optimizer":"{opt}","lr":{lr},"final_train_loss":{loss},"eval_loss":{loss},"diverged":false,"steps":4}}"#
            )
        };
        fs::write(
            dir.join("stream.jsonl"),
            format!(
                "{}\n{}\n{}\n",
                row(1, "adam", 1e-3, 2.0),
                row(2, "adam", 3e-3, 1.5),
                row(3, "slimadam", 1e-3, 1.8)
            ),
        )
        .unwrap();
        let store = RunStore::open(&dir).unwrap();
        let rep = store.report().unwrap();
        assert!(rep.contains("3 completed jobs"));
        assert!(rep.contains("adam"));
        assert!(rep.contains("slimadam"));
        assert!(rep.contains("1.5000"), "best adam loss missing:\n{rep}");
        let _ = fs::remove_dir_all(&dir);
    }
}
