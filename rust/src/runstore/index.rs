//! Run index: O(1) membership over every completed sweep job in a store.
//!
//! Built by streaming every `*.jsonl` row through `runstore::reader`
//! (never a DOM parse) and keying on the row's **config key** — the
//! stable hash of the full [`TrainConfig`] identity, job seed included
//! (`runstore::config_key`). The scheduler consults the index before
//! dispatch: a config whose key is present has already been computed,
//! and its stored metrics stand in for re-execution
//! ([`RunEntry::to_summary`]).
//!
//! Duplicate keys across stream files are deduplicated (first occurrence
//! wins — scan order is deterministic: files sorted by name, rows in
//! file order); a duplicate whose fingerprint *disagrees* is counted as
//! a conflict so `slimadam runs ls` can surface it.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::{RunSummary, TrainConfig};
use crate::snr::SnrProbe;
use crate::train::RunResult;

use super::reader::{RowView, ScanStats, Tolerance};

/// One indexed row: the scalar metrics a streamed sweep row carries.
#[derive(Debug, Clone, PartialEq)]
pub struct RunEntry {
    pub config_key: u64,
    pub fingerprint: u64,
    pub seed: u64,
    pub job: usize,
    pub label: String,
    pub model: String,
    pub optimizer: String,
    pub lr: f64,
    pub final_train_loss: f64,
    pub eval_loss: f64,
    pub diverged: bool,
    pub steps: usize,
}

impl RunEntry {
    /// Extract an entry from a row. `None` when the row predates the run
    /// store (PR 1 streams carry no `config_key`/`seed`) or is missing a
    /// required field — such rows are counted, not indexed.
    pub fn from_row(row: &RowView<'_>) -> Option<RunEntry> {
        Some(RunEntry {
            config_key: row.hex_u64("config_key")?,
            fingerprint: row.hex_u64("fingerprint")?,
            seed: row.hex_u64("seed")?,
            job: row.usize("job")?,
            label: row.str("label")?.to_string(),
            model: row.str("model")?.to_string(),
            optimizer: row.str("optimizer")?.to_string(),
            lr: row.f64("lr")?,
            final_train_loss: row.f64("final_train_loss")?,
            eval_loss: row.f64("eval_loss")?,
            diverged: row.bool("diverged")?,
            steps: row.usize("steps")?,
        })
    }

    /// Reconstitute a [`RunSummary`] for a job the scheduler skipped.
    /// Per-step losses and probe data are not streamed, so the result
    /// carries the *stored* fingerprint (`RunSummary::fingerprint`
    /// prefers it over recomputing from the empty loss vector); the
    /// scalar metrics are restored bit-exactly from the row. The exact
    /// `-1.0` sentinel (the writer's stand-in for a non-finite loss —
    /// a run that diverged or never evaluated) maps back to NaN so
    /// `LrSweep::metric` behaves as it would have live; other negative
    /// values pass through untouched.
    pub fn to_summary(&self) -> RunSummary {
        let unsentinel = |x: f64| if x == -1.0 { f64::NAN } else { x };
        RunSummary {
            label: self.label.clone(),
            model: self.model.clone(),
            optimizer: self.optimizer.clone(),
            lr: self.lr,
            result: RunResult {
                losses: Vec::new(),
                final_train_loss: unsentinel(self.final_train_loss),
                eval_loss: unsentinel(self.eval_loss),
                diverged: self.diverged,
                probe: SnrProbe::new(),
                wallclock_s: 0.0,
            },
            snr: None,
            memory: None,
            steps_per_s: 0.0,
            stored_fingerprint: Some(self.fingerprint),
            metrics: None,
            adaptive: None,
        }
    }
}

/// Aggregate counts from building an index (surfaced by `runs ls`).
#[derive(Debug, Clone, Copy, Default)]
pub struct IndexStats {
    pub files: usize,
    /// Well-formed rows scanned (indexed or not).
    pub rows: usize,
    /// Torn trailing lines recovered.
    pub torn: usize,
    /// Mid-file bad rows skipped.
    pub skipped: usize,
    /// Rows without run-store keys (pre-runstore streams).
    pub legacy: usize,
    /// Rows whose config key was already indexed (identical fingerprint).
    pub duplicates: usize,
    /// Duplicate config keys with *different* fingerprints.
    pub conflicts: usize,
}

/// O(1)-membership index of completed jobs, keyed by config key.
#[derive(Debug, Default)]
pub struct RunIndex {
    entries: HashMap<u64, RunEntry>,
    pub stats: IndexStats,
}

impl RunIndex {
    pub fn new() -> RunIndex {
        RunIndex::default()
    }

    /// Index every row of one stream file's text. Lenient by default:
    /// torn tails are recovered and mid-file bad rows skipped, because an
    /// index rebuild must succeed on a crashed store.
    pub fn scan_text(&mut self, text: &str) -> Result<ScanStats> {
        let stats = super::reader::scan_jsonl(
            text,
            Tolerance::SkipBad,
            &mut |_, row| {
                match RunEntry::from_row(&row) {
                    Some(e) => self.insert(e),
                    None => self.stats.legacy += 1,
                }
                Ok(())
            },
        )?;
        self.stats.files += 1;
        self.stats.rows += stats.rows;
        self.stats.torn += stats.torn;
        self.stats.skipped += stats.skipped;
        Ok(stats)
    }

    pub fn scan_file(&mut self, path: &std::path::Path) -> Result<ScanStats> {
        // lossy read: a torn tail that cut a multi-byte character must
        // not fail the rebuild (see `reader::read_stream_file`)
        let text = super::reader::read_stream_file(path)?;
        self.scan_text(&text)
    }

    /// Insert with first-wins dedup; fingerprint disagreement counts as a
    /// conflict (the first entry still stands).
    pub fn insert(&mut self, e: RunEntry) {
        match self.entries.get(&e.config_key) {
            None => {
                self.entries.insert(e.config_key, e);
            }
            Some(prev) => {
                if prev.fingerprint != e.fingerprint {
                    self.stats.conflicts += 1;
                } else {
                    self.stats.duplicates += 1;
                }
            }
        }
    }

    pub fn contains(&self, config_key: u64) -> bool {
        self.entries.contains_key(&config_key)
    }

    pub fn get(&self, config_key: u64) -> Option<&RunEntry> {
        self.entries.get(&config_key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> impl Iterator<Item = &RunEntry> {
        self.entries.values()
    }

    /// Which of `configs` are already complete (parallel to the input) —
    /// the scheduler's pre-dispatch consultation, exposed for tests.
    pub fn skip_mask(&self, configs: &[TrainConfig]) -> Vec<bool> {
        configs
            .iter()
            .map(|c| self.contains(super::config_key(c)))
            .collect()
    }

    /// Sorted `(config_key, fingerprint)` pairs — the store's identity
    /// for byte-equivalence assertions in tests and CI.
    pub fn fingerprints(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self
            .entries
            .values()
            .map(|e| (e.config_key, e.fingerprint))
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(key: u64, fp: u64) -> String {
        format!(
            r#"{{"config_key":"{key:016x}","fingerprint":"{fp:016x}","seed":"002a","job":0,"label":"m/adam@lr1e-3","model":"m","optimizer":"adam","lr":0.001,"final_train_loss":1.5,"eval_loss":1.6,"diverged":false,"steps":10}}"#
        )
    }

    #[test]
    fn indexes_rows_and_dedups() {
        let mut idx = RunIndex::new();
        let text = format!("{}\n{}\n{}\n", row(1, 10), row(2, 20), row(1, 10));
        idx.scan_text(&text).unwrap();
        assert_eq!(idx.len(), 2);
        assert!(idx.contains(1) && idx.contains(2) && !idx.contains(3));
        assert_eq!(idx.stats.duplicates, 1);
        assert_eq!(idx.stats.conflicts, 0);
    }

    #[test]
    fn conflicting_fingerprints_counted() {
        let mut idx = RunIndex::new();
        let text = format!("{}\n{}\n", row(1, 10), row(1, 99));
        idx.scan_text(&text).unwrap();
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.get(1).unwrap().fingerprint, 10); // first wins
        assert_eq!(idx.stats.conflicts, 1);
    }

    #[test]
    fn legacy_rows_counted_not_indexed() {
        let mut idx = RunIndex::new();
        // a PR-1-era row: no config_key / seed
        let text = r#"{"label":"m/adam","job":0,"fingerprint":"00000000000000aa"}"#;
        idx.scan_text(&format!("{text}\n")).unwrap();
        assert_eq!(idx.len(), 0);
        assert_eq!(idx.stats.legacy, 1);
        assert_eq!(idx.stats.rows, 1);
    }

    #[test]
    fn tail_torn_mid_multibyte_char_is_recovered() {
        // a SIGKILL can cut the final line inside a multi-byte UTF-8
        // character; the (lossy) file read must confine the damage to
        // the torn line rather than failing the whole rebuild
        let dir = std::env::temp_dir().join("slimadam_index_utf8_tear");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jsonl");
        let mut bytes = format!("{}\n", row(1, 10)).into_bytes();
        bytes.extend_from_slice(b"{\"label\":\"caf\xC3"); // torn inside 'é'
        std::fs::write(&path, bytes).unwrap();
        let mut idx = RunIndex::new();
        let stats = idx.scan_file(&path).unwrap();
        assert_eq!(idx.len(), 1);
        assert_eq!(stats.torn, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn entry_roundtrips_to_summary() {
        let mut idx = RunIndex::new();
        idx.scan_text(&format!("{}\n", row(7, 0xabcd))).unwrap();
        let s = idx.get(7).unwrap().to_summary();
        assert_eq!(s.fingerprint(), 0xabcd);
        assert_eq!(s.lr, 1e-3);
        assert_eq!(s.result.final_train_loss, 1.5);
        assert!(!s.result.diverged);
    }

    #[test]
    fn eval_sentinel_restores_to_nan() {
        let text = r#"{"config_key":"01","fingerprint":"02","seed":"03","job":0,"label":"l","model":"m","optimizer":"o","lr":0.1,"final_train_loss":2.0,"eval_loss":-1,"diverged":false,"steps":5}"#;
        let mut idx = RunIndex::new();
        idx.scan_text(&format!("{text}\n")).unwrap();
        assert!(idx.get(1).unwrap().to_summary().result.eval_loss.is_nan());
    }
}
