//! Streaming, visitor-based JSONL reader — the run store's hot scan path.
//!
//! Index rebuilds and compaction scan every row of every stream file on
//! startup, so this reader never materializes a [`crate::json::Value`]:
//! it drives the substrate scanner ([`crate::json::scan_value`],
//! re-exported here) over the shared [`Lexer`] and consumes the flat
//! [`Event`] stream. Escape-free strings (the overwhelmingly common case
//! in sweep rows) are borrowed straight from the input buffer — the scan
//! allocates only when a string actually contains an escape.
//!
//! Crash tolerance: a `SIGKILL`ed sweep can tear at most the *final*
//! line of a stream file (the writer appends each row in one
//! `write_all`, newline included — `metrics::JsonlWriter`). The scanner
//! therefore treats an unparseable, unterminated last line as expected
//! damage ([`Tolerance::TornTail`], the default), while mid-file
//! corruption stays a hard error unless the caller opts into
//! [`Tolerance::SkipBad`] (used by `runstore::compact` to salvage what
//! it can).

use std::borrow::Cow;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Lexer;

// The structural grammar itself lives in the substrate layer
// (`json::scan_value`); this module re-exports it so run-store callers
// keep one import site for the whole scan toolkit.
pub use crate::json::{scan_value, Event, Visitor};

// ---------------------------------------------------------------------------
// Row-level JSONL scanning
// ---------------------------------------------------------------------------

/// A top-level scalar field of one row.
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar<'a> {
    Str(Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

/// Borrowed view of one JSONL row: the raw line plus its depth-1 scalar
/// fields in document order. Nested objects/arrays are validated during
/// the scan but not collected — the run index only needs the flat
/// metadata fields, so the hot path stays allocation-free.
#[derive(Debug)]
pub struct RowView<'a> {
    /// The raw line, exactly as stored (no trailing newline).
    pub line: &'a str,
    pub fields: Vec<(Cow<'a, str>, Scalar<'a>)>,
}

impl<'a> RowView<'a> {
    pub fn get(&self, key: &str) -> Option<&Scalar<'a>> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Scalar::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(Scalar::Num(n)) => Some(*n),
            _ => None,
        }
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        let n = self.f64(key)?;
        (n >= 0.0 && n.fract() == 0.0).then_some(n as usize)
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(Scalar::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Fixed-width hex field (fingerprints, config keys, seeds — stored
    /// as hex strings because JSON numbers lose u64 precision).
    pub fn hex_u64(&self, key: &str) -> Option<u64> {
        u64::from_str_radix(self.str(key)?, 16).ok()
    }
}

/// Collects depth-1 scalars of a root object into a [`RowView`].
struct TopCollector<'a> {
    depth: usize,
    pending_key: Option<Cow<'a, str>>,
    fields: Vec<(Cow<'a, str>, Scalar<'a>)>,
}

impl<'a> TopCollector<'a> {
    fn new() -> Self {
        TopCollector {
            depth: 0,
            pending_key: None,
            fields: Vec::with_capacity(16),
        }
    }

    fn scalar(&mut self, s: Scalar<'a>) {
        if self.depth == 1 {
            if let Some(k) = self.pending_key.take() {
                self.fields.push((k, s));
            }
        }
    }

    // Inherent (not a `Visitor` impl: that would overlap the blanket
    // closure impl under coherence) — `parse_row` adapts it via closure.
    fn on_event(&mut self, ev: Event<'a>) -> Result<()> {
        match ev {
            Event::ObjBegin | Event::ArrBegin => {
                self.pending_key = None;
                self.depth += 1;
            }
            Event::ObjEnd | Event::ArrEnd => self.depth -= 1,
            Event::Key(k) => {
                if self.depth == 1 {
                    self.pending_key = Some(k);
                }
            }
            Event::Str(s) => self.scalar(Scalar::Str(s)),
            Event::Num(n) => self.scalar(Scalar::Num(n)),
            Event::Bool(b) => self.scalar(Scalar::Bool(b)),
            Event::Null => self.scalar(Scalar::Null),
        }
        Ok(())
    }
}

/// Parse one JSONL line into a [`RowView`]. The row must be a single
/// JSON object with nothing but whitespace after it.
pub fn parse_row(line: &str) -> Result<RowView<'_>> {
    let mut lex = Lexer::new(line);
    lex.skip_ws();
    if lex.peek()? != b'{' {
        bail!("JSONL row must be an object");
    }
    let mut c = TopCollector::new();
    scan_value(&mut lex, &mut |ev| c.on_event(ev))?;
    lex.skip_ws();
    if !lex.at_end() {
        bail!("trailing garbage at byte {}", lex.pos());
    }
    Ok(RowView { line, fields: c.fields })
}

/// How to treat rows that fail to parse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tolerance {
    /// Any bad row is an error.
    Strict,
    /// An unterminated, unparseable *final* line is recovered (counted in
    /// [`ScanStats::torn`]) — the crash signature line-atomic appends
    /// guarantee. Anything else is an error. The default.
    TornTail,
    /// Like `TornTail`, but mid-file bad rows are skipped and counted
    /// instead of fatal (compaction salvage mode).
    SkipBad,
}

/// What a scan saw, beyond the rows it delivered.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScanStats {
    /// Well-formed rows delivered to the callback.
    pub rows: usize,
    /// Unterminated final lines recovered (0 or 1 per file).
    pub torn: usize,
    /// Mid-file bad rows skipped (only under [`Tolerance::SkipBad`]).
    pub skipped: usize,
    /// Total bytes scanned.
    pub bytes: usize,
}

impl ScanStats {
    pub fn merge(&mut self, other: ScanStats) {
        self.rows += other.rows;
        self.torn += other.torn;
        self.skipped += other.skipped;
        self.bytes += other.bytes;
    }
}

/// Scan JSONL text, calling `on_row(line_number, row)` for each
/// well-formed row (line numbers are 1-based, counting every line).
/// Blank lines are ignored. See [`Tolerance`] for damage handling.
pub fn scan_jsonl<'a, F>(
    text: &'a str,
    tol: Tolerance,
    mut on_row: F,
) -> Result<ScanStats>
where
    F: FnMut(usize, RowView<'a>) -> Result<()>,
{
    let mut stats = ScanStats { bytes: text.len(), ..Default::default() };
    let mut start = 0;
    let mut lineno = 0;
    while start < text.len() {
        lineno += 1;
        let (line, had_newline, next) = match text[start..].find('\n') {
            Some(p) => (&text[start..start + p], true, start + p + 1),
            None => (&text[start..], false, text.len()),
        };
        start = next;
        let line = line.strip_suffix('\r').unwrap_or(line);
        if line.trim().is_empty() {
            continue;
        }
        match parse_row(line) {
            Ok(view) => {
                stats.rows += 1;
                on_row(lineno, view)?;
            }
            Err(e) => {
                let torn_tail = next >= text.len() && !had_newline;
                match tol {
                    Tolerance::Strict => {
                        return Err(e).context(format!("line {lineno}"))
                    }
                    Tolerance::TornTail | Tolerance::SkipBad if torn_tail => {
                        stats.torn += 1;
                    }
                    Tolerance::TornTail => {
                        return Err(e).context(format!(
                            "line {lineno} (mid-file corruption; \
                             `slimadam runs compact` can salvage)"
                        ))
                    }
                    Tolerance::SkipBad => stats.skipped += 1,
                }
            }
        }
    }
    Ok(stats)
}

/// Read a stream file for scanning, tolerating a torn tail that cut a
/// multi-byte UTF-8 sequence mid-character: invalid bytes decode
/// lossily (U+FFFD), which confines the damage to the already
/// unparseable torn line instead of failing the whole read — a strict
/// `read_to_string` would abort `runs ls`/`report`/`compact` on exactly
/// the files they exist to salvage. Complete rows are pure JSON (valid
/// UTF-8), so the lossy decode is the identity for them.
pub fn read_stream_file(path: &Path) -> Result<String> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    Ok(match String::from_utf8(bytes) {
        Ok(s) => s, // valid UTF-8: reuse the buffer without re-copying
        Err(e) => String::from_utf8_lossy(e.as_bytes()).into_owned(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    fn events(src: &str) -> Vec<String> {
        let mut lex = Lexer::new(src);
        let mut out = Vec::new();
        scan_value(&mut lex, &mut |ev: Event<'_>| {
            out.push(format!("{ev:?}"));
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn scalar_events() {
        assert_eq!(events("42"), ["Num(42.0)"]);
        assert_eq!(events("true"), ["Bool(true)"]);
        assert_eq!(events("null"), ["Null"]);
        assert_eq!(events(r#""hi""#), [r#"Str("hi")"#]);
    }

    #[test]
    fn nested_events_in_document_order() {
        let evs = events(r#"{"a": [1, {"b": null}], "c": "d"}"#);
        assert_eq!(
            evs,
            [
                "ObjBegin",
                r#"Key("a")"#,
                "ArrBegin",
                "Num(1.0)",
                "ObjBegin",
                r#"Key("b")"#,
                "Null",
                "ObjEnd",
                "ArrEnd",
                r#"Key("c")"#,
                r#"Str("d")"#,
                "ObjEnd",
            ]
        );
    }

    #[test]
    fn streaming_and_dom_agree_on_rejects() {
        for s in ["NaN", "+1", "01", "1.", r#""\ud800""#, "{", "[1,]"] {
            let mut lex = Lexer::new(s);
            let stream = scan_value(&mut lex, &mut |_ev: Event<'_>| Ok(()));
            assert!(stream.is_err(), "streaming must reject {s:?}");
            assert!(Value::parse(s).is_err(), "DOM must reject {s:?}");
        }
    }

    #[test]
    fn row_view_extracts_top_level_scalars() {
        let row = parse_row(
            r#"{"label":"gpt/adam","lr":0.001,"diverged":false,
               "memory":{"v_elems":10},"fingerprint":"00ff00ff00ff00ff"}"#,
        )
        .unwrap();
        assert_eq!(row.str("label"), Some("gpt/adam"));
        assert_eq!(row.f64("lr"), Some(1e-3));
        assert_eq!(row.bool("diverged"), Some(false));
        assert_eq!(row.hex_u64("fingerprint"), Some(0x00ff00ff00ff00ff));
        // nested object fields are not lifted to the top level
        assert!(row.get("v_elems").is_none());
        assert!(row.get("memory").is_none());
    }

    #[test]
    fn torn_tail_recovered_not_fatal() {
        let text = "{\"a\":1}\n{\"a\":2}\n{\"a\":3,\"tru";
        let mut seen = Vec::new();
        let stats = scan_jsonl(text, Tolerance::TornTail, &mut |_, r| {
            seen.push(r.f64("a").unwrap());
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, [1.0, 2.0]);
        assert_eq!(stats.rows, 2);
        assert_eq!(stats.torn, 1);
    }

    #[test]
    fn mid_file_corruption_is_fatal_unless_skipping() {
        let text = "{\"a\":1}\ngarbage\n{\"a\":3}\n";
        assert!(scan_jsonl(text, Tolerance::TornTail, &mut |_, _| Ok(())).is_err());
        let mut n = 0;
        let stats = scan_jsonl(text, Tolerance::SkipBad, &mut |_, _| {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!((n, stats.rows, stats.skipped), (2, 2, 1));
    }

    #[test]
    fn complete_final_line_without_newline_is_a_row() {
        let text = "{\"a\":1}\n{\"a\":2}";
        let stats =
            scan_jsonl(text, Tolerance::TornTail, &mut |_, _| Ok(())).unwrap();
        assert_eq!((stats.rows, stats.torn), (2, 0));
    }

    #[test]
    fn blank_lines_and_crlf_ignored() {
        let text = "{\"a\":1}\r\n\n{\"a\":2}\n";
        let stats =
            scan_jsonl(text, Tolerance::Strict, &mut |_, _| Ok(())).unwrap();
        assert_eq!(stats.rows, 2);
    }

    #[test]
    fn non_object_rows_rejected() {
        assert!(parse_row("[1,2]").is_err());
        assert!(parse_row("42").is_err());
    }

    #[test]
    fn deep_nesting_bounded_identically_in_both_layers() {
        let nested = |n: usize| {
            let mut s = String::new();
            for _ in 0..n {
                s.push('[');
            }
            for _ in 0..n {
                s.push(']');
            }
            s
        };
        // past the bound: both layers reject (stack-overflow guard)
        let deep = nested(100);
        let mut lex = Lexer::new(&deep);
        assert!(scan_value(&mut lex, &mut |_ev: Event<'_>| Ok(())).is_err());
        assert!(Value::parse(&deep).is_err());
        // within the bound: both layers accept
        let ok = nested(32);
        let mut lex = Lexer::new(&ok);
        assert!(scan_value(&mut lex, &mut |_ev: Event<'_>| Ok(())).is_ok());
        assert!(Value::parse(&ok).is_ok());
    }
}
