//! Background trace flusher (DESIGN.md §15).
//!
//! One thread, started by [`start_tracing`], wakes every ~50 ms, drains
//! every registered span ring, and appends one JSONL row per span to
//! `results/trace/trace-<pid>.jsonl` through the line-atomic
//! [`crate::metrics::JsonlWriter`] — so a crash (even `SIGKILL` mid-flush)
//! tears at most the final line, and the file always re-opens under
//! `runstore::reader::Tolerance::TornTail`.
//!
//! [`stop_tracing`] flips the enabled flag off, joins the flusher after a
//! final drain, writes a `metrics-<pid>.json` registry snapshot next to
//! the trace, and appends a trace footer row (span/drop totals) so
//! saturation is visible in the artifact itself.
//!
//! The flusher also rewrites the `metrics-<pid>.json` snapshot *live*
//! (every [`SNAPSHOT_EVERY_TICKS`] passes, via tmp-file + rename so a
//! reader never observes a half-written snapshot): a still-running daemon
//! is reportable with `slimadam obs report` — its trace file simply has no
//! footer yet, which the report treats as "live", not an error.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::json::Value;
use crate::metrics::JsonlWriter;

use super::ring;
use super::span::Span;

/// Flusher wake cadence. Rings absorb bursts between passes; see
/// [`ring::DEFAULT_CAPACITY`] for the resulting drop threshold.
const FLUSH_INTERVAL: Duration = Duration::from_millis(50);

/// Live metrics-snapshot cadence, in flusher passes (~1 s at the default
/// interval).
const SNAPSHOT_EVERY_TICKS: u64 = 20;

/// Write the registry snapshot atomically (tmp + rename): concurrent
/// readers see either the previous snapshot or the new one, never a torn
/// file. The `.tmp` suffix keeps it outside the report's `.json` glob.
fn write_snapshot(dir: &Path) -> Result<()> {
    let path = dir.join(format!("metrics-{}.json", std::process::id()));
    let tmp = dir.join(format!("metrics-{}.json.tmp", std::process::id()));
    std::fs::write(&tmp, super::registry::snapshot().dump_pretty())?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

struct Flusher {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<u64>,
    dir: PathBuf,
}

fn state() -> &'static Mutex<Option<Flusher>> {
    static STATE: OnceLock<Mutex<Option<Flusher>>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(None))
}

/// The directory the live (or last) tracing session writes into, if any.
pub fn trace_dir() -> Option<PathBuf> {
    state().lock().unwrap().as_ref().map(|f| f.dir.clone())
}

/// Default trace output directory.
pub fn default_dir() -> PathBuf {
    PathBuf::from("results").join("trace")
}

fn drain_all(writer: &mut JsonlWriter, buf: &mut Vec<Span>) -> u64 {
    let mut written = 0u64;
    for r in ring::all_rings() {
        buf.clear();
        r.drain(buf);
        for s in buf.iter() {
            if writer.write(&s.to_json(r.tid())).is_ok() {
                written += 1;
            }
        }
    }
    ring::retire_closed();
    written
}

/// Enable span tracing and start the background flusher writing
/// `trace-<pid>.jsonl` under `dir`. Idempotent: a second call while live
/// is a no-op (the first session's sink wins).
pub fn start_tracing(dir: impl AsRef<Path>) -> Result<()> {
    let mut st = state().lock().unwrap();
    if st.is_some() {
        super::set_enabled(true);
        return Ok(());
    }
    let dir = dir.as_ref().to_path_buf();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("trace-{}.jsonl", std::process::id()));
    let mut writer = JsonlWriter::append(&path)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let snap_dir = dir.clone();
    let handle = std::thread::Builder::new()
        .name("obs-flusher".into())
        .spawn(move || {
            let mut buf: Vec<Span> = Vec::new();
            let mut written = 0u64;
            let mut ticks = 0u64;
            while !stop2.load(Ordering::Acquire) {
                written += drain_all(&mut writer, &mut buf);
                ticks += 1;
                if ticks % SNAPSHOT_EVERY_TICKS == 0 {
                    let _ = write_snapshot(&snap_dir);
                }
                std::thread::sleep(FLUSH_INTERVAL);
            }
            // final pass: spans emitted up to the stop flag land on disk
            written += drain_all(&mut writer, &mut buf);
            let mut footer = Value::obj();
            footer
                .set("kind", "trace_footer")
                .set("spans", written as usize)
                .set("dropped", ring::total_dropped() as usize);
            let _ = writer.write(&footer);
            written
        })?;
    *st = Some(Flusher { stop, handle, dir });
    drop(st);
    super::set_enabled(true);
    Ok(())
}

/// Disable tracing, join the flusher (final drain + footer row), and write
/// the metrics-registry snapshot to `metrics-<pid>.json`. Returns the
/// number of spans flushed over the session (0 if tracing was never on).
pub fn stop_tracing() -> Result<u64> {
    super::set_enabled(false);
    let flusher = state().lock().unwrap().take();
    let Some(Flusher { stop, handle, dir }) = flusher else {
        return Ok(0);
    };
    stop.store(true, Ordering::Release);
    let written = handle.join().unwrap_or(0);
    write_snapshot(&dir)?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::SpanKind;
    use crate::runstore::reader::{scan_jsonl, Tolerance};

    #[test]
    fn start_emit_stop_writes_parseable_trace() {
        let dir = std::env::temp_dir()
            .join(format!("slimadam_obs_flush_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        start_tracing(&dir).unwrap();
        let label = crate::obs::intern("flush-test");
        for i in 0..32u64 {
            crate::obs::emit_instant(SpanKind::Step, label, [i, 0, 0, 0]);
        }
        let written = stop_tracing().unwrap();
        assert!(written >= 32, "flushed {written} < 32 spans");
        let path = dir.join(format!("trace-{}.jsonl", std::process::id()));
        let text = std::fs::read_to_string(&path).unwrap();
        let stats = scan_jsonl(&text, Tolerance::Strict, |_, _| Ok(())).unwrap();
        assert!(stats.rows >= 33); // spans + footer
        assert!(text.contains("trace_footer"));
        let snap = dir.join(format!("metrics-{}.json", std::process::id()));
        assert!(snap.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
