//! `slimadam obs report` — one table from a trace directory
//! (DESIGN.md §15).
//!
//! Merges every `metrics-<pid>.json` registry snapshot (counters and
//! gauges sum across processes; histograms merge count/sum/max and
//! recompute the mean — the per-process p50 survives only when a single
//! snapshot is present) and rolls the `trace-<pid>.jsonl` span streams up
//! to per-kind counts and total durations. Trace files are read under
//! [`Tolerance::TornTail`], so a SIGKILLed run still reports.
//!
//! The report is **live-tolerant**: a still-running daemon's snapshot may
//! be mid-rewrite (unparsable for one flusher tick) and its trace has no
//! `trace_footer` yet. Neither is an error — unreadable snapshots are
//! skipped and counted, and footer-less traces are reported as live.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::runstore::reader::{read_stream_file, scan_jsonl, Tolerance};

fn num(v: &Value) -> Option<f64> {
    match v {
        Value::Num(n) => Some(*n),
        _ => None,
    }
}

/// Merge one registry snapshot into the accumulated metric map.
fn merge_into(acc: &mut BTreeMap<String, Value>, snap: &Value) {
    let Value::Obj(obj) = snap else { return };
    for (k, v) in obj {
        match acc.get_mut(k) {
            None => {
                acc.insert(k.clone(), v.clone());
            }
            Some(Value::Num(a)) => {
                if let Some(b) = num(v) {
                    *a += b;
                }
            }
            Some(Value::Obj(a)) => {
                let Value::Obj(b) = v else { continue };
                for key in ["count", "sum"] {
                    let add = b.get(key).and_then(num).unwrap_or(0.0);
                    if let Some(Value::Num(x)) = a.get_mut(key) {
                        *x += add;
                    }
                }
                let bmax = b.get("max").and_then(num).unwrap_or(0.0);
                if let Some(Value::Num(x)) = a.get_mut("max") {
                    if bmax > *x {
                        *x = bmax;
                    }
                }
                let count = a.get("count").and_then(num).unwrap_or(0.0);
                let sum = a.get("sum").and_then(num).unwrap_or(0.0);
                if count > 0.0 {
                    a.insert("mean".into(), Value::Num(sum / count));
                }
                // quantiles don't merge across snapshots
                a.remove("p50");
            }
            _ => {}
        }
    }
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n:.3}")
    }
}

fn fmt_dur(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[derive(Default)]
struct KindAgg {
    count: u64,
    total_dur_ns: f64,
}

fn files_with_prefix(dir: &Path, prefix: &str, suffix: &str) -> Result<Vec<std::path::PathBuf>> {
    let mut out: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading trace dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(prefix) && n.ends_with(suffix))
        })
        .collect();
    out.sort();
    Ok(out)
}

/// Build the `obs report` table for a trace directory.
pub fn build(dir: &Path) -> Result<String> {
    let metric_files = files_with_prefix(dir, "metrics-", ".json")?;
    let trace_files = files_with_prefix(dir, "trace-", ".jsonl")?;
    if metric_files.is_empty() && trace_files.is_empty() {
        bail!("no metrics-*.json or trace-*.jsonl in {dir:?} — run with --trace first");
    }

    // A live daemon rewrites its snapshot via tmp+rename, so a snapshot is
    // almost always parseable — but a reader racing an old (pre-atomic)
    // writer, or a snapshot on a filesystem without atomic rename, can
    // observe a partial file. Skip and count; never fail the report.
    let mut metrics: BTreeMap<String, Value> = BTreeMap::new();
    let mut partial = 0usize;
    for path in &metric_files {
        let Ok(text) = std::fs::read_to_string(path) else {
            partial += 1;
            continue;
        };
        match Value::parse(&text) {
            Ok(snap) => merge_into(&mut metrics, &snap),
            Err(_) => partial += 1,
        }
    }

    let mut kinds: BTreeMap<String, KindAgg> = BTreeMap::new();
    let mut torn = 0usize;
    let mut live = 0usize;
    for path in &trace_files {
        let text = read_stream_file(path)?;
        let mut footer_seen = false;
        let scan = scan_jsonl(&text, Tolerance::TornTail, |_, row| {
            if let Some(kind) = row.str("kind") {
                if kind == "trace_footer" {
                    footer_seen = true;
                } else {
                    let agg = kinds.entry(kind.to_string()).or_default();
                    agg.count += 1;
                    agg.total_dur_ns += row.f64("dur").unwrap_or(0.0);
                }
            }
            Ok(())
        })
        .with_context(|| format!("scanning {path:?}"))?;
        torn += scan.torn;
        if !footer_seen {
            // no footer: the emitting process is still running (or was
            // killed) — report it as live rather than erroring
            live += 1;
        }
    }

    let mut notes = String::new();
    if torn > 0 {
        notes.push_str(&format!(", {torn} torn tail(s) recovered"));
    }
    if live > 0 {
        notes.push_str(&format!(", {live} live (no footer yet)"));
    }
    if partial > 0 {
        notes.push_str(&format!(", {partial} snapshot(s) mid-write skipped"));
    }
    let mut out = format!(
        "observability report — {} ({} metrics file(s), {} trace file(s){notes})\n",
        dir.display(),
        metric_files.len(),
        trace_files.len(),
    );
    if !metrics.is_empty() {
        out.push_str(&format!("\n{:<36} {}\n", "metric", "value"));
        for (name, v) in &metrics {
            let rendered = match v {
                Value::Num(n) => fmt_num(*n),
                Value::Obj(h) => {
                    let field = |k: &str| h.get(k).and_then(num);
                    let mut parts = Vec::new();
                    if let Some(c) = field("count") {
                        parts.push(format!("count {}", fmt_num(c)));
                    }
                    if let Some(m) = field("mean") {
                        parts.push(format!("mean {m:.2}"));
                    }
                    if let Some(p) = field("p50") {
                        parts.push(format!("p50 {}", fmt_num(p)));
                    }
                    if let Some(m) = field("max") {
                        parts.push(format!("max {}", fmt_num(m)));
                    }
                    parts.join("  ")
                }
                other => other.dump(),
            };
            out.push_str(&format!("{name:<36} {rendered}\n"));
        }
    }
    if !kinds.is_empty() {
        out.push_str(&format!("\n{:<20} {:>8}   {}\n", "span kind", "spans", "total"));
        for (kind, agg) in &kinds {
            out.push_str(&format!(
                "{:<20} {:>8}   {}\n",
                kind,
                agg.count,
                fmt_dur(agg.total_dur_ns)
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merges_snapshots_and_rolls_up_spans() {
        let dir = std::env::temp_dir()
            .join(format!("slimadam_obs_report_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("metrics-1.json"),
            "{\"exec_cache.hits\":3,\"batch.occupancy\":\
             {\"count\":2,\"sum\":6,\"mean\":3.0,\"p50\":4,\"max\":4}}",
        )
        .unwrap();
        std::fs::write(
            dir.join("metrics-2.json"),
            "{\"exec_cache.hits\":5,\"batch.occupancy\":\
             {\"count\":2,\"sum\":10,\"mean\":5.0,\"p50\":4,\"max\":8}}",
        )
        .unwrap();
        std::fs::write(
            dir.join("trace-1.jsonl"),
            "{\"kind\":\"step\",\"ts\":1.0,\"dur\":1000.0,\"tid\":1}\n\
             {\"kind\":\"step\",\"ts\":2.0,\"dur\":2000.0,\"tid\":1}\n\
             {\"kind\":\"trace_footer\",\"spans\":2,\"dropped\":0}\n",
        )
        .unwrap();
        let report = build(&dir).unwrap();
        assert!(report.contains("exec_cache.hits"), "{report}");
        assert!(report.contains("8"), "hits must sum 3+5:\n{report}");
        assert!(report.contains("count 4"), "occupancy count merges:\n{report}");
        assert!(report.contains("max 8"), "{report}");
        assert!(!report.contains("trace_footer"), "{report}");
        assert!(report.contains("step"), "{report}");
        assert!(report.contains("3.00 µs"), "total step dur:\n{report}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn live_daemon_dir_reports_instead_of_erroring() {
        // simulate reporting against a still-running daemon: a half-
        // written metrics snapshot and a footer-less (live) trace
        let dir = std::env::temp_dir()
            .join(format!("slimadam_obs_report_live_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("metrics-7.json"), "{\"serve.submitted\":2,")
            .unwrap();
        std::fs::write(
            dir.join("metrics-8.json"),
            "{\"serve.submitted\":3,\"serve.rows_streamed\":12}",
        )
        .unwrap();
        std::fs::write(
            dir.join("trace-7.jsonl"),
            "{\"kind\":\"serve_wave\",\"ts\":1.0,\"dur\":5000.0,\"tid\":1}\n\
             {\"kind\":\"step\",\"ts\":2.0,\"dur\":100.0,\"tid\":1}\n\
             {\"kind\":\"step\",\"ts\":3.0,\"dur\":100.0,\"ti",
        )
        .unwrap();
        let report = build(&dir).unwrap();
        assert!(report.contains("1 snapshot(s) mid-write skipped"), "{report}");
        assert!(report.contains("1 live (no footer yet)"), "{report}");
        assert!(report.contains("1 torn tail(s) recovered"), "{report}");
        assert!(report.contains("serve.rows_streamed"), "{report}");
        assert!(report.contains("serve_wave"), "{report}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_errors() {
        let dir = std::env::temp_dir()
            .join(format!("slimadam_obs_report_empty_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(build(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
