//! Flight recorder — structured tracing, per-phase metrics, and live SNR
//! telemetry (DESIGN.md §15).
//!
//! Three pieces, all in-repo (no new dependencies):
//!
//! * **Span tracing** ([`span`], [`ring`], [`flush`]): typed spans with
//!   monotonic timestamps pushed into lock-free per-thread ring buffers,
//!   drained by a background flusher into line-atomic
//!   `results/trace/trace-<pid>.jsonl` files. [`chrome`] converts them to
//!   Chrome `trace_event` JSON for `chrome://tracing` / Perfetto.
//! * **Metrics registry** ([`registry`]): named atomic counters / gauges /
//!   histograms replacing the scattered ad-hoc counters. Always on (plain
//!   atomics, no I/O); snapshotted into `RunSummary.metrics` and the
//!   `slimadam obs report` table.
//! * **SNR telemetry** ([`telemetry`]): opt-in `--telemetry snr[:every_n]`
//!   train-loop tap streaming per-tensor SNR + compressible-fraction rows
//!   into the trace stream — the signal the ROADMAP item 5 controller
//!   consumes.
//!
//! ## Identity neutrality
//!
//! Tracing observes, never steers: no code path reads a span, a metric, or
//! the enabled flag to make a training decision, so run fingerprints are
//! bit-identical with tracing on or off (enforced by
//! `rust/tests/obs_trace.rs`).
//!
//! ## Disabled cost
//!
//! When tracing is off every emission site reduces to one relaxed atomic
//! load + branch ([`enabled`]); no timestamps are taken and no spans are
//! constructed. The `fused_step_traced` bench row gates the *enabled* cost
//! at ≤ 5% over the untraced fused step.

pub mod chrome;
pub mod flush;
pub mod registry;
pub mod report;
pub mod ring;
pub mod span;
pub mod telemetry;

pub use flush::{start_tracing, stop_tracing, trace_dir};
pub use ring::SpanRing;
pub use span::{Span, SpanKind};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Global tracing switch. All span emission funnels through [`enabled`];
/// flipping this on/off is the entire cost model of the disabled path.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span tracing live? One relaxed load + branch — the documented
/// disabled-path overhead (ISSUE 7 acceptance).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub(crate) fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Monotonic nanoseconds since the first observability call in this
/// process. Spans across threads share this epoch, so a merged trace
/// orders correctly.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Start timestamp helper: a clock read when tracing is live, 0 (and no
/// clock read) when it is not. Pair with [`emit`]/[`Span::close`].
#[inline]
pub fn clock() -> u64 {
    if enabled() {
        now_ns()
    } else {
        0
    }
}

/// Emit a span into the current thread's ring (drops it, counted, if the
/// ring is full or tracing is disabled).
#[inline]
pub fn emit(span: Span) {
    if !enabled() {
        return;
    }
    ring::push_current_thread(span);
}

/// Emit an instantaneous (zero-duration) span stamped now.
#[inline]
pub fn emit_instant(kind: SpanKind, label: u32, args: [u64; 4]) {
    if !enabled() {
        return;
    }
    let ts = now_ns();
    ring::push_current_thread(Span { kind, start_ns: ts, dur_ns: 0, label, args });
}

/// Emit a duration span opened at `start_ns` (from [`clock`]) and closed
/// now. No-op when tracing is off.
#[inline]
pub fn emit_since(kind: SpanKind, label: u32, start_ns: u64, args: [u64; 4]) {
    if !enabled() {
        return;
    }
    let now = now_ns();
    ring::push_current_thread(Span {
        kind,
        start_ns,
        dur_ns: now.saturating_sub(start_ns),
        label,
        args,
    });
}

// ---------------------------------------------------------------------------
// Label interner
// ---------------------------------------------------------------------------

/// Sentinel label id for "no label".
pub const NO_LABEL: u32 = u32::MAX;

fn interner() -> &'static Mutex<Vec<String>> {
    static INTERNER: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    INTERNER.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern a label string, returning its stable id. Intended for setup-time
/// call sites (engine/job construction); hot loops cache the returned id.
pub fn intern(label: &str) -> u32 {
    let mut v = interner().lock().unwrap();
    if let Some(i) = v.iter().position(|s| s == label) {
        return i as u32;
    }
    v.push(label.to_string());
    (v.len() - 1) as u32
}

/// Resolve an interned id back to its string (empty for [`NO_LABEL`] or
/// unknown ids).
pub fn label_str(id: u32) -> String {
    if id == NO_LABEL {
        return String::new();
    }
    interner()
        .lock()
        .unwrap()
        .get(id as usize)
        .cloned()
        .unwrap_or_default()
}
