//! Lock-free per-thread span rings (DESIGN.md §15).
//!
//! Each emitting thread owns one bounded single-producer / single-consumer
//! ring: the owning thread is the only producer, the background flusher is
//! the only consumer (serialized by the flusher's drain lock). A push is
//! two atomic loads, one slot store, and one release store — no CAS, no
//! mutex, no allocation — so tracing stays off the training hot path.
//!
//! **Overflow contract:** when a ring is full the span is *dropped* and the
//! ring's `dropped` counter is bumped — producers never block and never
//! overwrite unflushed spans. The flusher reports cumulative drops per ring
//! in the trace footer, so a saturated trace is detectable, never silently
//! truncated mid-file.
//!
//! Rings of exited threads (the intra-op pool spawns short-lived scoped
//! workers) are marked closed on thread exit; the flusher drains them one
//! last time and retires them from the registry.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::span::{Span, SpanKind};

/// Spans buffered per thread between flusher passes. At the default 50 ms
/// flush cadence this absorbs ~80k spans/s per thread before dropping.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Bounded SPSC span ring. Producer: the owning thread, via
/// [`SpanRing::push`]. Consumer: the flusher, via [`SpanRing::drain`].
pub struct SpanRing {
    slots: Box<[UnsafeCell<Span>]>,
    /// Next write index (monotonic; slot = `head % cap`). Producer-owned.
    head: AtomicUsize,
    /// Next read index (monotonic). Consumer-owned.
    tail: AtomicUsize,
    /// Spans rejected because the ring was full.
    dropped: AtomicU64,
    /// Producer thread tag carried into trace rows.
    tid: u64,
    /// Set when the owning thread exits; the flusher retires the ring
    /// after a final drain.
    closed: AtomicBool,
}

// Slots are only written by the producer at indices the consumer has not
// yet claimed (head/tail ordering below), and vice versa — the classic
// SPSC argument — so sharing the UnsafeCell slab across the two threads
// is sound.
unsafe impl Sync for SpanRing {}
unsafe impl Send for SpanRing {}

impl SpanRing {
    pub fn new(tid: u64, capacity: usize) -> SpanRing {
        let filler = Span {
            kind: SpanKind::Step,
            start_ns: 0,
            dur_ns: 0,
            label: super::NO_LABEL,
            args: [0; 4],
        };
        SpanRing {
            slots: (0..capacity.max(2))
                .map(|_| UnsafeCell::new(filler))
                .collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            tid,
            closed: AtomicBool::new(false),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn tid(&self) -> u64 {
        self.tid
    }

    /// Cumulative spans dropped at overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }

    /// Producer side (owning thread only). Returns `false` — and counts
    /// the drop — when the ring is full.
    pub fn push(&self, span: Span) -> bool {
        let cap = self.slots.len();
        let head = self.head.load(Ordering::Relaxed);
        // Acquire pairs with the consumer's release store of `tail`: once
        // we observe the freed slots we may reuse them.
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        unsafe { *self.slots[head % cap].get() = span };
        // Release publishes the slot write before the new head.
        self.head.store(head.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side (flusher only — callers must hold the flusher's drain
    /// lock so the single-consumer invariant holds). Appends all pending
    /// spans to `out` and frees their slots.
    pub fn drain(&self, out: &mut Vec<Span>) -> usize {
        let cap = self.slots.len();
        let tail = self.tail.load(Ordering::Relaxed);
        // Acquire pairs with the producer's release store of `head`.
        let head = self.head.load(Ordering::Acquire);
        let n = head.wrapping_sub(tail);
        out.reserve(n);
        let mut i = tail;
        while i != head {
            out.push(unsafe { *self.slots[i % cap].get() });
            i = i.wrapping_add(1);
        }
        // Release publishes the reads before freeing the slots for reuse.
        self.tail.store(head, Ordering::Release);
        n
    }

    /// Pending (unflushed) span count — approximate under concurrency.
    pub fn len(&self) -> usize {
        self.head
            .load(Ordering::Acquire)
            .wrapping_sub(self.tail.load(Ordering::Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Ring registry + thread-local producer handle
// ---------------------------------------------------------------------------

fn rings() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Snapshot of all live rings for the flusher.
pub(crate) fn all_rings() -> Vec<Arc<SpanRing>> {
    rings().lock().unwrap().clone()
}

/// Drop rings that are closed *and* fully drained (called by the flusher
/// after a pass, so short-lived intra-op worker threads don't leak rings).
pub(crate) fn retire_closed() {
    rings()
        .lock()
        .unwrap()
        .retain(|r| !(r.is_closed() && r.is_empty()));
}

/// Total spans dropped across all rings that are still registered.
pub fn total_dropped() -> u64 {
    rings().lock().unwrap().iter().map(|r| r.dropped()).sum()
}

struct RingGuard {
    ring: Arc<SpanRing>,
}

impl Drop for RingGuard {
    fn drop(&mut self) {
        // Clear the raw producer pointer *before* closing: once closed the
        // flusher may retire the ring (dropping the registry's Arc), and a
        // stale pointer from a late TLS-destructor push would dangle.
        let _ = CURRENT.try_with(|c| c.set(std::ptr::null()));
        self.ring.close();
    }
}

thread_local! {
    static CURRENT: Cell<*const SpanRing> = const { Cell::new(std::ptr::null()) };
    static GUARD: std::cell::RefCell<Option<RingGuard>> =
        const { std::cell::RefCell::new(None) };
}

/// Push a span into this thread's ring, registering a fresh ring on first
/// use. Called only behind [`super::enabled`].
pub(crate) fn push_current_thread(span: Span) {
    let Ok(ptr) = CURRENT.try_with(|c| c.get()) else {
        return; // thread TLS is tearing down — drop the span
    };
    if !ptr.is_null() {
        // The ring outlives the pointer: the registry holds one Arc and
        // the thread-local guard another, and the guard clears on drop.
        unsafe { &*ptr }.push(span);
        return;
    }
    let ring = Arc::new(SpanRing::new(
        NEXT_TID.fetch_add(1, Ordering::Relaxed),
        DEFAULT_CAPACITY,
    ));
    rings().lock().unwrap().push(ring.clone());
    ring.push(span);
    let registered = CURRENT
        .try_with(|c| c.set(Arc::as_ptr(&ring)))
        .and_then(|_| {
            GUARD.try_with(|g| *g.borrow_mut() = Some(RingGuard { ring: ring.clone() }))
        });
    if registered.is_err() {
        // Couldn't install the teardown guard — close now so the flusher
        // drains this one span and retires the ring.
        let _ = CURRENT.try_with(|c| c.set(std::ptr::null()));
        ring.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(kind: SpanKind, a0: u64) -> Span {
        Span {
            kind,
            start_ns: a0,
            dur_ns: 0,
            label: crate::obs::NO_LABEL,
            args: [a0, 0, 0, 0],
        }
    }

    #[test]
    fn push_drain_roundtrip() {
        let r = SpanRing::new(1, 8);
        for i in 0..5 {
            assert!(r.push(mk(SpanKind::Step, i)));
        }
        let mut out = Vec::new();
        assert_eq!(r.drain(&mut out), 5);
        let got: Vec<u64> = out.iter().map(|s| s.args[0]).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overflow_drops_and_counts() {
        let r = SpanRing::new(1, 4);
        for i in 0..7 {
            r.push(mk(SpanKind::Step, i));
        }
        assert_eq!(r.dropped(), 3);
        let mut out = Vec::new();
        assert_eq!(r.drain(&mut out), 4);
        // FIFO: the *oldest* spans survive; overflow rejects new ones
        let got: Vec<u64> = out.iter().map(|s| s.args[0]).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        // after a drain the ring accepts pushes again
        assert!(r.push(mk(SpanKind::Step, 99)));
    }

    #[test]
    fn wraparound_preserves_order() {
        let r = SpanRing::new(1, 4);
        let mut out = Vec::new();
        let mut next = 0u64;
        for _ in 0..10 {
            for _ in 0..3 {
                assert!(r.push(mk(SpanKind::Step, next)));
                next += 1;
            }
            r.drain(&mut out);
        }
        let got: Vec<u64> = out.iter().map(|s| s.args[0]).collect();
        let want: Vec<u64> = (0..30).collect();
        assert_eq!(got, want);
    }
}
