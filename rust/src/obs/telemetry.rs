//! Live SNR telemetry — the `--telemetry snr[:every_n]` train-loop tap
//! (DESIGN.md §15).
//!
//! Streams per-tensor SNR triples (Eq. 3, via [`crate::snr::measure`]) and
//! a per-probe compressible-fraction roll-up into the trace as
//! [`SpanKind::Snr`] / [`SpanKind::SnrSummary`] rows. This is the
//! trajectory signal the ROADMAP item 5 controller consumes: it reads the
//! *live* second moments the paper's offline probe only sees post-hoc.
//!
//! The tap is read-only over optimizer state (identity-neutral — it never
//! perturbs the run) and costs nothing unless both tracing is live and a
//! cadence was configured.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::optim::Optimizer;
use crate::runtime::manifest::ParamInfo;
use crate::snr::measure;
use crate::tensor::Tensor;

use super::span::{Span, SpanKind};

/// SNR tap cadence in steps; 0 = off.
static SNR_EVERY: AtomicUsize = AtomicUsize::new(0);

/// Default cadence when `--telemetry snr` is given without `:every_n`.
pub const DEFAULT_EVERY: usize = 25;

/// Configure the tap (`None` disables it).
pub fn set_snr_every(every: Option<usize>) {
    SNR_EVERY.store(every.unwrap_or(0), Ordering::SeqCst);
}

pub fn snr_every() -> Option<usize> {
    match SNR_EVERY.load(Ordering::Relaxed) {
        0 => None,
        n => Some(n),
    }
}

/// Parse the `--telemetry` spec: `snr` or `snr:<every_n>`.
pub fn parse_spec(spec: &str) -> anyhow::Result<usize> {
    let (kind, every) = match spec.split_once(':') {
        Some((k, n)) => (
            k,
            n.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --telemetry cadence {n:?}"))?,
        ),
        None => (spec, DEFAULT_EVERY),
    };
    anyhow::ensure!(
        kind == "snr" && every > 0,
        "unknown --telemetry spec {spec:?} (expected snr[:every_n])"
    );
    Ok(every)
}

/// Should the tap fire at `step`? One relaxed load on the hot path when
/// tracing is off or no cadence is set.
#[inline]
pub fn active(step: usize) -> bool {
    if !super::enabled() {
        return false;
    }
    match SNR_EVERY.load(Ordering::Relaxed) {
        0 => false,
        n => step > 0 && step % n == 0,
    }
}

fn emit_samples<'a>(
    step: usize,
    model: u32,
    samples: impl Iterator<Item = (&'a ParamInfo, crate::snr::SnrSample)>,
) {
    let ts = super::now_ns();
    let mut compressible = 0u64;
    let mut total = 0u64;
    for (info, s) in samples {
        total += 1;
        let best = s.fan_out.max(s.fan_in).max(s.both);
        if best >= 1.0 {
            compressible += 1;
        }
        super::emit(Span {
            kind: SpanKind::Snr,
            start_ns: ts,
            dur_ns: 0,
            label: super::intern(&info.name),
            args: [
                step as u64,
                s.fan_out.to_bits(),
                s.fan_in.to_bits(),
                s.both.to_bits(),
            ],
        });
    }
    if total == 0 {
        return;
    }
    let fraction = compressible as f64 / total as f64;
    super::emit(Span {
        kind: SpanKind::SnrSummary,
        start_ns: ts,
        dur_ns: super::now_ns().saturating_sub(ts),
        label: model,
        args: [step as u64, compressible, total, fraction.to_bits()],
    });
}

/// Tap the split path: read each live second moment off the optimizer
/// (skipping optimizers without an Adam-style V). Call only when
/// [`active`] returned true.
pub fn record_opt(
    step: usize,
    model: u32,
    opt: &dyn Optimizer,
    metas: &[ParamInfo],
) {
    emit_samples(
        step,
        model,
        metas.iter().enumerate().filter_map(|(i, info)| {
            opt.second_moment(i).map(|v| (info, measure(&v, info)))
        }),
    );
}

/// Tap the fused path: measure already-materialized V tensors (from
/// `TrainEngine::second_moments`). Call only when [`active`] returned true.
pub fn record_tensors(step: usize, model: u32, vs: &[Tensor], metas: &[ParamInfo]) {
    emit_samples(
        step,
        model,
        vs.iter().zip(metas).map(|(v, info)| (info, measure(v, info))),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(parse_spec("snr").unwrap(), DEFAULT_EVERY);
        assert_eq!(parse_spec("snr:7").unwrap(), 7);
        assert!(parse_spec("snr:0").is_err());
        assert!(parse_spec("snr:x").is_err());
        assert!(parse_spec("latency").is_err());
    }

    #[test]
    fn inactive_without_tracing_or_cadence() {
        set_snr_every(None);
        assert!(!active(10));
        set_snr_every(Some(5));
        // tracing may be off in this test process: active() must then be
        // false regardless of cadence
        if !crate::obs::enabled() {
            assert!(!active(10));
        }
        set_snr_every(None);
    }
}
