//! Typed spans — the flight recorder's unit of record (DESIGN.md §15).
//!
//! A [`Span`] is plain old data (`Copy`, no heap) so producers can write it
//! into a lock-free ring slot with a single store. String context travels
//! as an interned label id ([`super::intern`]); numeric context rides in
//! four `u64` args whose meaning is per-kind (f64 values are packed with
//! `to_bits`). The flusher resolves both into named JSON fields.

use crate::json::Value;

/// Everything the recorder knows how to describe. The taxonomy mirrors the
/// phases of a sweep: compile & cache, dispatch planning, training steps,
/// evals, store appends, resume skips, intra-op kernel chunks, and the SNR
/// telemetry tap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Backend artifact compile (label = artifact name).
    Compile = 0,
    /// Executable-cache hit (label = artifact name).
    CacheHit = 1,
    /// Executable-cache miss (label = artifact name).
    CacheMiss = 2,
    /// One dispatch group planned (label = shard key; args\[0\] = group
    /// size, args\[1\] = batch cap).
    PlanGroup = 3,
    /// One optimizer step, sequential path (label = model; args\[0\] =
    /// step index).
    Step = 4,
    /// One lockstep batched step (label = model; args\[0\] = step index,
    /// args\[1\] = active lanes, args\[2\] = total lanes).
    BatchedStep = 5,
    /// Final-loss eval pass (label = model; args\[0\] = eval batches).
    Eval = 6,
    /// One result row appended to a run-store stream (label = file stem;
    /// args\[0\] = job index).
    StoreAppend = 7,
    /// A grid point skipped because the run store already holds it
    /// (args\[0\] = job index).
    ResumeSkip = 8,
    /// One intra-op parallel kernel section (label = kernel name;
    /// args\[0\] = chunks, args\[1\] = elements).
    IntraopChunk = 9,
    /// Per-tensor SNR telemetry row (label = param name; args\[0\] = step,
    /// args\[1..4\] = f64 bits of SNR at K=fan_out / fan_in / both).
    Snr = 10,
    /// Per-probe SNR roll-up (label = model; args\[0\] = step, args\[1\] =
    /// compressible params, args\[2\] = total params, args\[3\] = f64 bits
    /// of the compressible fraction).
    SnrSummary = 11,
    /// One serve-daemon dispatch wave (args\[0\] = jobs taken, args\[1\] =
    /// configs expanded, args\[2\] = adaptive batch cap).
    ServeWave = 12,
    /// One adaptive-controller eval (DESIGN.md §18; label = model;
    /// args\[0\] = step, args\[1\] = tensors in reduced mode, args\[2\] =
    /// ruled tensors, args\[3\] = f64 bits of the compressed element
    /// fraction).
    AdaptiveEval = 13,
    /// One adaptive mode switch (label = param name; args\[0\] = step,
    /// args\[1\] = direction, 0 = compress / 1 = decompress, args\[2\] =
    /// f64 bits of the triggering SNR).
    AdaptiveSwitch = 14,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Compile => "compile",
            SpanKind::CacheHit => "cache_hit",
            SpanKind::CacheMiss => "cache_miss",
            SpanKind::PlanGroup => "plan_group",
            SpanKind::Step => "step",
            SpanKind::BatchedStep => "batched_step",
            SpanKind::Eval => "eval",
            SpanKind::StoreAppend => "store_append",
            SpanKind::ResumeSkip => "resume_skip",
            SpanKind::IntraopChunk => "intraop_chunk",
            SpanKind::Snr => "snr",
            SpanKind::SnrSummary => "snr_summary",
            SpanKind::ServeWave => "serve_wave",
            SpanKind::AdaptiveEval => "adaptive_eval",
            SpanKind::AdaptiveSwitch => "adaptive_switch",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        Some(match s {
            "compile" => SpanKind::Compile,
            "cache_hit" => SpanKind::CacheHit,
            "cache_miss" => SpanKind::CacheMiss,
            "plan_group" => SpanKind::PlanGroup,
            "step" => SpanKind::Step,
            "batched_step" => SpanKind::BatchedStep,
            "eval" => SpanKind::Eval,
            "store_append" => SpanKind::StoreAppend,
            "resume_skip" => SpanKind::ResumeSkip,
            "intraop_chunk" => SpanKind::IntraopChunk,
            "snr" => SpanKind::Snr,
            "snr_summary" => SpanKind::SnrSummary,
            "serve_wave" => SpanKind::ServeWave,
            "adaptive_eval" => SpanKind::AdaptiveEval,
            "adaptive_switch" => SpanKind::AdaptiveSwitch,
            _ => return None,
        })
    }

    /// JSON field names for the four numeric args (`""` = unused).
    /// `"f:<name>"` marks an arg carrying `f64::to_bits` payload.
    fn arg_names(self) -> [&'static str; 4] {
        match self {
            SpanKind::Compile => ["", "", "", ""],
            SpanKind::CacheHit | SpanKind::CacheMiss => ["", "", "", ""],
            SpanKind::PlanGroup => ["jobs", "batch_cap", "", ""],
            SpanKind::Step => ["step", "", "", ""],
            SpanKind::BatchedStep => ["step", "active", "lanes", ""],
            SpanKind::Eval => ["batches", "", "", ""],
            SpanKind::StoreAppend => ["job", "", "", ""],
            SpanKind::ResumeSkip => ["job", "", "", ""],
            SpanKind::IntraopChunk => ["chunks", "elems", "", ""],
            SpanKind::Snr => ["step", "f:fan_out", "f:fan_in", "f:both"],
            SpanKind::SnrSummary => {
                ["step", "compressible", "total", "f:fraction"]
            }
            SpanKind::ServeWave => ["jobs", "configs", "batch_cap", ""],
            SpanKind::AdaptiveEval => {
                ["step", "compressed", "ruled", "f:fraction"]
            }
            SpanKind::AdaptiveSwitch => ["step", "direction", "f:snr", ""],
        }
    }
}

/// One recorded event: POD, 56 bytes, written to a ring slot by value.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// Monotonic ns since the process trace epoch ([`super::now_ns`]).
    pub start_ns: u64,
    /// 0 for instantaneous events.
    pub dur_ns: u64,
    /// Interned label id ([`super::intern`]) or [`super::NO_LABEL`].
    pub label: u32,
    /// Per-kind numeric payload (see [`SpanKind::arg_names`]).
    pub args: [u64; 4],
}

impl Span {
    /// Serialize to one trace JSONL row. `tid` is the emitting ring's
    /// thread tag.
    pub fn to_json(&self, tid: u64) -> Value {
        let mut v = Value::obj();
        v.set("kind", self.kind.as_str())
            .set("ts", self.start_ns as f64)
            .set("dur", self.dur_ns as f64)
            .set("tid", tid as usize);
        let name = super::label_str(self.label);
        if !name.is_empty() {
            v.set("name", name);
        }
        for (slot, &arg) in self.kind.arg_names().iter().zip(&self.args) {
            if slot.is_empty() {
                continue;
            }
            if let Some(fname) = slot.strip_prefix("f:") {
                v.set(fname, f64::from_bits(arg));
            } else {
                v.set(slot, arg as usize);
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [
            SpanKind::Compile,
            SpanKind::CacheHit,
            SpanKind::CacheMiss,
            SpanKind::PlanGroup,
            SpanKind::Step,
            SpanKind::BatchedStep,
            SpanKind::Eval,
            SpanKind::StoreAppend,
            SpanKind::ResumeSkip,
            SpanKind::IntraopChunk,
            SpanKind::Snr,
            SpanKind::SnrSummary,
            SpanKind::ServeWave,
            SpanKind::AdaptiveEval,
            SpanKind::AdaptiveSwitch,
        ] {
            assert_eq!(SpanKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(SpanKind::parse("nope"), None);
    }

    #[test]
    fn snr_args_pack_f64() {
        let label = crate::obs::intern("blocks.0.w_q");
        let s = Span {
            kind: SpanKind::Snr,
            start_ns: 10,
            dur_ns: 0,
            label,
            args: [7, 1.5f64.to_bits(), 0.25f64.to_bits(), 3.0f64.to_bits()],
        };
        let v = s.to_json(3);
        assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("fan_out").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(v.get("fan_in").unwrap().as_f64().unwrap(), 0.25);
        assert_eq!(v.get("both").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "blocks.0.w_q");
    }
}
